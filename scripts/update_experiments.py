"""Regenerate the §Dry-run and §Roofline tables in EXPERIMENTS.md from
results/dryrun/*.json. Keeps hand-written sections intact via markers.

    PYTHONPATH=src python scripts/update_experiments.py
"""

import json
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))
sys.path.insert(0, str(ROOT / "src"))

from benchmarks.roofline_report import load_cells, markdown_table  # noqa: E402

BEGIN = "<!-- AUTOGEN:{} BEGIN -->"
END = "<!-- AUTOGEN:{} END -->"


def splice(text: str, tag: str, payload: str) -> str:
    b, e = BEGIN.format(tag), END.format(tag)
    pat = re.compile(re.escape(b) + r".*?" + re.escape(e), re.S)
    block = f"{b}\n{payload}\n{e}"
    if pat.search(text):
        return pat.sub(lambda _: block, text)
    return text + "\n" + block + "\n"


def dryrun_summary(cells) -> str:
    ok = [c for c in cells if c["status"] == "ok"]
    skipped = [c for c in cells if c["status"] == "skipped"]
    err = [c for c in cells if c["status"] == "error"]
    lines = [
        f"- cells compiled OK: **{len(ok)}** "
        f"(single-pod 16x16=256 chips and multi-pod 2x16x16=512 chips)",
        f"- cells skipped by assignment: **{len(skipped)}** "
        f"(full-attention archs at 500k ctx; see DESIGN.md Sec 6)",
        f"- cells failed: **{len(err)}**",
        "",
        "| arch | shape | mesh | compile s | HBM GB/dev (args+tmp) | "
        "collectives present |",
        "|---|---|---|---|---|---|",
    ]
    for c in ok:
        mem = c["memory"].get("peak_bytes_est", 0) / 1e9
        colls = ",".join(k for k, v in c.get("collectives", {}).items() if v)
        lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                     f"{c['compile_s']} | {mem:.2f} | {colls or '-'} |")
    for c in skipped:
        lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | skipped | "
                     f"-- | -- |")
    return "\n".join(lines)


def main():
    cells = load_cells()
    exp = ROOT / "EXPERIMENTS.md"
    text = exp.read_text() if exp.exists() else "# EXPERIMENTS\n"
    text = splice(text, "dryrun", dryrun_summary(cells))
    single = [c for c in cells if c["mesh"] == "single"]
    text = splice(text, "roofline", markdown_table(single))
    exp.write_text(text)
    print(f"updated {exp} with {len(cells)} cells")


if __name__ == "__main__":
    main()
