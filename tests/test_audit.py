"""Tests for the online shadow-audit subsystem (obs/audit.py,
obs/error_model.py, and the engine/policy wiring).

Covers: deterministic replayable sampling (splitmix64 hash, two-level
step/row draw), the componentwise forward-error model (amplification,
budget-conserving target derivation, flip attribution, the relax mask),
the zero-token-perturbation guarantee (audit-on streams token-identical to
audit-off on both kernels with chunked prefill + speculation + the fused
step enabled), lamp_audit_* metric population, tau-monotone audited error,
error-derived targets actually changing policy actuation (the acceptance
criterion), the RELAXED/SHED guardrails, the engine-driven calibration
loop, per-request accumulation, and the hang-diagnostic audit ring.
"""

import numpy as np
import pytest

import jax

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import api
from repro.obs import Observability, ObsConfig
from repro.obs.audit import (AuditConfig, ShadowAuditor, audit_hash,
                             select_rows)
from repro.obs.error_model import (amplification, attribute_flips, calibrate,
                                   derive_target_rates, relax_mask)
from repro.serving import (EngineConfig, LampEngine, PolicyConfig,
                           PolicyController, PolicySignals, SamplingParams)
from repro.serving.policy import MODE_RELAXED, MODE_SHED


@pytest.fixture(scope="module")
def model():
    cfg = reduce_cfg(get_config("gpt2")).replace(vocab=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


_BASE = dict(block_size=4, max_model_len=64, max_prefill_batch=4,
             max_decode_batch=16, max_prefill_tokens=24,
             chunked_prefill=True, speculative=True, draft_len=3,
             fused_step=True)


def _mk(cfg, params, *, rate, **kw):
    base = dict(_BASE)
    base.update(kw)
    audit_kw = {k[6:]: base.pop(k) for k in list(base)
                if k.startswith("audit_")}
    return LampEngine(cfg, params, EngineConfig(
        audit=AuditConfig(rate=rate, **audit_kw), **base))


def _stream(cfg, rng, n=8, greedy=True):
    reqs = []
    for i in range(n):
        prompt = rng.integers(0, cfg.vocab,
                              size=int(rng.integers(4, 16))).tolist()
        reqs.append((prompt, SamplingParams(
            max_new_tokens=int(rng.integers(6, 12)), seed=i,
            temperature=0.0 if greedy or i % 2 == 0 else 0.8)))
    return reqs


def _feed(engine, reqs):
    for i, (prompt, sp) in enumerate(reqs):
        engine.add_request(list(prompt), sp, arrival_time=float(i))


# ------------------------------------------------------- sampling hash

def test_audit_hash_deterministic_and_bounded():
    vals = [audit_hash(s, r, salt) for s in (0, 1, 7, 10**9)
            for r in (0, 3, 99) for salt in (0, 1)]
    assert all(0.0 <= v < 1.0 for v in vals)
    assert audit_hash(5, 2, 0) == audit_hash(5, 2, 0)
    assert audit_hash(5, 2, 0) != audit_hash(5, 2, 1)
    assert audit_hash(5, 2, 0) != audit_hash(2, 5, 0)
    # roughly uniform over many steps (a loose sanity band, not statistics)
    m = np.mean([audit_hash(s, 1, 0) for s in range(2000)])
    assert 0.45 < m < 0.55


def test_select_rows_rate_and_replay():
    ids = [10, 11, 12, 13, 14, 15]
    assert select_rows(3, ids, 0.0, 0, 4) == []
    assert select_rows(3, [], 1.0, 0, 4) == []
    # rate=1 audits every step; the row cap binds and indices are sorted
    for step in range(20):
        rows = select_rows(step, ids, 1.0, 0, 4)
        assert len(rows) == 4
        assert rows == sorted(rows)
        assert rows == select_rows(step, ids, 1.0, 0, 4)   # replayable
    # the step-level draw audits ~rate of steps
    hits = sum(bool(select_rows(s, ids, 0.25, 0, 4)) for s in range(2000))
    assert 0.18 < hits / 2000 < 0.32
    # a different salt audits a different subset of steps
    hits_b = [bool(select_rows(s, ids, 0.25, 7, 4)) for s in range(200)]
    hits_a = [bool(select_rows(s, ids, 0.25, 0, 4)) for s in range(200)]
    assert hits_a != hits_b


def test_audit_config_validation():
    with pytest.raises(ValueError):
        AuditConfig(rate=1.5)
    with pytest.raises(ValueError):
        AuditConfig(ema=0.0)
    with pytest.raises(ValueError):
        AuditConfig(max_rows=0)
    with pytest.raises(ValueError):
        AuditConfig(min_rate=0.6, max_rate=0.5)


# ------------------------------------------------------- error model

def test_amplification_shape_and_top_layer():
    e = np.array([0.1, 0.2, 0.0, 0.05])
    a = amplification(e)
    assert a.shape == e.shape
    assert a[-1] == pytest.approx(1.0)         # nothing above the top layer
    assert np.all(a >= 1.0)
    # deeper layers are amplified by everything above them
    assert a[0] == pytest.approx((1.2) * (1.0) * (1.05))
    assert np.all(amplification(np.zeros(5)) == 1.0)


def test_derive_targets_uniform_is_fixed_point():
    # up to the O(e) amplification skew (deeper layers sit under more
    # stack), uniform audited errors keep the scalar default
    t = derive_target_rates(np.full(4, 1e-3), 0.05)
    assert np.allclose(t, 0.05, rtol=1e-2)


def test_derive_targets_orders_by_error_and_conserves_budget():
    err = np.array([5e-3, 1e-4, 1e-4, 1e-4])
    t = derive_target_rates(err, 0.05)
    assert t[0] > 0.05                  # noisy layer above the scalar default
    assert np.all(t[1:] < 0.05)         # quiet layers give budget up
    assert t.mean() == pytest.approx(0.05, rel=0.05)   # redistributed, not
    assert np.all(t >= 0.005) and np.all(t <= 0.5)     # inflated; clamped
    with pytest.raises(ValueError):
        derive_target_rates(err, 0.0)
    with pytest.raises(ValueError):
        derive_target_rates(err, 1.5)


def test_derive_targets_clamps():
    err = np.array([1.0, 1e-12, 1e-12, 1e-12, 1e-12])
    t = derive_target_rates(err, 0.05, min_rate=0.01, max_rate=0.2)
    assert t[0] == pytest.approx(0.2)           # ceiling
    assert np.allclose(t[1:], 0.01)             # floor


def test_attribute_flips_partitions_rate():
    err = np.array([2e-3, 1e-3, 5e-4])
    attr = attribute_flips(0.06, err)
    assert attr.sum() == pytest.approx(0.06)
    assert attr[0] > attr[1] > attr[2]
    assert np.all(attribute_flips(0.5, np.zeros(3)) == 0.0)


def test_relax_mask_freezes_over_budget_layers():
    err = np.array([1e-2, 1e-5, 1e-5])   # layer 0 owns ~all the error mass
    ok = relax_mask(0.10, err, flip_budget=0.02)
    assert not ok[0]
    assert ok[1] and ok[2]
    assert np.all(relax_mask(0.0, err, flip_budget=0.02))


def test_calibrate_returns_both_halves():
    t, ok = calibrate(np.array([1e-2, 1e-5]), 0.10, 0.05, flip_budget=0.02)
    assert t.shape == ok.shape == (2,)
    assert t[0] > t[1]
    assert not ok[0] and ok[1]


# ------------------------------------------------------- policy integration

def _ctrl(n_layers=2, **kw):
    cfgkw = dict(enabled=True, target_rate=0.05, interval=1, deadband=0.0,
                 ema=1.0)
    cfgkw.update(kw)
    return PolicyController(PolicyConfig(**cfgkw), n_layers, 0.05,
                            base_rule="relaxed", base_draft_len=4)


def _sig(rates, util=0.1, preempt=0, accept=1.0):
    return PolicySignals(layer_rates=np.asarray(rates, np.float64),
                         utilization=util, preemptions=preempt,
                         step_latency_s=0.001, spec_acceptance=accept)


def test_set_error_targets_validation_and_stats():
    c = _ctrl()
    with pytest.raises(ValueError):
        c.set_error_targets([0.1, 0.2, 0.3])        # wrong length
    with pytest.raises(ValueError):
        c.set_error_targets([0.0, 0.1])             # out of (0, 1]
    c.set_error_targets([0.08, 0.02], [True, False])
    s = c.stats()
    assert s["targets"] == [0.08, 0.02]
    assert s["target_updates"] == 1
    assert s["guarded_layers"] == 1


def test_error_targets_change_actuation():
    """The acceptance criterion: error-derived targets split tau where the
    scalar default would move every layer identically. Both layers run the
    same recompute rate; the audited-noisy layer's higher target pulls its
    tau DOWN (recompute more) while the quiet layer's tau rises."""
    scalar = _ctrl()
    scalar.update(_sig([0.05, 0.05]))               # at target: no movement
    tau_scalar = scalar.taus.copy()
    assert tau_scalar[0] == pytest.approx(tau_scalar[1])

    derived = _ctrl()
    t = derive_target_rates(np.array([5e-3, 1e-4]), 0.05)
    derived.set_error_targets(t)
    derived.update(_sig([0.05, 0.05]))
    tau_err = derived.taus
    assert t[0] > 0.05 > t[1]
    assert tau_err[0] < tau_scalar[0]   # high-error layer recomputes more
    assert tau_err[1] > tau_scalar[1]   # quiet layer gives its budget up


def test_relaxed_guardrail_holds_flipping_layer():
    """RELAXED scales targets down -- except for a layer whose audited flip
    attribution is over budget: its tau must not rise above the in-budget
    twin's."""
    c = _ctrl(util_high=0.5, util_low=0.3)
    c.set_error_targets([0.05, 0.05], relax_ok=[False, True])
    c.update(_sig([0.05, 0.05], util=0.6))          # -> RELAXED
    assert c.mode == MODE_RELAXED
    tau = c.taus
    # layer 1 relaxed toward the scaled-down target (tau up); layer 0 held
    # at its full target (rate == target -> no movement)
    assert tau[1] > tau[0]
    assert tau[0] == pytest.approx(0.05, rel=1e-5)


def test_shed_guardrail_holds_flipping_layer():
    c = _ctrl(util_high=0.5, util_low=0.3, shed_util=0.7)
    c.set_error_targets([0.05, 0.05], relax_ok=[False, True])
    tau0 = c.taus.copy()
    c.update(_sig([0.05, 0.05], util=0.9))          # -> SHED
    assert c.mode == MODE_SHED
    tau = c.taus
    assert tau[1] > tau0[1]                         # slews toward tau_max
    assert tau[0] == pytest.approx(tau0[0])         # guarded layer holds


# ------------------------------------------------------- engine integration

@pytest.mark.parametrize("kernel", ["gather", "pallas"])
def test_audit_token_identity(model, kernel):
    """The zero-perturbation acceptance gate: every step audited, full
    feature set on (chunked prefill + speculation + fused step), both
    kernels -- the served token streams must be identical to audit-off."""
    cfg, params = model
    reqs = _stream(cfg, np.random.default_rng(11), greedy=False)
    off = _mk(cfg, params, rate=0.0, kernel=kernel)
    _feed(off, reqs)
    off_outs = {o.req_id: o.tokens for o in off.run_to_completion()}
    on = _mk(cfg, params, rate=1.0, kernel=kernel)
    _feed(on, reqs)
    on_outs = {o.req_id: o.tokens for o in on.run_to_completion()}
    assert on_outs == off_outs
    a = on.stats()["audit"]
    assert a["audited_steps"] == on.total_steps > 0
    assert a["audited_rows"] > 0


def test_audit_metrics_and_per_request_accumulation(model):
    cfg, params = model
    reqs = _stream(cfg, np.random.default_rng(5))
    eng = _mk(cfg, params, rate=1.0)
    _feed(eng, reqs)
    outs = eng.run_to_completion()
    reg = eng.obs.registry
    steps = reg.get("lamp_audit_steps_total").value
    assert steps == eng.total_steps > 0
    assert reg.get("lamp_audit_rows_total").value > 0
    fam = reg.get("lamp_audit_layer_err_total")
    for l in range(cfg.n_layers):
        for site in ("kq", "cum"):
            assert fam.labels(str(l), site).value >= 0.0
    assert fam.labels("0", "kq").value > 0.0
    # per-row histograms saw every audited row
    rows = reg.get("lamp_audit_rows_total").value
    assert reg.get("lamp_audit_logit_rel_err").count == rows
    assert reg.get("lamp_audit_topk_overlap").count == rows
    # per-request accumulation reached the outputs and the finish histogram
    assert all(o.audit_samples > 0 for o in outs)
    assert all(o.audit_err_sum >= 0.0 for o in outs)
    assert reg.get("lamp_audit_request_cum_err").count == len(outs)
    a = eng.stats()["audit"]
    assert a["enabled"] and a["logit_rel_err"] > 0.0
    assert len(a["layer_kq_err"]) == cfg.n_layers
    # the launch rode the "audit" span/launch accounting
    assert eng.obs.registry.get("engine_launches_total") \
        .labels("audit").value == eng.total_steps


def test_audit_sampled_rate_bounds_and_ring(model):
    cfg, params = model
    reqs = _stream(cfg, np.random.default_rng(9))
    eng = _mk(cfg, params, rate=0.5, audit_max_rows=2, audit_salt=3)
    _feed(eng, reqs)
    eng.run_to_completion()
    a = eng.stats()["audit"]
    assert 0 < a["audited_steps"] < eng.total_steps
    assert a["audited_rows"] <= 2 * a["audited_steps"]
    tail = eng.auditor.ring_tail()
    assert 0 < len(tail) <= 8
    assert all("flip_rate=" in line for line in tail)


def test_audit_error_monotone_in_tau(model):
    """Sanity on what the audit measures: recomputing nearly everything
    (tiny tau) must audit (much) less error than recomputing nearly
    nothing (large tau)."""
    cfg, params = model
    reqs = _stream(cfg, np.random.default_rng(2), n=4)

    def run(tau):
        c = cfg.replace(lamp=cfg.lamp.replace(
            kq=cfg.lamp.kq.replace(tau=tau)))
        eng = _mk(c, params, rate=1.0)
        _feed(eng, reqs)
        eng.run_to_completion()
        return eng.stats()["audit"]["logit_rel_err"]

    assert run(1e-4) < run(0.5)


def test_audit_disabled_without_lamp(model):
    cfg, params = model
    eng = _mk(cfg, params, rate=1.0, use_lamp=False)
    assert eng.auditor is None
    assert eng.stats()["audit"] == {"enabled": False}


def test_hang_diagnostic_includes_audit_ring(model):
    cfg, params = model
    reqs = _stream(cfg, np.random.default_rng(4), n=2)
    eng = _mk(cfg, params, rate=1.0)
    _feed(eng, reqs)
    eng.step()
    msg = eng._hang_diagnostic()
    assert "audit ring tail:" in msg
    assert "step=0" in msg
    off = _mk(cfg, params, rate=0.0)
    assert "audit off" in off._hang_diagnostic()


def test_engine_calibration_feeds_policy(model):
    """The full loop: audited per-layer error -> error-model targets ->
    PolicyController. The audited-noisiest layer must end up with the
    highest recompute-rate target."""
    cfg, params = model
    reqs = _stream(cfg, np.random.default_rng(8))
    eng = _mk(cfg, params, rate=1.0, audit_calibrate_every=2,
              audit_min_samples=2,
              policy=PolicyConfig(enabled=True, target_rate=0.05))
    _feed(eng, reqs)
    eng.run_to_completion()
    a = eng.stats()["audit"]
    assert a["calibrations"] > 0
    assert eng.policy.target_updates == a["calibrations"]
    targets = np.asarray(a["targets"])
    err = np.asarray(a["layer_kq_err"]) + np.asarray(a["layer_router_err"])
    assert targets[int(np.argmax(err))] == targets.max()
    assert targets.mean() == pytest.approx(0.05, rel=0.1)
    assert eng.obs.registry.get("policy_target_updates_total").value \
        == a["calibrations"]
    # frozen controllers are the token-identity arm: never calibrated into
    froz = _mk(cfg, params, rate=1.0,
               policy=PolicyConfig(enabled=True, frozen=True))
    _feed(froz, reqs)
    froz.run_to_completion()
    assert froz.policy.target_updates == 0


def test_auditor_account_unit():
    """ShadowAuditor bookkeeping without an engine: EMA seeding, ring
    entries, counter increments, finish_request histogram."""
    obs = Observability(ObsConfig())
    aud = ShadowAuditor(AuditConfig(rate=1.0, ema=0.5), 2, obs)

    class Seq:
        audit_samples = 0
        audit_err_sum = 0.0
        audit_flips = 0

    s = Seq()
    m = {"kq_err": np.array([1e-3, 2e-3]),
         "router_err": np.zeros(2), "cum_err": np.array([1e-3, 3e-3]),
         "logit_rel": np.array([1e-2]), "logit_max_abs": np.array([0.1]),
         "flip": np.array([1.0]), "topk": np.array([0.8])}
    aud.account(0, [s], m)
    assert aud.audited_steps == 1 and aud.audited_rows == 1
    assert aud.flip_rate == 1.0                     # first sample seeds EMA
    assert np.allclose(aud.kq_err, [1e-3, 2e-3])
    m2 = dict(m, flip=np.array([0.0]))
    aud.account(1, [s], m2)
    assert aud.flip_rate == pytest.approx(0.5)      # blended at ema=0.5
    assert s.audit_samples == 2 and s.audit_flips == 1
    assert len(aud.ring) == 2
    assert aud.ring[-1]["worst_layer"] == 1
    aud.finish_request(s)
    assert obs.registry.get("lamp_audit_request_cum_err").count == 1
    assert obs.registry.get("lamp_audit_flips_total").value == 1.0
