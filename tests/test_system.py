"""End-to-end behaviour tests for the LAMP system.

Validates the paper's headline behaviours on a GPT-2-family model (the
paper's own test vehicle, reduced to CPU scale): KL-divergence orderings,
recompute-rate scalings, strict-vs-relaxed Pareto relation, and mu-
independence of the recompute rate (paper Sec 4.3 observation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import LampPolicy
from repro.models import api


@pytest.fixture(scope="module")
def gpt2_setup():
    cfg = get_config("gpt2-small").replace(
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_ff=512,
        vocab=512, max_seq=256)
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 96), 0, cfg.vocab)
    return cfg, params, {"tokens": tokens}


def _mean_kl(p_logits, q_logits):
    p = jax.nn.softmax(p_logits.astype(jnp.float32), -1)
    lp = jax.nn.log_softmax(p_logits.astype(jnp.float32), -1)
    lq = jax.nn.log_softmax(q_logits.astype(jnp.float32), -1)
    return float(jnp.mean(jnp.sum(p * (lp - lq), -1)))


def _logits_with(cfg, params, batch, policy, use_lamp=True):
    c = cfg.replace(lamp=policy)
    return api.forward_logits(c, params, batch, use_lamp=use_lamp,
                              attn_impl="full")


def test_lamp_beats_uniform_low_precision(gpt2_setup):
    """Fig 1/2 qualitative: LAMP at small recompute rate lands much closer
    to the FP32 reference than uniform PS(mu) accumulation."""
    cfg, params, batch = gpt2_setup
    ref = _logits_with(cfg, params, batch, LampPolicy.disabled(), use_lamp=False)
    kl_low = _mean_kl(ref, _logits_with(
        cfg, params, batch, LampPolicy.paper_default(mu=4, tau=2.0)))
    kl_lamp = _mean_kl(ref, _logits_with(
        cfg, params, batch, LampPolicy.paper_default(mu=4, tau=0.05)))
    assert kl_lamp < kl_low / 5


def test_kl_decreases_with_mu(gpt2_setup):
    """Fig 2: KL divergence decays roughly exponentially in mu."""
    cfg, params, batch = gpt2_setup
    ref = _logits_with(cfg, params, batch, LampPolicy.disabled(), use_lamp=False)
    kls = [
        _mean_kl(ref, _logits_with(cfg, params, batch,
                                   LampPolicy.paper_default(mu=mu, tau=2.0)))
        for mu in (3, 6, 10)
    ]
    assert kls[0] > kls[1] > kls[2]


def test_relaxed_close_to_strict(gpt2_setup):
    """Fig 3: relaxed rule (9) is only marginally worse than strict (8)."""
    cfg, params, batch = gpt2_setup
    ref = _logits_with(cfg, params, batch, LampPolicy.disabled(), use_lamp=False)
    kl_strict = _mean_kl(ref, _logits_with(
        cfg, params, batch, LampPolicy.paper_default(mu=4, tau=0.05, rule="strict")))
    kl_relaxed = _mean_kl(ref, _logits_with(
        cfg, params, batch,
        LampPolicy.paper_default(mu=4, tau=0.05, rule="relaxed")))
    kl_low = _mean_kl(ref, _logits_with(
        cfg, params, batch, LampPolicy.paper_default(mu=4, tau=2.0)))
    # both rules improve on uniform-low, relaxed within ~5x of strict
    assert kl_strict < kl_low and kl_relaxed < kl_low
    assert kl_relaxed < max(5 * kl_strict, kl_low * 0.5)


def test_flip_rate_improves(gpt2_setup):
    """Fig 2 second metric: argmax flips vs reference shrink under LAMP."""
    cfg, params, batch = gpt2_setup
    ref = _logits_with(cfg, params, batch, LampPolicy.disabled(), use_lamp=False)
    low = _logits_with(cfg, params, batch, LampPolicy.paper_default(mu=3, tau=2.0))
    lam = _logits_with(cfg, params, batch, LampPolicy.paper_default(mu=3, tau=0.03))
    flips_low = float(jnp.mean((jnp.argmax(low, -1) != jnp.argmax(ref, -1))))
    flips_lam = float(jnp.mean((jnp.argmax(lam, -1) != jnp.argmax(ref, -1))))
    assert flips_lam <= flips_low


def test_moe_router_lamp_protects_routing():
    """Beyond-paper site: router-LAMP keeps top-k routing decisions close to
    FP32 routing under low-precision router logits."""
    from repro.core.policy import LampSite
    from repro.models.moe import router_probs_lamp
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32)) * (64 ** -0.5) * 4
    p_ref, _ = router_probs_lamp(x, w, LampSite(enabled=False))
    p_low, _ = router_probs_lamp(x, w, LampSite(enabled=True, mu=3, tau=2.0,
                                                rule="strict", granularity=1))
    p_lamp, rate = router_probs_lamp(x, w, LampSite(enabled=True, mu=3, tau=0.05,
                                                    rule="strict", granularity=1))
    top_ref = jnp.argmax(p_ref, -1)
    agree_low = float(jnp.mean((jnp.argmax(p_low, -1) == top_ref)))
    agree_lamp = float(jnp.mean((jnp.argmax(p_lamp, -1) == top_ref)))
    assert agree_lamp >= agree_low
    assert float(rate) < 0.6
