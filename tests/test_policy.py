"""Tests for the adaptive LAMP policy controller (serving/policy.py) and
the engine plumbing that applies it.

Covers:
  * controller unit behavior: config validation, the degradation-ladder
    mode machine with enter/exit hysteresis, SHED's tau push and rule-tier
    drop, the acceptance gate on draft shedding, frozen mode
  * hypothesis properties: tau always inside [tau_min, tau_max] with the
    per-update slew bounded by max_step; the deadband holds tau still
    around the setpoint (no oscillation); the mode is monotone in pool
    utilization (more pressure never yields a lower mode)
  * engine integration: controller-off vs frozen-controller streams are
    token-identical on both kernels; moving tau between runs triggers
    zero recompiles (tau rides the jitted steps as a traced operand); a
    live controller actually actuates and publishes stats/gauges
  * bugfix regressions: speculative acceptance counters are clamped to
    the drafts actually kept when a stop token truncates the accepted
    prefix; finished requests leave no per-request engine state behind
    (bounded memory)
"""

import numpy as np
import pytest

import jax

try:                                    # optional, as in tests/conftest.py
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import api
from repro.serving import (EngineConfig, LampEngine, PolicyConfig,
                           PolicyController, PolicySignals, SamplingParams,
                           MODE_NORMAL, MODE_RELAXED, MODE_SHED)


@pytest.fixture(scope="module")
def model():
    cfg = reduce_cfg(get_config("gpt2")).replace(vocab=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _sig(rates=None, util=0.0, preempt=0, lat=0.0, accept=0.0):
    return PolicySignals(
        layer_rates=None if rates is None else np.asarray(rates, np.float64),
        utilization=util, preemptions=preempt, step_latency_s=lat,
        spec_acceptance=accept)


def _ctrl(n_layers=3, tau0=0.01, **over):
    kw = dict(enabled=True, target_rate=0.05, util_high=0.6, util_low=0.4,
              shed_util=0.8)
    kw.update(over)
    return PolicyController(PolicyConfig(**kw), n_layers, tau0,
                            base_rule="relaxed", base_draft_len=4)


# ------------------------------------------------------------- unit behavior

def test_config_validation():
    with pytest.raises(ValueError):
        PolicyConfig(tau_min=0.5, tau_max=0.1)
    with pytest.raises(ValueError):
        PolicyConfig(tau_max=1.0)
    with pytest.raises(ValueError):
        PolicyConfig(ema=0.0)
    with pytest.raises(ValueError):
        PolicyConfig(interval=0)
    with pytest.raises(ValueError):
        PolicyConfig(util_low=0.9, util_high=0.5)
    with pytest.raises(ValueError):
        PolicyController(PolicyConfig(target_rates=[0.1, 0.2]), 3, 0.01)


def test_frozen_never_actuates():
    c = _ctrl(frozen=True)
    base = c.taus.copy()
    for sig in (_sig([0.9, 0.9, 0.9], util=0.99, preempt=3),
                _sig([0.0, 0.0, 0.0], util=0.0, preempt=3),
                _sig(None, util=1.0, preempt=9)):
        act = c.update(sig)
        assert act.changed is False
        assert act.rule is None
        assert act.draft_len == c.base_draft_len
        assert np.array_equal(act.taus, base)
    assert c.stats()["actuations"] == 0
    # the mode machine still tracks (observability), it just never applies
    assert c.mode == MODE_SHED


def test_mode_ladder_hysteresis():
    c = _ctrl()
    assert c.update(_sig(util=0.5)).mode == MODE_NORMAL
    assert c.update(_sig(util=0.65)).mode == MODE_RELAXED    # >= util_high
    # inside the hysteresis band (util_low, util_high): RELAXED holds
    assert c.update(_sig(util=0.5)).mode == MODE_RELAXED
    assert c.update(_sig(util=0.3)).mode == MODE_NORMAL      # <= util_low
    # a preemption jumps straight to SHED
    assert c.update(_sig(util=0.3, preempt=1)).mode == MODE_SHED
    # SHED never exits straight to NORMAL, even at zero utilization
    assert c.update(_sig(util=0.0, preempt=1)).mode == MODE_RELAXED
    assert c.update(_sig(util=0.0, preempt=1)).mode == MODE_NORMAL
    assert c.mode_transitions == 5


def test_shed_pushes_tau_and_drops_rule_tier():
    c = _ctrl(tau0=0.01, tau_max=0.9)
    prev = float(c.taus.mean())
    for k in range(40):
        act = c.update(_sig(util=0.99, preempt=k + 1))
        assert act.mode == MODE_SHED
        assert act.rule == "none"          # relaxed -> none, one tier
        cur = float(act.taus.mean())
        assert cur >= prev                 # monotone toward tau_max
        prev = cur
    assert np.allclose(c.taus, 0.9, rtol=1e-5)


def test_acceptance_gates_draft_shedding():
    # low acceptance: the lookahead is wasting blocks -> shed it
    c = _ctrl()
    assert c.update(_sig(util=0.99, preempt=1, accept=0.1)).draft_len == 0
    # high acceptance: speculation drains the pool faster -> keep it
    c = _ctrl()
    assert c.update(_sig(util=0.99, preempt=1, accept=0.9)).draft_len == 4
    # RELAXED halves the draft only when acceptance is low
    c = _ctrl()
    assert c.update(_sig(util=0.7, accept=0.1)).draft_len == 2
    c = _ctrl()
    assert c.update(_sig(util=0.7, accept=0.9)).draft_len == 4


def test_tracking_moves_tau_toward_target():
    c = _ctrl(tau0=0.01, target_rate=0.05)
    # recompute rate far above target: tau must rise (select less)
    t0 = c.taus.copy()
    c.update(_sig([0.5, 0.5, 0.5], util=0.1))
    assert (c.taus > t0).all()
    # far below target: tau must fall (select more)
    c = _ctrl(tau0=0.01, target_rate=0.05)
    t0 = c.taus.copy()
    c.update(_sig([0.001, 0.001, 0.001], util=0.1))
    assert (c.taus < t0).all()


# ------------------------------------------------------- hypothesis properties

if HAVE_HYPOTHESIS:
    _rates = st.lists(st.floats(0.0, 1.0), min_size=3, max_size=3)
    _utils = st.floats(0.0, 1.0)

    @given(st.lists(st.tuples(_rates, _utils, st.integers(0, 2),
                              st.floats(0.0, 1.0)),
                    min_size=1, max_size=25))
    def test_tau_within_clamps_and_slew_bounded(steps):
        c = _ctrl(tau0=0.01, tau_min=1e-4, tau_max=0.9, max_step=0.25)
        preempt = 0
        for rates, util, dp, accept in steps:
            prev = np.log(c.taus.astype(np.float64))
            preempt += dp
            c.update(_sig(rates, util=util, preempt=preempt, accept=accept))
            cur = np.log(c.taus.astype(np.float64))
            assert (c.taus >= 1e-4 * (1 - 1e-5)).all()
            assert (c.taus <= 0.9 * (1 + 1e-5)).all()
            assert (np.abs(cur - prev) <= 0.25 + 1e-5).all()

    @given(st.lists(st.floats(-1.0, 1.0), min_size=3, max_size=3),
           st.integers(1, 10))
    def test_deadband_holds_tau_still(jitter, n_steps):
        # rates pinned inside the deadband around the setpoint: tau never
        # moves, so the loop cannot oscillate around its own target
        target, deadband = 0.05, 0.1
        c = _ctrl(target_rate=target, deadband=deadband)
        base = c.taus.copy()
        rates = [target * (1.0 + deadband * j) for j in jitter]
        for _ in range(n_steps):
            act = c.update(_sig(rates, util=0.1))
            assert np.array_equal(act.taus, base)

    @given(st.lists(st.tuples(_utils, st.integers(0, 1)), max_size=10),
           _utils, _utils)
    def test_mode_monotone_in_utilization(prefix, u1, u2):
        lo, hi = min(u1, u2), max(u1, u2)
        a, b = _ctrl(), _ctrl()
        preempt = 0
        for util, dp in prefix:
            preempt += dp
            a.update(_sig(util=util, preempt=preempt))
            b.update(_sig(util=util, preempt=preempt))
        ma = a.update(_sig(util=lo, preempt=preempt)).mode
        mb = b.update(_sig(util=hi, preempt=preempt)).mode
        assert ma <= mb
else:                                    # keep the property names visible
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_policy_hypothesis_properties():
        pass


# --------------------------------------------------------- engine integration

def _reqs(rng, cfg, n, max_new=8):
    return [(rng.integers(0, cfg.vocab, size=int(rng.integers(3, 16))
                          ).tolist(),
             SamplingParams(max_new_tokens=int(rng.integers(2, max_new + 1)),
                            seed=i))
            for i in range(n)]


def _run(cfg, params, reqs, **ekw):
    kw = dict(block_size=4, max_model_len=64, max_prefill_tokens=64,
              max_prefill_batch=4, max_decode_batch=8, use_lamp=True)
    kw.update(ekw)
    engine = LampEngine(cfg, params, EngineConfig(**kw))
    for prompt, sampling in reqs:
        engine.add_request(prompt, sampling)
    outs = engine.run_to_completion()
    return engine, {o.req_id: o.tokens for o in outs}


@pytest.mark.parametrize("kernel", ["gather", "pallas"])
def test_frozen_controller_token_identity(model, kernel):
    """The frozen (observe-only) controller must not perturb serving: its
    token streams are bit-identical to a controller-less engine."""
    cfg, params = model
    rng = np.random.default_rng(7)
    reqs = _reqs(rng, cfg, 6)
    _, off = _run(cfg, params, reqs, kernel=kernel)
    eng, frz = _run(cfg, params, reqs, kernel=kernel,
                    policy=PolicyConfig(enabled=True, frozen=True,
                                        util_high=0.5, util_low=0.3,
                                        shed_util=0.7))
    assert frz == off
    assert eng.stats()["policy"]["frozen"] is True
    assert eng.stats()["policy"]["actuations"] == 0


@pytest.mark.parametrize("kernel", ["gather", "pallas"])
def test_tau_move_zero_recompile(model, kernel):
    """tau is a traced operand of the jitted steps: changing every layer's
    threshold between streams must not trigger a single recompile."""
    cfg, params = model
    rng = np.random.default_rng(8)
    reqs = _reqs(rng, cfg, 4)
    # prefix cache off: a rerun of the same prompts would otherwise prefill
    # through new (cached-window) bucket shapes, compiling for the shape --
    # noise this test must exclude to isolate the tau operand
    engine, _ = _run(cfg, params, reqs, kernel=kernel, prefix_cache=False)
    warm = engine.stats()["compiles"]
    engine._taus = np.clip(engine._taus * 0.31 + 0.003, 1e-4,
                           0.9).astype(np.float32)
    for prompt, sampling in reqs:
        engine.add_request(prompt, sampling)
    engine.run_to_completion()
    assert engine.stats()["compiles"] == warm


def test_live_controller_actuates_and_publishes(model):
    cfg, params = model
    rng = np.random.default_rng(9)
    reqs = _reqs(rng, cfg, 6)
    engine, _ = _run(
        cfg, params, reqs,
        policy=PolicyConfig(enabled=True, target_rate=0.01,
                            util_high=0.01, util_low=0.0, shed_util=0.9))
    p = engine.stats()["policy"]
    assert p["enabled"] and not p["frozen"]
    assert p["actuations"] > 0
    # tau actually moved off the static site threshold
    assert not np.allclose(engine._taus, float(cfg.lamp.kq.tau))
    # and the actuation is visible in the metrics registry
    snap = engine.obs.registry.snapshot()
    assert "lamp_tau" in snap and "policy_mode" in snap
    assert snap["policy_actuations_total"] > 0
    # one tau gauge per layer, tracking the live thresholds
    assert len(snap["lamp_tau"]) == cfg.n_layers
    gauges = sorted((k, v) for k, v in snap["lamp_tau"].items())
    assert np.allclose([v for _, v in gauges], engine._taus)


# --------------------------------------------------------- bugfix regressions

def test_spec_accept_clamped_on_stop_token(model):
    """A stop token inside the accepted prefix truncates the emit; the
    acceptance counters must count only the drafts actually kept."""
    cfg, params = model
    rng = np.random.default_rng(10)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(4, 12))
                            ).tolist() for _ in range(6)]
    base = [(p, SamplingParams(max_new_tokens=16, seed=i))
            for i, p in enumerate(prompts)]
    _, ref = _run(cfg, params, base, speculative=True, draft_len=4)
    # stop each request on a token it is known to emit mid-stream, so the
    # truncation lands inside accepted prefixes across the batch
    stopped = [(p, SamplingParams(max_new_tokens=16, seed=i,
                                  stop_token=ref[i][len(ref[i]) // 2]))
               for i, p in enumerate(prompts)]
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=4, max_model_len=64, max_prefill_tokens=64,
        max_prefill_batch=4, max_decode_batch=8, use_lamp=True,
        speculative=True, draft_len=4))
    for prompt, sampling in stopped:
        engine.add_request(prompt, sampling)
    outs = {o.req_id: o for o in engine.run_to_completion()}
    n_stop = 0
    for i, (p, sp) in enumerate(stopped):
        o = outs[i]
        # truncation identity: the stopped stream is the unstopped stream
        # cut at the first stop-token occurrence
        cut = ref[i].index(sp.stop_token)
        assert o.tokens == ref[i][:cut + 1]
        if o.finish_reason == "stop_token":
            n_stop += 1
        # the regression: accepted counts only drafts actually appended
        assert o.spec_accepted <= len(o.tokens)
        assert o.spec_accepted <= o.spec_drafted
    assert n_stop > 0
    s = engine.stats()
    assert s["spec_accepted_tokens"] <= s["spec_drafted_tokens"]
    assert 0.0 <= s["spec_acceptance_rate"] <= 1.0


def test_finished_requests_leave_no_state_behind(model):
    """Finished sequences are pruned from the live table and the finished
    ring is bounded, so a long-lived engine's memory cannot grow with the
    request count (while stats() keys stay intact)."""
    cfg, params = model
    rng = np.random.default_rng(11)
    reqs = _reqs(rng, cfg, 10, max_new=5)
    engine, outs = _run(cfg, params, reqs, finished_retention=4)
    assert len(outs) == 10
    assert engine._seqs == {}                 # live table fully pruned
    assert len(engine._finished) <= 4         # retention ring bounded
    s = engine.stats()
    assert s["num_finished"] == 10            # counters survive the pruning
    assert s["cached_tokens"] >= 0 and s["resume_cached_tokens"] >= 0
    assert np.isfinite(s["latency_p50_s"]) and np.isfinite(s["latency_p99_s"])
