import os

import pytest

# Tests run on the single real CPU device (NOT the 512-device dry-run world);
# keep compilation deterministic and quiet.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

# Force every Pallas kernel (flash_decode, lamp_attention, the paged
# attention family, ...) through pl.pallas_call(..., interpret=True): tier-1
# runs the real kernel bodies on CPU instead of skipping them, and tests
# that flip engine/model code onto the "pallas" kernel path exercise the
# same code that compiles to Mosaic on TPU. kernels/ops._default_interpret
# reads this at call time.
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")

import jax

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True)
def _pallas_interpret_on_cpu(monkeypatch):
    """Keep the interpret flag pinned even for tests that scrub os.environ."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET",
                       os.environ.get("REPRO_PALLAS_INTERPRET", "1"))

# Hypothesis profiles (no-op when hypothesis is not installed). Tier-1 / CI
# run the pinned deterministic "ci" profile (derandomized, 500 examples) via
# HYPOTHESIS_PROFILE=ci; plain local runs get a quicker derandomized "dev"
# profile. The genuinely random deep fuzz lives behind `pytest -m slow`.
try:
    from hypothesis import settings

    settings.register_profile("ci", max_examples=500, deadline=None,
                              derandomize=True, print_blob=True)
    settings.register_profile("dev", max_examples=100, deadline=None,
                              derandomize=True)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:
    pass
