import os

# Tests run on the single real CPU device (NOT the 512-device dry-run world);
# keep compilation deterministic and quiet.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

import jax

jax.config.update("jax_enable_x64", False)
