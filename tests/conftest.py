import os

# Tests run on the single real CPU device (NOT the 512-device dry-run world);
# keep compilation deterministic and quiet.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

import jax

jax.config.update("jax_enable_x64", False)

# Hypothesis profiles (no-op when hypothesis is not installed). Tier-1 / CI
# run the pinned deterministic "ci" profile (derandomized, 500 examples) via
# HYPOTHESIS_PROFILE=ci; plain local runs get a quicker derandomized "dev"
# profile. The genuinely random deep fuzz lives behind `pytest -m slow`.
try:
    from hypothesis import settings

    settings.register_profile("ci", max_examples=500, deadline=None,
                              derandomize=True, print_blob=True)
    settings.register_profile("dev", max_examples=100, deadline=None,
                              derandomize=True)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:
    pass
