"""Per-kernel allclose vs the ref.py oracles, swept over shapes/dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype, scale=1.0):
    x = jax.random.normal(jax.random.PRNGKey(key), shape) * scale
    return x.astype(dtype)


# ------------------------------------------------------------- ps_matmul

def _divblock(n, cap=32):
    for c in (cap, 16, 8, 4):
        if n % c == 0:
            return c
    return n


@pytest.mark.parametrize("shape", [(32, 64, 16), (128, 128, 128), (64, 96, 48),
                                   (16, 256, 32)])
@pytest.mark.parametrize("mu", [4, 7, 23])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ps_matmul_sweep(shape, mu, dtype):
    M, K, N = shape
    a = _rand(0, (M, K), dtype)
    b = _rand(1, (K, N), dtype)
    bm, bn, bk = _divblock(M), _divblock(N), _divblock(K)
    out = ops.ps_matmul(a, b, mu=mu, block_m=bm, block_n=bn, block_k=bk,
                        interpret=True)
    want = ref.ps_matmul_ref(a, b, mu, bk)
    # mu=23 keeps full f32 accumulation: dot-product reassociation between
    # the pallas dot and the jnp reference leaves ~1e-6 relative noise
    tol = 1e-5 if mu == 23 else 1e-6
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol)


def test_ps_matmul_mu23_exact():
    a = _rand(2, (64, 64), jnp.float32)
    b = _rand(3, (64, 64), jnp.float32)
    out = ops.ps_matmul(a, b, mu=23, block_m=32, block_n=32, block_k=32,
                        interpret=True)
    want = jnp.matmul(a, b)
    # blocked K accumulation reorders sums vs single-pass matmul: f32 noise
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- lamp_attention

@pytest.mark.parametrize("T,D,bq,bk,sub", [(64, 32, 16, 16, 8),
                                           (128, 64, 32, 64, 32),
                                           (96, 16, 32, 32, 16)])
@pytest.mark.parametrize("mu,tau", [(5, 0.05), (7, 0.2), (23, 0.05)])
@pytest.mark.parametrize("causal", [True, False])
def test_lamp_flash_attention_sweep(T, D, bq, bk, sub, mu, tau, causal):
    B, H = 1, 2
    q = _rand(0, (B, H, T, D), jnp.float32, 1.5)
    k = _rand(1, (B, H, T, D), jnp.float32, 1.5)
    v = _rand(2, (B, H, T, D), jnp.float32)
    kw = dict(mu=mu, tau=tau, causal=causal, block_q=bq, block_k=bk,
              k_subtile=sub)
    out, nsel = ops.lamp_flash_attention(q, k, v, interpret=True, **kw)
    want, nsel_ref = ref.lamp_flash_attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-6)
    assert float(nsel) == float(nsel_ref)


def test_lamp_flash_attention_bf16_inputs():
    B, H, T, D = 1, 1, 64, 32
    q = _rand(0, (B, H, T, D), jnp.bfloat16, 1.5)
    k = _rand(1, (B, H, T, D), jnp.bfloat16, 1.5)
    v = _rand(2, (B, H, T, D), jnp.bfloat16)
    out, _ = ops.lamp_flash_attention(q, k, v, mu=7, tau=0.1, causal=True,
                                      block_q=16, block_k=16, k_subtile=16,
                                      interpret=True)
    want, _ = ref.lamp_flash_attention_ref(q, k, v, mu=7, tau=0.1, causal=True,
                                           block_q=16, block_k=16, k_subtile=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


def test_lamp_flash_attention_vs_exact_at_mu23():
    """mu=23, tau>=1-eps disables LAMP: kernel == plain attention."""
    from repro.core.attention import attention_reference
    B, H, T, D = 1, 2, 64, 32
    q = _rand(3, (B, H, T, D), jnp.float32)
    k = _rand(4, (B, H, T, D), jnp.float32)
    v = _rand(5, (B, H, T, D), jnp.float32)
    out, _ = ops.lamp_flash_attention(q, k, v, mu=23, tau=0.999, causal=True,
                                      block_q=16, block_k=16, interpret=True)
    want = attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------- flash_decode

@pytest.mark.parametrize("S,D,bk,sub", [(128, 32, 32, 8), (256, 64, 64, 32),
                                        (64, 16, 16, 16)])
@pytest.mark.parametrize("mu,tau", [(5, 0.05), (23, 0.2)])
def test_flash_decode_sweep(S, D, bk, sub, mu, tau):
    B, H = 2, 3
    q = _rand(0, (B, H, 1, D), jnp.float32, 1.5)
    kc = _rand(1, (B, H, S, D), jnp.float32, 1.5)
    vc = _rand(2, (B, H, S, D), jnp.float32)
    length = jnp.array([S - 7, S])
    out, nsel = ops.flash_decode(q, kc, vc, length, mu=mu, tau=tau,
                                 block_k=bk, k_subtile=sub, interpret=True)
    want, nsel_ref = ref.flash_decode_ref(q, kc, vc, length, mu=mu, tau=tau,
                                          block_k=bk, k_subtile=sub)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-6)
    assert float(nsel) == float(nsel_ref)


def test_flash_decode_matches_core_decode():
    """Kernel (two-pass exact rule 9) == core decode_attention_lamp with the
    same cast-free granularity semantics at mu=23."""
    from repro.core.attention import decode_attention_lamp
    from repro.core.policy import LampSite
    B, H, S, D = 2, 2, 64, 32
    q = _rand(6, (B, H, 1, D), jnp.float32)
    kc = _rand(7, (B, H, S, D), jnp.float32)
    vc = _rand(8, (B, H, S, D), jnp.float32)
    length = jnp.array([50, 64])
    out, _ = ops.flash_decode(q, kc, vc, length, mu=23, tau=0.99,
                              block_k=16, interpret=True)
    want, _ = decode_attention_lamp(q, kc, vc, length,
                                    LampSite(enabled=False))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- rmsnorm

@pytest.mark.parametrize("shape", [(8, 64), (3, 37, 128), (256, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = _rand(0, shape, dtype)
    w = _rand(1, (shape[-1],), jnp.float32, 0.1)
    out = ops.rmsnorm(x, w, block_rows=16, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=2e-2 if
                               dtype == jnp.bfloat16 else 1e-6, atol=1e-6)


# ----------------------------------------------- kernel <-> model-path cross

def test_kernel_matches_model_attention_path():
    """The Pallas lamp_attention kernel and the model's chunked-LAMP path
    implement the same deployment semantics: one-pass relaxed rule (9),
    cast-only PS(mu). With matching block sizes the outputs agree."""
    from repro.core.attention import chunked_attention_lamp
    from repro.core.policy import LampSite
    B, H, T, D = 1, 2, 128, 32
    q = _rand(10, (B, H, T, D), jnp.float32, 1.5)
    k = _rand(11, (B, H, T, D), jnp.float32, 1.5)
    v = _rand(12, (B, H, T, D), jnp.float32)
    mu, tau, blk = 7, 0.05, 32
    out_k, nsel_k = ops.lamp_flash_attention(
        q, k, v, mu=mu, tau=tau, causal=True, block_q=blk, block_k=blk,
        k_subtile=D, interpret=True)
    site = LampSite(enabled=True, mu=mu, tau=tau, rule="relaxed",
                    granularity=0)
    out_m, aux = chunked_attention_lamp(q, k, v, site, causal=True,
                                        block=blk, onepass=True, q_tiles=1)
    # same selection count and matching outputs: k_subtile=D makes the
    # kernel's subtile rounding == the model's cast-only rounding
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_m),
                               rtol=2e-4, atol=2e-5)
    assert float(nsel_k) == float(aux.n_selected)
