"""Property / differential tests for prefix-cached COW paged KV blocks and
chunked prefill.

Three layers:

  * Pool state-machine properties: random interleavings of admit (alloc +
    prefix-fork + COW), free, and defrag must preserve block conservation
    (free + unique owned == total), never double-free, keep refcounts equal
    to the number of owning sequences, and keep every block table pointing
    at live arena rows. Driven twice: a hypothesis stateful machine (the
    deep harness; skipped when hypothesis is not installed) and a seeded
    numpy random walk over the same shared ops (always runs).
  * Differential: a randomized request stream (shared-prefix groups +
    disjoint prompts, mixed temperatures) through the engine with prefix
    caching + chunked prefill ON must be token-identical to the PR-1
    configuration with both OFF, while allocating strictly fewer blocks
    whenever prefixes overlap by at least one block.
  * Chunked-prefill edge cases: chunk/block-boundary prompt lengths,
    prompts shorter than one chunk, preemption between chunks (resume
    re-prefills only the un-cached suffix), and decode steps interleaving
    mid-prefill.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import api, transformer
from repro.serving import (EngineConfig, LampEngine, PagedKVPool,
                           SamplingParams, Sequence)
from repro.serving.kv_pool import chain_hashes


@pytest.fixture(scope="module")
def model():
    cfg = reduce_cfg(get_config("gpt2")).replace(vocab=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def tiny_cfg():
    return reduce_cfg(get_config("gpt2")).replace(vocab=8)


def _prompt(rng, cfg, n):
    return rng.integers(0, cfg.vocab, size=n).tolist()


# ===================================================================== pool
# Shared op driver: emulates the scheduler's admission (match / cap / share /
# COW / alloc) and release paths against a real pool, then checks the full
# invariant set. Used by both the seeded fuzz walk and the hypothesis
# stateful machine.

class PoolHarness:
    def __init__(self, cfg, n_blocks=8, block_size=2, vocab=3):
        self.pool = PagedKVPool(cfg, n_blocks=n_blocks,
                                block_size=block_size,
                                enable_prefix_cache=True)
        self.vocab = vocab
        self.seqs = {}                  # seq id -> Sequence
        self.next_id = 0

    # -- ops ---------------------------------------------------------------

    def admit(self, tokens):
        """Scheduler-shaped admission; returns the seq id or None when the
        block budget cannot cover it."""
        pool, bs = self.pool, self.pool.block_size
        target = len(tokens)
        matched = pool.match_prefix(tokens)
        cached = min(len(matched) * bs, target - 1)
        kept = -(-cached // bs)
        matched = matched[:kept]
        need_new = pool.blocks_for(target) - kept
        need_cow = 1 if cached % bs else 0
        revive = sum(1 for b in matched if pool.is_cached_free(b))
        if need_new + need_cow + revive > pool.num_free:
            return None
        pool.share(matched)
        blocks = list(matched)
        if need_cow:
            blocks[-1] = pool.copy_on_write(blocks[-1])
        if need_new > 0:
            blocks.extend(pool.alloc(need_new))
        seq = Sequence(self.next_id, list(tokens), SamplingParams(),
                       float(self.next_id))
        self.next_id += 1
        seq.block_ids = blocks
        seq.cache_len = seq.prefill_cursor = target
        # "prefill done": full blocks become matchable
        pool.register_prefix(tokens, blocks, target)
        self.seqs[seq.req_id] = seq
        return seq.req_id

    def free(self, sid):
        seq = self.seqs.pop(sid)
        self.pool.free_blocks(seq.block_ids)
        seq.block_ids = []

    def rollback(self, sid, n_tokens):
        """Speculative rollback: truncate a sequence's cache to n_tokens
        (<= its current cache_len), freeing the surplus blocks and COWing a
        shared/registered partial tail."""
        seq = self.seqs[sid]
        n_tokens = min(n_tokens, seq.cache_len)
        seq.block_ids = self.pool.rollback(seq.block_ids, n_tokens)
        seq.cache_len = seq.prefill_cursor = n_tokens

    def defrag(self):
        live = sorted(self.seqs.values(), key=lambda s: s.arrival_time)
        self.pool.defrag(live)

    # -- invariants ---------------------------------------------------------

    def check(self):
        # the full oracle now lives on the pool itself (production recovery
        # paths run it too); the harness just feeds it every live owner
        self.pool.check_invariants(self.seqs.values())


def _random_tokens(rng, vocab, block_size):
    # tiny vocab + short lengths -> frequent shared prefixes and reuse
    n = int(rng.integers(1, 4 * block_size + 2))
    return rng.integers(0, vocab, size=n).tolist()


def _fuzz_step(h, rng):
    ops = ["admit", "admit", "free", "double_free", "rollback", "defrag"]
    op = ops[int(rng.integers(len(ops)))]
    if op == "admit":
        h.admit(_random_tokens(rng, h.vocab, h.pool.block_size))
    elif op == "free" and h.seqs:
        sid = list(h.seqs)[int(rng.integers(len(h.seqs)))]
        h.free(sid)
    elif op == "rollback" and h.seqs:
        sid = list(h.seqs)[int(rng.integers(len(h.seqs)))]
        h.rollback(sid, int(rng.integers(0, h.seqs[sid].cache_len + 1)))
    elif op == "double_free" and h.seqs:
        # freeing a sequence's blocks twice must raise, never corrupt
        sid = list(h.seqs)[int(rng.integers(len(h.seqs)))]
        blocks = list(h.seqs[sid].block_ids)
        h.free(sid)
        gone = [b for b in blocks if h.pool.refcount.get(b, 0) == 0]
        if gone:
            with pytest.raises(ValueError):
                h.pool.free_blocks(gone)
    elif op == "defrag":
        h.defrag()
    h.check()


def test_pool_invariants_seeded_walk(tiny_cfg):
    """Non-hypothesis fallback: 200-step random walk over the same ops."""
    for seed in range(3):
        rng = np.random.default_rng(seed)
        h = PoolHarness(tiny_cfg)
        for _ in range(200):
            _fuzz_step(h, rng)
        # drain: every request finishes -> all blocks reclaimable
        for sid in list(h.seqs):
            h.free(sid)
        h.check()
        assert h.pool.num_free == h.pool.num_total


def test_pool_double_free_raises(tiny_cfg):
    pool = PagedKVPool(tiny_cfg, n_blocks=6, block_size=2)
    a = pool.alloc(2)
    pool.free_blocks(a)
    with pytest.raises(ValueError, match="double free"):
        pool.free_blocks([a[0]])
    with pytest.raises(ValueError, match="double free"):
        pool.free_blocks([5])       # never allocated == still on free list
    with pytest.raises(ValueError, match="null block"):
        pool.free_blocks([0])
    # shared blocks need one free per owner -- premature re-free must raise
    b = pool.alloc(1)
    pool.share(b)
    pool.free_blocks(b)
    pool.free_blocks(b)           # second owner: fine
    with pytest.raises(ValueError):
        pool.free_blocks(b)       # third: double free


def test_pool_cow_and_sharing_semantics(tiny_cfg):
    pool = PagedKVPool(tiny_cfg, n_blocks=8, block_size=2,
                       enable_prefix_cache=True)
    tokens = [1, 0, 1, 1]                      # two full blocks
    blocks = pool.alloc(2)
    pool.register_prefix(tokens, blocks, 4)
    assert pool.match_prefix(tokens) == blocks
    assert pool.match_prefix([1, 0, 7, 7]) == blocks[:1]
    assert pool.match_prefix([0, 0, 1, 1]) == []
    # a second owner forks the full prefix
    pool.share(blocks)
    assert pool.refcount[blocks[0]] == 2
    # shared + registered blocks must be COW'd before writing
    assert pool.needs_cow(blocks[1])
    new = pool.copy_on_write(blocks[1])
    assert new != blocks[1]
    assert pool.refcount[blocks[1]] == 1 and pool.refcount[new] == 1
    assert not pool.needs_cow(new)
    # the forker releases its share; the original owner still holds block 0
    pool.free_blocks(blocks)
    assert pool.match_prefix(tokens) == blocks
    assert pool.is_cached_free(blocks[1])
    assert pool.refcount[blocks[0]] == 1
    # the original owner and the COW copy go too: registered blocks stay
    # matchable (cached-free) ...
    pool.free_blocks([blocks[0], new])
    assert pool.is_cached_free(blocks[0])
    assert pool.match_prefix(tokens) == blocks
    # ... until eviction reclaims them under pressure
    got = pool.alloc(pool.num_free)
    assert set(blocks) <= set(got), "cached-free blocks must be reclaimable"
    assert pool.match_prefix(tokens) == []


def test_pool_rollback_frees_surplus_and_conserves(tiny_cfg):
    pool = PagedKVPool(tiny_cfg, n_blocks=10, block_size=2)
    blocks = pool.alloc(4)                      # covers 8 tokens
    kept = pool.rollback(blocks, 3)             # 3 tokens -> 2 blocks
    assert kept == blocks[:2]
    assert pool.num_free == pool.num_total - 2
    assert pool.refcount == {blocks[0]: 1, blocks[1]: 1}
    # surplus is really free: re-freeing it raises (double free)
    with pytest.raises(ValueError, match="double free"):
        pool.free_blocks([blocks[2]])
    # rollback to zero releases everything
    assert pool.rollback(kept, 0) == []
    assert pool.num_free == pool.num_total
    # rollback cannot keep more blocks than the sequence owns
    with pytest.raises(ValueError, match="rollback"):
        pool.rollback([], 1)


def test_pool_rollback_cow_never_mutates_shared_block(tiny_cfg):
    """Rolling back into a COW-shared tail must copy, not mutate: the other
    owner's arena row is untouched and its table still points at it."""
    pool = PagedKVPool(tiny_cfg, n_blocks=8, block_size=2,
                       enable_prefix_cache=True)
    blocks = pool.alloc(3)                      # seq A: 6 tokens
    pool.share(blocks)                          # seq B shares all three
    ids = jnp.arange(pool.n_blocks, dtype=jnp.float32)
    pool.k = jnp.ones_like(pool.k) * ids[None, :, None, None, None]
    # A rolls back to 3 tokens: block 2 freed (B still owns it), block 1
    # becomes A's partial tail -> must be COW'd off the shared copy
    kept = pool.rollback(list(blocks), 3)
    assert kept[0] == blocks[0]
    assert kept[1] != blocks[1], "shared partial tail must be copied"
    assert pool.refcount[blocks[0]] == 2        # still shared
    assert pool.refcount[blocks[1]] == 1        # B's copy survives
    assert pool.refcount[blocks[2]] == 1
    assert pool.refcount[kept[1]] == 1
    # the shared row's contents were copied, not moved or zeroed
    assert float(pool.k[0, blocks[1], 0, 0, 0]) == blocks[1]
    assert float(pool.k[0, kept[1], 0, 0, 0]) == blocks[1]
    assert not pool.needs_cow(kept[1])


def test_pool_rollback_registered_tail_cow_and_index_survival(tiny_cfg):
    """Rollback into a registered (prefix-indexed) block COWs the partial
    tail; the index keeps mapping the original block with its contents."""
    pool = PagedKVPool(tiny_cfg, n_blocks=8, block_size=2,
                       enable_prefix_cache=True)
    tokens = [1, 0, 1, 1, 0, 0]
    blocks = pool.alloc(3)
    pool.register_prefix(tokens, blocks, 6)     # all three blocks indexed
    kept = pool.rollback(list(blocks), 3)       # mid-block cap in block 1
    assert kept[0] == blocks[0]
    assert kept[1] != blocks[1], "registered partial tail must be copied"
    # the index still maps the original chain (contents never mutated);
    # freed/copied-off blocks sit on the cached-free LRU, still matchable
    assert pool.match_prefix(tokens) == blocks
    assert pool.is_cached_free(blocks[1]) and pool.is_cached_free(blocks[2])
    # block-aligned rollback keeps the (full, registered) tail without COW
    kept2 = pool.rollback(kept, 2)
    assert kept2 == kept[:1]


def test_engine_rejects_zero_prefill_budget(model):
    cfg, params = model
    with pytest.raises(ValueError, match="max_prefill_tokens"):
        LampEngine(cfg, params, EngineConfig(block_size=4, max_model_len=64,
                                             max_prefill_tokens=0))


def test_match_verifies_content_not_just_hash(tiny_cfg):
    """A chain-hash collision (same hash, different tokens) must degrade to
    a cache miss, never map a request onto foreign KV blocks."""
    pool = PagedKVPool(tiny_cfg, n_blocks=8, block_size=2,
                       enable_prefix_cache=True)
    a = [1, 0, 1, 1]
    blocks = pool.alloc(2)
    pool.register_prefix(a, blocks, 4)
    forged = chain_hashes(a, 2)   # "colliding" hashes for different tokens
    assert pool.match_prefix([2, 2, 2, 2], hashes=forged) == []
    assert pool.match_prefix([1, 0, 2, 2], hashes=forged) == blocks[:1]
    assert pool.match_prefix(a, hashes=forged) == blocks


def test_chain_hashes_prefix_property():
    a = [1, 2, 3, 4, 5, 6]
    b = [1, 2, 3, 4, 9, 9]
    ha, hb = chain_hashes(a, 2), chain_hashes(b, 2)
    assert ha[:2] == hb[:2] and ha[2] != hb[2]
    assert chain_hashes(a, 2, 5) == ha[:2]     # partial coverage: full blocks
    # equal block content at different depth must not collide
    assert chain_hashes([7, 7, 7, 7], 2)[0] != chain_hashes([7, 7, 7, 7], 2)[1]


# The hypothesis stateful machine: the deep property harness. Import-guarded
# (not importorskip) so the seeded fallback tests above still run without
# hypothesis installed; CI pins the "ci" profile (derandomized, 500
# examples) via HYPOTHESIS_PROFILE -- see tests/conftest.py.
try:
    import hypothesis
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    TOKENS = st.lists(st.integers(0, 2), min_size=1, max_size=10)

    class PoolStateMachine(RuleBasedStateMachine):
        cfg = None  # injected by the test

        @initialize()
        def setup(self):
            self.h = PoolHarness(type(self).cfg)

        @rule(tokens=TOKENS)
        def admit(self, tokens):
            self.h.admit(tokens)

        @rule(idx=st.integers(0, 1 << 30))
        def free(self, idx):
            if self.h.seqs:
                self.h.free(list(self.h.seqs)[idx % len(self.h.seqs)])

        @rule(idx=st.integers(0, 1 << 30))
        def double_free_rejected(self, idx):
            """Freeing any sequence's blocks twice must raise, not corrupt."""
            if not self.h.seqs:
                return
            sid = list(self.h.seqs)[idx % len(self.h.seqs)]
            blocks = list(self.h.seqs[sid].block_ids)
            self.h.free(sid)
            gone = [b for b in blocks if self.h.pool.refcount.get(b, 0) == 0]
            if gone:
                with pytest.raises(ValueError):
                    # blocks that actually went free: re-freeing must fault
                    # (still-shared ones would just drop another owner)
                    self.h.pool.free_blocks(gone)

        @rule(idx=st.integers(0, 1 << 30), frac=st.floats(0.0, 1.0))
        def rollback(self, idx, frac):
            """Speculative rollback to any point in a sequence's cache must
            conserve blocks, never corrupt shared/registered state, and
            leave a writable (private) partial tail."""
            if not self.h.seqs:
                return
            sid = list(self.h.seqs)[idx % len(self.h.seqs)]
            n = int(frac * self.h.seqs[sid].cache_len)
            self.h.rollback(sid, n)

        @rule()
        def defrag(self):
            self.h.defrag()

        @invariant()
        def pool_invariants(self):
            if hasattr(self, "h"):
                self.h.check()


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_pool_state_machine(tiny_cfg):
    PoolStateMachine.cfg = tiny_cfg
    hypothesis.stateful.run_state_machine_as_test(PoolStateMachine)


@pytest.mark.slow
@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_pool_state_machine_deep(tiny_cfg):
    """Opt-in deep fuzz (pytest -m slow): many more examples per run."""
    PoolStateMachine.cfg = tiny_cfg
    hypothesis.stateful.run_state_machine_as_test(
        PoolStateMachine,
        settings=hypothesis.settings(max_examples=300, deadline=None,
                                     stateful_step_count=80))


# ============================================================== differential

def _staggered_run(cfg, params, reqs, *, prefix_cache, chunked_prefill,
                   n_blocks=0, max_prefill_tokens=8):
    """One engine pass, arrivals staggered one step apart so later requests
    can hit earlier requests' registered prefixes."""
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=4, max_model_len=64, max_prefill_batch=4,
        max_decode_batch=8, n_blocks=n_blocks,
        max_prefill_tokens=max_prefill_tokens,
        prefix_cache=prefix_cache, chunked_prefill=chunked_prefill))
    outs = []
    for prompt, sampling in reqs:
        engine.add_request(prompt, sampling)
        outs.extend(engine.step())
    outs.extend(engine.run_to_completion())
    return engine, {o.req_id: o for o in outs}


def test_differential_vs_pr1_baseline(model):
    """Prefix caching + chunked prefill ON == both OFF, token for token,
    with strictly fewer blocks allocated (prefixes overlap >= one block)."""
    cfg, params = model
    rng = np.random.default_rng(11)
    shared_a = _prompt(rng, cfg, 12)           # 3 full blocks at bs=4
    shared_b = _prompt(rng, cfg, 8)
    reqs = []
    for i in range(9):
        if i % 3 == 0:
            prompt = shared_a + _prompt(rng, cfg, int(rng.integers(1, 8)))
        elif i % 3 == 1:
            prompt = shared_b + _prompt(rng, cfg, int(rng.integers(1, 8)))
        else:
            prompt = _prompt(rng, cfg, int(rng.integers(3, 20)))
        temp = 0.0 if i % 2 else 0.7
        reqs.append((prompt, SamplingParams(
            max_new_tokens=int(rng.integers(2, 8)), seed=i,
            temperature=temp)))

    on, on_outs = _staggered_run(cfg, params, reqs,
                                 prefix_cache=True, chunked_prefill=True)
    off, off_outs = _staggered_run(cfg, params, reqs,
                                   prefix_cache=False, chunked_prefill=False)
    assert len(on_outs) == len(off_outs) == len(reqs)
    for i in range(len(reqs)):
        assert on_outs[i].tokens == off_outs[i].tokens, f"req {i}"
    s_on, s_off = on.stats(), off.stats()
    assert s_on["blocks_allocated"] < s_off["blocks_allocated"]
    assert s_on["blocks_saved"] > 0
    assert s_on["cached_tokens"] > 0
    assert s_off["blocks_saved"] == 0 and s_off["cached_tokens"] == 0
    # all blocks returned in both configurations
    assert on.pool.num_used == 0 and off.pool.num_used == 0


def test_paged_prefill_window_matches_full(model):
    """Splitting a prompt into windows must reproduce the full prefill's
    last-position logits exactly (same gathered width, row-wise compute)."""
    cfg, params = model
    rng = np.random.default_rng(12)
    prompt = _prompt(rng, cfg, 10)
    bs = 4
    for use_lamp in (False, True):
        arenas = [transformer.init_paged_cache(cfg, 16, bs, jnp.float32)
                  for _ in range(2)]
        bt = jnp.asarray(np.array([[1, 2, 3, 0, 0, 0, 0, 0]], np.int32))
        tokens = np.zeros((1, 16), np.int32)
        tokens[0, :10] = prompt
        full, _, _ = transformer.paged_prefill(
            cfg, params, jnp.asarray(tokens), arenas[0], bt,
            jnp.asarray([10], jnp.int32), use_lamp=use_lamp)
        # two windows: 6 tokens then 4 tokens
        w1 = np.zeros((1, 8), np.int32)
        w1[0, :6] = prompt[:6]
        _, arena, _ = transformer.paged_prefill_window(
            cfg, params, jnp.asarray(w1), arenas[1], bt,
            jnp.asarray([0], jnp.int32), jnp.asarray([6], jnp.int32),
            use_lamp=use_lamp)
        w2 = np.zeros((1, 4), np.int32)
        w2[0, :4] = prompt[6:]
        split, _, _ = transformer.paged_prefill_window(
            cfg, params, jnp.asarray(w2), arena, bt,
            jnp.asarray([6], jnp.int32), jnp.asarray([4], jnp.int32),
            use_lamp=use_lamp)
        np.testing.assert_array_equal(np.asarray(full), np.asarray(split))


# ========================================================== chunk edge cases

@pytest.mark.parametrize("plen", [4, 8, 16, 3, 17, 9])
def test_chunk_and_block_boundaries(model, plen):
    """Prompt lengths on / off chunk (8) and block (4) boundaries, shorter
    than one chunk, and spanning several chunks: identical to baseline."""
    cfg, params = model
    rng = np.random.default_rng(13)
    reqs = [(_prompt(rng, cfg, plen), SamplingParams(max_new_tokens=4))]
    _, on = _staggered_run(cfg, params, reqs, prefix_cache=True,
                           chunked_prefill=True, max_prefill_tokens=8)
    _, off = _staggered_run(cfg, params, reqs, prefix_cache=False,
                            chunked_prefill=False)
    assert on[0].tokens == off[0].tokens


def test_cow_on_block_aligned_duplicate(model):
    """An exact duplicate of a block-aligned prompt matches every full
    block; the prompt-1 cap lands mid-block, forcing one COW copy."""
    cfg, params = model
    rng = np.random.default_rng(14)
    prompt = _prompt(rng, cfg, 8)              # 2 full blocks at bs=4
    reqs = [(prompt, SamplingParams(max_new_tokens=4, seed=0)),
            (prompt, SamplingParams(max_new_tokens=4, seed=1))]
    engine, outs = _staggered_run(cfg, params, reqs, prefix_cache=True,
                                  chunked_prefill=True)
    assert outs[0].tokens == outs[1].tokens    # greedy + same prompt
    assert outs[1].num_cached_tokens == len(prompt) - 1
    assert engine.pool.cow_copies >= 1
    _, off = _staggered_run(cfg, params, reqs, prefix_cache=False,
                            chunked_prefill=False)
    for i in range(2):
        assert outs[i].tokens == off[i].tokens


def test_preemption_under_pressure_identical_outputs(model):
    """Heavy churn (preemptions, chunked prefill, prefix cache all active)
    must not change any request's output vs an unconstrained pool."""
    cfg, params = model
    rng = np.random.default_rng(15)
    reqs = [(_prompt(rng, cfg, int(rng.integers(16, 40))),
             SamplingParams(max_new_tokens=8, seed=i,
                            temperature=0.6 if i % 2 else 0.0))
            for i in range(6)]
    big, big_outs = _staggered_run(cfg, params, reqs, prefix_cache=True,
                                   chunked_prefill=True, n_blocks=200)
    small, small_outs = _staggered_run(cfg, params, reqs, prefix_cache=True,
                                       chunked_prefill=True, n_blocks=20)
    assert big.num_preemptions == 0
    assert small.num_preemptions > 0
    for i in range(len(reqs)):
        assert big_outs[i].tokens == small_outs[i].tokens, f"req {i}"
    assert small.pool.num_used == 0


def test_preempt_between_chunks_resume_suffix_only(model):
    """A long prompt preempted mid-(chunked-)prefill re-admits against its
    own registered blocks: the resume prefills only the un-cached suffix."""
    cfg, params = model
    rng = np.random.default_rng(18)
    short = _prompt(rng, cfg, 4)
    long = _prompt(rng, cfg, 32)

    def run(prefix_cache):
        # pool sized so A's decode growth collides with B's chunked prefill:
        # B (youngest) is preempted mid-prefill and later resumed
        engine = LampEngine(cfg, params, EngineConfig(
            block_size=4, max_model_len=40, n_blocks=12,
            max_prefill_tokens=8, prefix_cache=prefix_cache,
            chunked_prefill=True))
        a = engine.add_request(short, SamplingParams(max_new_tokens=16,
                                                     seed=0))
        engine.step()                      # A prefills, starts decoding
        b = engine.add_request(long, SamplingParams(max_new_tokens=4,
                                                    seed=1))
        engine.run_to_completion()
        outs = {o.req_id: o for o in engine._finished}
        return engine, outs[a].tokens, outs[b].tokens

    on, a_on, b_on = run(True)
    off, a_off, b_off = run(False)
    assert on.num_preemptions > 0 and off.num_preemptions > 0
    # identical outputs with and without the cache ...
    assert a_on == a_off and b_on == b_off
    # ... but the resume re-used B's registered chunk blocks instead of
    # re-running the whole prompt. Own-KV resume hits are accounted as
    # resume_cached_tokens (not cached_tokens, which tracks cross-request
    # prefix sharing only -- no request here shares a prefix)
    assert on.stats()["resume_cached_tokens"] > 0
    assert on.stats()["cached_tokens"] == 0
    assert on.prefill_tokens_run < off.prefill_tokens_run
    assert on.pool.num_used == 0 and off.pool.num_used == 0


def test_decode_interleaves_mid_prefill(model):
    """While a long prompt prefills in chunks, an already-decoding request
    keeps producing tokens between the chunks."""
    cfg, params = model
    rng = np.random.default_rng(16)
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=4, max_model_len=64, max_prefill_tokens=4,
        prefix_cache=True, chunked_prefill=True))
    a = engine.add_request(_prompt(rng, cfg, 4),
                           SamplingParams(max_new_tokens=12, seed=0))
    engine.step()                              # A prefills, starts decoding
    b = engine.add_request(_prompt(rng, cfg, 24),
                           SamplingParams(max_new_tokens=4, seed=1))
    kinds = []
    while engine.has_unfinished():
        pre, dec = engine.prefill_steps, engine.decode_steps
        engine.step()
        p = engine.prefill_steps > pre
        d = engine.decode_steps > dec
        kinds.append("b" if p and d else "p" if p else "d")
    trace = "".join(kinds)
    # B needs 6 chunks of 4; A must decode between (split phases) or
    # within (fused mixed step, "b") those chunks
    assert trace.count("p") + trace.count("b") >= 6
    assert "b" in trace or ("pd" in trace and "dp" in trace), trace
    assert engine.prefill_chunks >= 5
    outs = {o.req_id: o for o in engine._finished}
    assert len(outs[a].tokens) == 12 and len(outs[b].tokens) == 4


def test_defrag_with_shared_blocks(model):
    """Refcount-aware defrag: shared blocks map to one new row, every
    sharer's table is rewritten, refcounts and the prefix index survive."""
    cfg, params = model
    rng = np.random.default_rng(17)
    shared = _prompt(rng, cfg, 12)
    reqs = [(shared + _prompt(rng, cfg, 3 + i),
             SamplingParams(max_new_tokens=6, seed=i)) for i in range(3)]

    def run(defrag_every):
        engine = LampEngine(cfg, params, EngineConfig(
            block_size=4, max_model_len=64, n_blocks=40,
            max_prefill_tokens=8, prefix_cache=True, chunked_prefill=True))
        outs = []
        for prompt, sampling in reqs:
            engine.add_request(prompt, sampling)
            outs.extend(engine.step())
        step = 0
        while engine.has_unfinished():
            outs.extend(engine.step())
            step += 1
            if defrag_every and step % defrag_every == 0:
                engine.defrag()
        assert engine.pool.num_used == 0
        return {o.req_id: o.tokens for o in outs}

    assert run(0) == run(1)
