"""Multi-device distributed correctness: run in a subprocess with 8 fake CPU
devices (device count must be fixed before jax initializes, so these can't
share the main test process)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ.pop("JAX_PLATFORMS", None)
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_sp_decode_attention_exact():
    """Sequence-parallel decode == single-device reference (GQA, masking)."""
    out = _run("""
        from repro.distributed.collectives import sp_decode_attention
        from repro.core.attention import decode_attention_lamp
        from repro.core.policy import LampSite
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        B, H, Hkv, S, D = 4, 8, 2, 64, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, 1, D))
        kc = jax.random.normal(ks[1], (B, Hkv, S, D)).astype(jnp.bfloat16)
        vc = jax.random.normal(ks[2], (B, Hkv, S, D)).astype(jnp.bfloat16)
        length = jnp.array([50, 64, 10, 33])
        with jax.set_mesh(mesh):
            out = jax.jit(lambda *a: sp_decode_attention(mesh, *a))(
                q, kc, vc, length)
        # reference: repeat kv heads, local exact attention
        kr = jnp.repeat(kc.astype(jnp.float32), H // Hkv, axis=1)
        vr = jnp.repeat(vc.astype(jnp.float32), H // Hkv, axis=1)
        # match sp numerics: q cast to bf16 for the QK product
        ref, _ = decode_attention_lamp(
            q.astype(jnp.bfloat16).astype(jnp.float32), kr, vr, length,
            LampSite(enabled=False))
        err = float(jnp.max(jnp.abs(out - ref)))
        print("ERR", err)
        assert err < 5e-2, err
    """)
    assert "ERR" in out


def test_sp_decode_lamp_selects():
    """Distributed rule (9) runs and stays close to the fp32 result."""
    out = _run("""
        from repro.distributed.collectives import sp_decode_attention
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        B, H, Hkv, S, D = 2, 4, 4, 32, 8
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (B, H, 1, D)) * 2
        kc = jax.random.normal(ks[1], (B, Hkv, S, D)) * 2
        vc = jax.random.normal(ks[2], (B, Hkv, S, D))
        length = jnp.array([32, 20])
        with jax.set_mesh(mesh):
            exact = jax.jit(lambda *a: sp_decode_attention(mesh, *a))(
                q, kc, vc, length)
            lamp = jax.jit(lambda *a: sp_decode_attention(
                mesh, *a, mu=5, tau=0.05, lamp=True))(q, kc, vc, length)
        err = float(jnp.max(jnp.abs(exact - lamp)))
        print("LAMP drift", err)
        assert err < 0.1, err
    """)
    assert "LAMP drift" in out


def test_quantized_psum_multidevice():
    out = _run("""
        from repro.distributed.collectives import quantized_psum
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                              jnp.float32)}
        out = quantized_psum(mesh, g, axis="data")
        # mean over 8 identical replicas == original (up to int8 error)
        err = float(jnp.max(jnp.abs(out["w"] - g["w"])))
        print("QERR", err)
        assert err < 2.0 / 127 * float(jnp.max(jnp.abs(g['w']))) + 1e-6, err
    """)
    assert "QERR" in out


def test_pipeline_two_stages():
    """GPipe 2-stage pipeline == sequential reference."""
    out = _run("""
        from repro.distributed.pipeline import pipeline_apply, split_stages
        mesh = jax.make_mesh((2,), ("stage",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        L, d, M, mb = 4, 8, 3, 2
        params = {"w": jnp.asarray(
            np.random.default_rng(0).normal(size=(L, d, d)) * 0.2, jnp.float32)}
        x = jnp.asarray(np.random.default_rng(1).normal(size=(M, mb, d)),
                        jnp.float32)

        def stage_fn(p, xin):
            def body(c, w):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, xin, p["w"])
            return y

        staged = split_stages(params, 2)
        outp = pipeline_apply(mesh, stage_fn, staged, x)
        want = jax.vmap(lambda b: stage_fn(params, b))(x)
        err = float(jnp.max(jnp.abs(outp - want)))
        print("PERR", err)
        assert err < 1e-5, err
    """)
    assert "PERR" in out


def test_dryrun_single_cell_subprocess():
    """End-to-end dry-run of one small cell on the production mesh."""
    out = _run("""
        from repro.launch.dryrun import run_cell
        rec = run_cell("olmoe-1b-7b", "decode_32k", False)
        assert rec["status"] == "ok", rec
        assert rec["n_devices"] == 256
        r = rec["roofline"]
        assert r["compute_s"] > 0 and r["memory_s"] > 0
        print("CELL OK", r["dominant"])
    """)
    assert "CELL OK" in out
