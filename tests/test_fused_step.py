"""Trace-replay differential harness for the fused serving step.

The fused step (`EngineConfig.fused_step`) collapses chunked-prefill
windows, plain decode rows and speculative verify rows into one mixed
StepPlan executed as a single bucketed jitted launch. The claim that makes
it shippable is equivalence: for the SAME plan stream, the fused launch
must produce exactly the tokens and per-request LAMP telemetry the legacy
phase-segregated sub-steps produce -- on both the gather reference path
and the Pallas kernel -- while making strictly fewer kernel launches and
compiling fewer jit signatures.

The harness enforces that claim three ways:

  * trace-replay differential: a live fused stream records its exact
    StepPlan sequence (tests/plan_replay.py); a twin engine configured
    with `mixed_exec="split"` replays under a checker that fails the
    moment its scheduler deviates, then tokens, telemetry, launch counts
    and compile counts are compared.
  * a hypothesis stateful machine (plus an always-on seeded fallback
    walk, matching the test_prefix_cache.py pattern): random arrivals,
    chunk sizes, draft-budget actuation and pool-pressure preemptions
    drive fused and split-exec twins in lockstep, with per-step
    invariants -- identical plan streams, token-identical outputs,
    bit-exact per-row LAMP counts, and no plan placing a row in a bucket
    whose window cannot hold it.
  * regression pins: the stats() key set, role-derived step views, the
    "mixed" phase span, and the bounded shared fn cache.
"""

import numpy as np
import pytest

import jax

from plan_replay import check_replay, record_plans
from repro.configs import get_config, reduced as reduce_cfg
from repro.models import api
from repro.serving import (EngineConfig, LampEngine, SamplingParams)
from repro.serving import engine as engine_mod
from repro.serving.fn_cache import STEP_FNS, FnCache


@pytest.fixture(scope="module")
def model():
    cfg = reduce_cfg(get_config("gpt2")).replace(vocab=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


_BASE = dict(block_size=4, max_model_len=64, max_prefill_batch=4,
             max_decode_batch=16, max_prefill_tokens=24,
             chunked_prefill=True)


def _mk(cfg, params, *, fused=True, exec_="fused", **kw):
    base = dict(_BASE)
    base.update(kw)
    return LampEngine(cfg, params, EngineConfig(
        fused_step=fused, mixed_exec=exec_, **base))


def _decode_heavy_stream(cfg, rng, n=10, greedy=False):
    """>= 8 concurrent requests, short prompts, long generations: most
    steps carry a decode/verify majority with prefill chunks riding
    along. Mixed temperatures/top-k unless `greedy`."""
    shared = rng.integers(0, cfg.vocab, size=9).tolist()
    reqs = []
    for i in range(n):
        prompt = (shared if i % 3 == 0 else []) \
            + rng.integers(0, cfg.vocab,
                           size=int(rng.integers(4, 16))).tolist()
        reqs.append((prompt, SamplingParams(
            max_new_tokens=int(rng.integers(8, 14)), seed=i,
            temperature=0.0 if greedy or i % 2 == 0 else 0.8,
            top_k=0 if greedy or i % 3 else 5)))
    return reqs


def _feed(engine, reqs):
    for i, (prompt, sp) in enumerate(reqs):
        engine.add_request(list(prompt), sp, arrival_time=float(i))


# ==================================================== trace-replay harness

@pytest.mark.parametrize("kernel", ["gather", "pallas"])
def test_trace_replay_differential(model, kernel):
    """The acceptance harness: a decode-heavy mixed stream (>= 8
    concurrent, chunked prefill + speculation on) is token-identical
    fused-vs-split on this kernel, with equal per-request LAMP telemetry,
    an identical replayed plan stream, strictly fewer launches, and (cold
    gather arm) strictly fewer jit compiles."""
    cfg, params = model
    reqs = _decode_heavy_stream(cfg, np.random.default_rng(3))
    cold = kernel == "gather"   # compile counting needs a cold cache; the
    if cold:                    # pallas arm reuses warm fns (counts ~0)
        engine_mod.reset_step_caches()

    fused = _mk(cfg, params, kernel=kernel, speculative=True, draft_len=3)
    trace = record_plans(fused)
    _feed(fused, reqs)
    f_outs = {o.req_id: o for o in fused.run_to_completion()}
    assert len(f_outs) == len(reqs)
    assert fused.mixed_steps == fused.total_steps > 0

    if cold:
        engine_mod.reset_step_caches()
    twin = _mk(cfg, params, kernel=kernel, exec_="split",
               speculative=True, draft_len=3)
    seen = check_replay(twin, trace)
    _feed(twin, reqs)
    t_outs = {o.req_id: o for o in twin.run_to_completion()}

    # the twin consumed the whole recorded plan stream, plan for plan
    assert seen == trace
    # token identity and per-request LAMP telemetry equality
    for rid, fo in f_outs.items():
        to = t_outs[rid]
        assert fo.tokens == to.tokens
        assert fo.lamp_selected == to.lamp_selected
        assert fo.lamp_valid == to.lamp_valid
        assert fo.lamp_layer_selected == to.lamp_layer_selected
        assert fo.lamp_layer_valid == to.lamp_layer_valid
        assert fo.spec_drafted == to.spec_drafted
        assert fo.spec_accepted == to.spec_accepted
    # strictly fewer kernel launches for the same number of steps
    assert fused.total_steps == twin.total_steps
    assert fused.launches < twin.launches
    # and a smaller jit cache: fewer compiled signatures from cold
    if cold:
        assert 0 < fused.stats()["compiles"] < twin.stats()["compiles"]


@pytest.mark.parametrize("speculative", [False, True])
def test_fused_matches_classic_greedy(model, speculative):
    """Fused vs the pre-fusion engine (fused_step off): greedy token
    streams are schedule-invariant, so the two engines -- which compose
    *different* plans -- must still emit identical tokens."""
    cfg, params = model
    reqs = _decode_heavy_stream(cfg, np.random.default_rng(5), n=8,
                                greedy=True)
    classic = _mk(cfg, params, fused=False, speculative=speculative,
                  draft_len=3)
    _feed(classic, reqs)
    c_outs = {o.req_id: o for o in classic.run_to_completion()}
    fused = _mk(cfg, params, speculative=speculative, draft_len=3)
    _feed(fused, reqs)
    f_outs = {o.req_id: o for o in fused.run_to_completion()}
    assert {r: o.tokens for r, o in f_outs.items()} \
        == {r: o.tokens for r, o in c_outs.items()}
    assert classic.mixed_steps == 0 and fused.mixed_steps > 0


# ============================================= stats / obs under mixed steps

def test_stats_keys_pinned_and_role_derived_views(model):
    """Regression pin: the exact stats() key surface (old keys intact,
    fused additions present), prefill/decode step views derived from row
    roles, and the mixed phase span."""
    cfg, params = model
    fused = _mk(cfg, params, speculative=True, draft_len=2)
    _feed(fused, _decode_heavy_stream(cfg, np.random.default_rng(7), n=8))
    fused.run_to_completion()
    s = fused.stats()
    expected = {
        "num_finished", "elapsed_s", "tokens_per_s", "requests_per_s",
        "latency_p50_s", "latency_p99_s", "ttft_p50_s", "steps",
        "prefill_steps", "decode_steps", "mixed_steps", "launches",
        "prefill_chunks", "preemptions", "blocks_allocated", "blocks_saved",
        "cached_tokens", "resume_cached_tokens", "prefill_tokens_run",
        "cache_hit_rate", "cow_copies", "cache_evictions", "kv_util_mean",
        "kv_util_peak", "lamp_recompute_rate", "lamp_layer_rates",
        "compiles", "compile_time_s", "phase", "live_requests",
        "spec_rounds", "spec_drafted_tokens", "spec_accepted_tokens",
        "spec_acceptance_rate", "spec_tokens_per_round",
        "verify_recompute_rate", "policy", "audit",
        "recoveries", "failed_requests", "faults",
    }
    assert set(s) == expected
    # every step was mixed, yet the legacy views stay populated by role
    assert s["steps"] == s["mixed_steps"] > 0
    assert int(fused._c_prefill_steps.value) == 0
    assert int(fused._c_decode_steps.value) == 0
    assert s["prefill_steps"] > 0 and s["decode_steps"] > 0
    assert s["spec_rounds"] > 0
    assert s["verify_recompute_rate"] > 0
    # phase histograms gain the mixed span (one per mixed step); the
    # legacy prefill/decode spans never fire on the fused path
    assert fused.obs.phase_hist("mixed").count == s["mixed_steps"]
    assert fused.obs.phase_hist("prefill").count == 0
    assert fused.obs.phase_hist("decode").count == 0
    # mixed compile events carry the (rows, max_window) bucket key
    for e in fused.compile_events:
        assert e["kind"] in ("mixed", "draft")
        if e["kind"] == "mixed":
            assert len(e["shape"]) == 2
    # launches: one mixed launch per no-draft step, +1 draft when drafting
    assert s["launches"] <= 2 * s["mixed_steps"]


def test_classic_engine_stats_unchanged(model):
    """Backward compatibility: a default (non-fused) engine reports zero
    mixed steps, launches == steps (+1 per spec round for the separate
    verify), and the same derived views as before."""
    cfg, params = model
    eng = _mk(cfg, params, fused=False)
    _feed(eng, _decode_heavy_stream(cfg, np.random.default_rng(9), n=4))
    eng.run_to_completion()
    s = eng.stats()
    assert s["mixed_steps"] == 0
    assert s["launches"] == s["steps"]
    assert s["prefill_steps"] + s["decode_steps"] == s["steps"]
    assert eng.obs.phase_hist("mixed").count == 0


# ================================================= the shared bounded cache

def test_fn_cache_bounds_and_lru():
    c = FnCache(maxsize=2)
    assert c.get_or_build("a", lambda: 1) == 1
    assert c.get_or_build("a", lambda: 2) == 1     # cached, not rebuilt
    assert c.get_or_build("b", lambda: 2) == 2
    assert c.get_or_build("a", lambda: 3) == 1     # refresh a's recency
    assert c.get_or_build("c", lambda: 3) == 3     # evicts b (LRU)
    assert c.keys() == ["a", "c"] and c.evictions == 1
    assert "b" not in c and len(c) == 2
    assert c.get_or_build("b", lambda: 4) == 4     # rebuilt after eviction
    with pytest.raises(ValueError):
        FnCache(maxsize=0)


def test_step_fns_share_one_cache(model):
    """The three step-function families (prefill/decode, spec draft/verify,
    fused mixed) all key into the one bounded store -- and a mixed stream
    adds at most one entry beyond what the split paths already built."""
    cfg, params = model
    STEP_FNS.clear()
    split = _mk(cfg, params, fused=False, speculative=True, draft_len=3)
    _feed(split, _decode_heavy_stream(cfg, np.random.default_rng(11), n=6,
                                      greedy=True))
    split.run_to_completion()
    split_keys = set(STEP_FNS.keys())
    assert split_keys and all(k[0] in ("step", "spec") for k in split_keys)
    fused = _mk(cfg, params, speculative=True, draft_len=3)
    _feed(fused, _decode_heavy_stream(cfg, np.random.default_rng(11), n=6,
                                      greedy=True))
    fused.run_to_completion()
    new = set(STEP_FNS.keys()) - split_keys
    assert all(k[0] == "mixed" for k in new) and len(new) <= 1


# ============================== randomized stream harness (machine + walk)

class StreamHarness:
    """Drive a fused engine and its split-exec twin in lockstep under a
    randomized request stream, asserting per-step that the plan streams
    are identical, outputs and per-row LAMP counts are bit-exact, and
    every mixed plan fits its bucket."""

    def __init__(self, cfg, params, speculative, kernel="gather"):
        base = dict(block_size=4, max_model_len=48, n_blocks=30,
                    max_prefill_batch=3, max_decode_batch=6,
                    max_prefill_tokens=12, kernel=kernel,
                    chunked_prefill=True, speculative=speculative,
                    draft_len=3)
        self.cfg = cfg
        self.speculative = speculative
        self.fused = LampEngine(cfg, params,
                                EngineConfig(fused_step=True, **base))
        self.twin = LampEngine(cfg, params, EngineConfig(
            fused_step=True, mixed_exec="split", **base))
        self.ftrace = record_plans(self.fused)
        self.ttrace = record_plans(self.twin)
        self.t = 0.0
        self.next_req = 0
        self.fin_f = {}
        self.fin_t = {}

    def arrive(self, plen, mnew, temp, topk, tok_seed):
        prompt = np.random.default_rng(tok_seed).integers(
            0, self.cfg.vocab, size=plen).tolist()
        sp = SamplingParams(max_new_tokens=mnew, seed=self.next_req,
                            temperature=temp, top_k=topk)
        for eng in (self.fused, self.twin):
            eng.add_request(list(prompt), sp, arrival_time=self.t)
        self.next_req += 1
        self.t += 1.0

    def set_draft(self, kd):
        # the policy controller's actuation path: a host int, no recompile
        if self.speculative:
            self.fused.scheduler.spec_draft_len = kd
            self.twin.scheduler.spec_draft_len = kd

    def step(self):
        for o in self.fused.step():
            self.fin_f[o.req_id] = o
        for o in self.twin.step():
            self.fin_t[o.req_id] = o
        self.t += 1.0
        self.check()

    def check(self):
        assert self.ftrace == self.ttrace
        for rec in self.ftrace:
            if rec is None or rec.kind != "mixed":
                continue
            # bucket invariant: the (rows, max_window) bucket the plan
            # compiles under must hold every row it mixes in
            Wb = engine_mod._bucket(max(rec.windows), 0)
            n_pre = 0
            for w, role, kd in zip(rec.windows, rec.roles, rec.draft_lens):
                assert 1 <= w <= Wb
                if role == "prefill":
                    assert kd == 0
                    n_pre += w
                else:
                    assert w == 1 + kd
                    assert (role == "verify") == (kd > 0)
            assert n_pre <= 12                     # prefill token budget
            assert len(rec.req_ids) <= 3 + 6       # batch caps
        for rid, fo in self.fin_f.items():
            if rid in self.fin_t:
                to = self.fin_t[rid]
                assert fo.tokens == to.tokens
                assert fo.lamp_layer_selected == to.lamp_layer_selected
                assert fo.lamp_layer_valid == to.lamp_layer_valid
        # live sequences: tokens and per-row LAMP counts bit-exact mid-run
        for rid, sf in self.fused._seqs.items():
            st_ = self.twin._seqs.get(rid)
            if st_ is None:
                continue
            assert sf.generated == st_.generated
            if sf.lamp.by_layer_selected is not None \
                    and st_.lamp.by_layer_selected is not None:
                assert np.array_equal(sf.lamp.by_layer_selected,
                                      st_.lamp.by_layer_selected)
                assert np.array_equal(sf.lamp.by_layer_valid,
                                      st_.lamp.by_layer_valid)

    def drain(self, max_steps=300):
        n = 0
        while (self.fused.has_unfinished()
               or self.twin.has_unfinished()) and n < max_steps:
            self.step()
            n += 1
        assert not self.fused.has_unfinished()
        assert not self.twin.has_unfinished()
        assert set(self.fin_f) == set(self.fin_t)
        if self.fused.mixed_steps:
            assert self.fused.launches <= self.twin.launches


@pytest.mark.parametrize("speculative", [False, True])
def test_fused_stream_seeded_walk(model, speculative):
    """Always-on seeded fallback for the stateful machine below: a fixed
    random walk of arrivals / draft-budget moves / steps, with the same
    per-step invariants (runs without hypothesis installed)."""
    cfg, params = model
    h = StreamHarness(cfg, params, speculative)
    rng = np.random.default_rng(17 if speculative else 23)
    for i in range(4):
        h.arrive(int(rng.integers(1, 20)), int(rng.integers(2, 8)),
                 0.8 if i % 2 else 0.0, 0, i)
    for _ in range(28):
        r = rng.random()
        if r < 0.2 and h.next_req < 10:
            h.arrive(int(rng.integers(1, 20)), int(rng.integers(2, 8)),
                     float(rng.choice([0.0, 0.8])),
                     int(rng.choice([0, 5])), int(rng.integers(1 << 16)))
        elif r < 0.3:
            h.set_draft(int(rng.integers(0, 4)))
        else:
            h.step()
    h.drain()


# The hypothesis stateful machine: the deep property harness. Import-guarded
# (not importorskip) so the seeded walk above still runs without hypothesis;
# engine steps are expensive, so example counts are pinned explicitly rather
# than inherited from the profile.
try:
    import hypothesis
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     rule)
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    class FusedStreamMachine(RuleBasedStateMachine):
        cfg = None      # injected by the test
        params = None
        speculative = True

        @initialize()
        def setup(self):
            cls = type(self)
            self.h = StreamHarness(cls.cfg, cls.params, cls.speculative)

        @rule(plen=st.integers(1, 24), mnew=st.integers(1, 8),
              temp=st.sampled_from([0.0, 0.8]),
              topk=st.sampled_from([0, 5]),
              tok_seed=st.integers(0, 1 << 16))
        def arrive(self, plen, mnew, temp, topk, tok_seed):
            if self.h.next_req < 12:
                self.h.arrive(plen, mnew, temp, topk, tok_seed)

        @rule(kd=st.integers(0, 3))
        def set_draft(self, kd):
            self.h.set_draft(kd)

        @rule()
        def step(self):
            self.h.step()


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@pytest.mark.parametrize("speculative", [False, True])
def test_fused_stream_state_machine(model, speculative):
    FusedStreamMachine.cfg, FusedStreamMachine.params = model
    FusedStreamMachine.speculative = speculative
    hypothesis.stateful.run_state_machine_as_test(
        FusedStreamMachine,
        settings=hypothesis.settings(max_examples=4, deadline=None,
                                     stateful_step_count=10))


@pytest.mark.slow
@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@pytest.mark.parametrize("speculative", [False, True])
def test_fused_stream_state_machine_deep(model, speculative):
    """Opt-in deep fuzz (pytest -m slow): more and longer examples."""
    FusedStreamMachine.cfg, FusedStreamMachine.params = model
    FusedStreamMachine.speculative = speculative
    hypothesis.stateful.run_state_machine_as_test(
        FusedStreamMachine,
        settings=hypothesis.settings(max_examples=30, deadline=None,
                                     stateful_step_count=40))
