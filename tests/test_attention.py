"""LAMP attention invariants: consistency across implementations, the
paper's qualitative claims at unit-test scale, and serving-path agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    attention_lamp, attention_reference, chunked_attention,
    chunked_attention_lamp, decode_attention_lamp, dot_ps,
    lamp_matmul_softmax, masked_softmax)
from repro.core.policy import LampSite


def _qkv(T=64, D=32, B=2, H=2, scale=1.5, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, T, D)) * scale
    k = jax.random.normal(ks[1], (B, H, T, D)) * scale
    v = jax.random.normal(ks[2], (B, H, T, D))
    return q, k, v


def test_chunked_equals_reference():
    q, k, v = _qkv()
    for causal in (True, False):
        ref = attention_reference(q, k, v, causal=causal)
        out = chunked_attention(q, k, v, causal=causal, block=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_window_attention():
    q, k, v = _qkv(T=48)
    ref = attention_reference(q, k, v, causal=True, window=8)
    out = chunked_attention(q, k, v, causal=True, window=8, block=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_lamp_reduces_error_vs_uniform_low_precision():
    """The paper's core claim at unit scale: LAMP-selected recompute beats
    uniform low precision by a large factor at the same mu."""
    q, k, v = _qkv(T=128, scale=2.0)
    ref = attention_reference(q, k, v)
    site_off = LampSite(enabled=True, mu=4, tau=1e9, rule="strict", granularity=1)
    site_on = LampSite(enabled=True, mu=4, tau=0.03, rule="strict", granularity=1)
    out_low, aux_low = attention_lamp(q, k, v, site_off)
    out_lamp, aux_lamp = attention_lamp(q, k, v, site_on)
    err_low = float(jnp.mean(jnp.abs(out_low - ref)))
    err_lamp = float(jnp.mean(jnp.abs(out_lamp - ref)))
    assert float(aux_lamp.recompute_rate) < 0.5
    assert err_lamp < err_low / 3


def test_random_recompute_is_useless():
    """Paper App C.4: the same NUMBER of random recomputes gives ~no gain."""
    q, k, v = _qkv(T=128, scale=2.0, seed=3)
    ref = attention_reference(q, k, v)
    site = LampSite(enabled=True, mu=4, tau=0.03, rule="strict", granularity=1)
    out_lamp, aux = attention_lamp(q, k, v, site)
    out_rand, aux_r = attention_lamp(q, k, v, site,
                                     random_key=jax.random.PRNGKey(9))
    assert abs(float(aux.n_selected) - float(aux_r.n_selected)) <= 1
    err_lamp = float(jnp.mean(jnp.abs(out_lamp - ref)))
    err_rand = float(jnp.mean(jnp.abs(out_rand - ref)))
    assert err_lamp < err_rand / 2


def test_online_lamp_matches_materialized_relaxed():
    """Two-pass online relaxed LAMP == materialized relaxed LAMP."""
    q, k, v = _qkv(T=64, seed=5)
    site = LampSite(enabled=True, mu=5, tau=0.05, rule="relaxed", granularity=0)
    out_m, aux_m = attention_lamp(q, k, v, site)
    out_o, aux_o = chunked_attention_lamp(q, k, v, site, block=16)
    np.testing.assert_allclose(np.asarray(out_o), np.asarray(out_m),
                               rtol=1e-4, atol=1e-5)


def test_onepass_is_conservative():
    """One-pass running threshold selects a superset (recompute rate >=)."""
    q, k, v = _qkv(T=64, seed=6)
    site = LampSite(enabled=True, mu=5, tau=0.1, rule="relaxed", granularity=0)
    _, aux2 = chunked_attention_lamp(q, k, v, site, block=8)
    _, aux1 = chunked_attention_lamp(q, k, v, site, block=8, onepass=True)
    assert float(aux1.recompute_rate) >= float(aux2.recompute_rate) - 1e-9


def test_decode_matches_full_row():
    q, k, v = _qkv(T=32, seed=7)
    site = LampSite(enabled=False)
    full = attention_reference(q, k, v, causal=True)
    out, _ = decode_attention_lamp(q[:, :, -1:], k, v,
                                   jnp.full((2,), 32), site)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, :, -1:]),
                               rtol=1e-5, atol=1e-5)


def test_strict_rule_threshold_semantics():
    """Rule (8): exactly the entries with 2 z (1-z) |y| > tau recompute."""
    a = jax.random.normal(jax.random.PRNGKey(0), (1, 32, 16)) * 1.5
    b = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32)) * 1.5
    z, y, mask = lamp_matmul_softmax(a, b, 5, 0.05, rule="strict")
    y_low = dot_ps(a, b, 5, granularity=1)
    zl = masked_softmax(y_low)
    crit = 2 * zl * (1 - zl) * jnp.abs(y_low)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(crit > 0.05))


def test_recompute_rate_decreases_with_tau():
    q, k, v = _qkv(T=96, seed=8)
    rates = []
    for tau in (0.01, 0.05, 0.2, 0.8):
        site = LampSite(enabled=True, mu=5, tau=tau, rule="strict", granularity=1)
        _, aux = attention_lamp(q, k, v, site)
        rates.append(float(aux.recompute_rate))
    assert all(a >= b - 1e-9 for a, b in zip(rates, rates[1:]))


def test_dot_ps_error_scales_with_granularity():
    """c_g ~ ceil(K/g) u: per-FMA rounding error >> subtile >> cast-only."""
    a = jax.random.normal(jax.random.PRNGKey(0), (64, 256))
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 64))
    exact = a @ b
    def err(g):
        return float(jnp.mean(jnp.abs(dot_ps(a, b, 7, granularity=g) - exact)))
    e1, e32, e0 = err(1), err(32), err(0)
    assert e1 > 2 * e32 > 2 * e0 * 0.99
