"""Tests for the serving observability layer (repro.obs + engine wiring).

Covers: metrics-registry semantics (counter monotonicity, histogram bucket
boundaries and streaming quantiles, label children, typed re-registration),
tracer ring-buffer overflow, Chrome-trace JSON schema validity, fake-clock
determinism, and the engine integration -- per-layer LAMP counts summing to
the aggregates, compile-event logging, trace-on vs trace-off token identity,
stats() key compatibility, and the hang-diagnostic dump.
"""

import json

import numpy as np
import pytest

import jax

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import api
from repro.obs import ObsConfig, Observability
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.tracing import NULL_TRACER, StepTracer
from repro.serving import EngineConfig, LampEngine, SamplingParams


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ------------------------------------------------------------------ registry

def test_counter_monotone():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    assert c.value == 3.5


def test_gauge_last_write_wins():
    g = Gauge()
    g.set(5)
    g.inc(-2)
    assert g.value == 3.0


def test_histogram_bucket_boundaries():
    h = Histogram(edges=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 2.0, 4.0, 9.0):
        h.observe(v)
    # cumulative-le semantics: bucket i counts v <= edges[i]; an observation
    # exactly on an edge lands in that edge's bucket, not the next one
    assert h.counts == [2, 2, 1, 1]       # (<=1, <=2, <=4, +Inf]
    assert h.count == 6
    assert h.sum == pytest.approx(18.0)
    assert h.vmin == 0.5 and h.vmax == 9.0


def test_histogram_rejects_bad_edges():
    for edges in ((), (2.0, 1.0), (1.0, 1.0)):
        with pytest.raises(ValueError):
            Histogram(edges=edges)


def test_histogram_quantile_bounded_and_ordered():
    h = Histogram(edges=(1e-3, 1e-2, 1e-1, 1.0))
    rng = np.random.default_rng(0)
    vals = rng.uniform(0.002, 0.5, size=500)
    for v in vals:
        h.observe(v)
    qs = [h.quantile(q) for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0)]
    assert all(a <= b + 1e-12 for a, b in zip(qs, qs[1:]))
    assert all(h.vmin <= q <= h.vmax for q in qs)
    # streaming estimate stays within the true value's bucket span
    true_p50 = np.percentile(vals, 50)
    assert abs(h.quantile(0.5) - true_p50) <= 0.1   # one decade bucket
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_histogram_single_bucket_does_not_smear():
    h = Histogram(edges=(1e-3, 1.0, 100.0))
    for v in (0.4, 0.5, 0.6):
        h.observe(v)
    # all mass in the (1e-3, 1] bucket: interpolation must stay inside the
    # observed [0.4, 0.6], not the raw bucket span
    assert 0.4 <= h.quantile(0.5) <= 0.6


def test_empty_histogram_quantile():
    assert Histogram(edges=(1.0,)).quantile(0.5) == 0.0
    assert Histogram(edges=(1.0,)).mean == 0.0


def test_registry_labels_and_memoization():
    reg = MetricsRegistry()
    fam = reg.counter("steps_total", labels=("kind",))
    a1, a2 = fam.labels("prefill"), fam.labels("prefill")
    assert a1 is a2
    fam.labels("decode").inc(3)
    a1.inc()
    snap = reg.snapshot()
    assert snap["steps_total"] == {"kind=prefill": 1.0, "kind=decode": 3.0}
    with pytest.raises(ValueError):
        fam.labels("a", "b")          # arity mismatch
    # same-name re-registration returns the same family; kind change raises
    assert reg.counter("steps_total", labels=("kind",)) is fam
    with pytest.raises(ValueError):
        reg.gauge("steps_total")


def test_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("reqs_total", help="requests").inc(2)
    h = reg.histogram("lat_seconds", edges=(0.1, 1.0), labels=("phase",))
    h.labels("decode").observe(0.05)
    h.labels("decode").observe(0.5)
    text = reg.to_prometheus()
    assert "# TYPE reqs_total counter" in text
    assert "reqs_total 2" in text
    assert 'lat_seconds_bucket{phase="decode",le="0.1"} 1' in text
    assert 'lat_seconds_bucket{phase="decode",le="+Inf"} 2' in text
    assert 'lat_seconds_count{phase="decode"} 2' in text


def _scrape_histogram(text, name):
    """Parse one histogram family back out of the exposition text:
    {labelset: {"buckets": [(le, cum), ...in emission order],
                "sum": float, "count": float}} where labelset is the
    sorted non-le label pairs (() for the unlabeled child)."""
    out = {}
    for line in text.splitlines():
        if line.startswith("#") or not line.startswith(name):
            continue
        metric, val = line.rsplit(" ", 1)
        series, _, rest = metric.partition("{")
        labels = dict(p.split("=", 1) for p in rest[:-1].split(",") if p)
        labels = {k: v.strip('"') for k, v in labels.items()}
        le = labels.pop("le", None)
        child = out.setdefault(tuple(sorted(labels.items())),
                               {"buckets": [], "sum": None, "count": None})
        if series == f"{name}_bucket":
            child["buckets"].append((le, float(val)))
        elif series == f"{name}_sum":
            child["sum"] = float(val)
        elif series == f"{name}_count":
            child["count"] = float(val)
    return out


def _check_histogram_child(child, edges):
    les = [le for le, _ in child["buckets"]]
    cums = [c for _, c in child["buckets"]]
    # one series per configured finite edge, then the explicit +Inf bucket
    assert les == [f"{e:g}" for e in edges] + ["+Inf"]
    # cumulative counts are monotone non-decreasing toward +Inf
    assert all(a <= b for a, b in zip(cums, cums[1:]))
    # +Inf carries every observation, and _count agrees with it
    assert cums[-1] == child["count"]
    return cums


def test_prometheus_histogram_roundtrip_unlabeled():
    """Scrape-parse the unlabeled `_bucket` emission branch: per-edge
    cumulative monotonicity, the explicit +Inf bucket, and _sum/_count
    consistency with the raw observations."""
    reg = MetricsRegistry()
    edges = (0.01, 0.1, 1.0, 10.0)
    h = reg.histogram("step_seconds", edges=edges, help="step wall")
    obs_vals = [0.005, 0.05, 0.05, 0.5, 5.0, 50.0]   # one past the top edge
    for v in obs_vals:
        h.observe(v)
    parsed = _scrape_histogram(reg.to_prometheus(), "step_seconds")
    assert set(parsed) == {()}
    child = parsed[()]
    cums = _check_histogram_child(child, edges)
    assert cums == [1, 3, 4, 5, 6]     # 50.0 lands only in +Inf
    assert child["count"] == len(obs_vals)
    assert child["sum"] == pytest.approx(sum(obs_vals))


def test_prometheus_histogram_roundtrip_labeled():
    """The labeled `_bucket` branch: every child keeps its own monotone
    cumulative series, `le` composes after the child's own labels, and
    _sum/_count are per-child."""
    reg = MetricsRegistry()
    edges = (0.1, 1.0)
    fam = reg.histogram("lat_seconds", edges=edges, labels=("phase",))
    fam.labels("decode").observe(0.05)
    fam.labels("decode").observe(0.5)
    fam.labels("decode").observe(5.0)
    fam.labels("prefill").observe(0.5)
    text = reg.to_prometheus()
    # the raw series names place le after the child's own label
    assert 'lat_seconds_bucket{phase="decode",le="+Inf"} 3' in text
    parsed = _scrape_histogram(text, "lat_seconds")
    assert set(parsed) == {(("phase", "decode"),), (("phase", "prefill"),)}
    dec = parsed[(("phase", "decode"),)]
    pre = parsed[(("phase", "prefill"),)]
    assert _check_histogram_child(dec, edges) == [1, 2, 3]
    assert _check_histogram_child(pre, edges) == [0, 1, 1]
    assert dec["sum"] == pytest.approx(5.55)
    assert pre["sum"] == pytest.approx(0.5)
    # both emission branches render the same structure for the same
    # observations: an unlabeled twin fed decode's samples parses equal
    reg2 = MetricsRegistry()
    twin = reg2.histogram("lat_seconds", edges=edges)
    for v in (0.05, 0.5, 5.0):
        twin.observe(v)
    t2 = _scrape_histogram(reg2.to_prometheus(), "lat_seconds")[()]
    assert t2["buckets"] == dec["buckets"]
    assert t2["count"] == dec["count"]
    assert t2["sum"] == pytest.approx(dec["sum"])


# -------------------------------------------------------------------- tracer

def test_tracer_fake_clock_spans():
    clk = FakeClock()
    tr = StepTracer(capacity=16, clock=clk)
    with tr.span("prefill", rows=2):
        clk.advance(0.25)
    clk.advance(0.1)
    tr.instant("compile:decode")
    (ph1, n1, _, t1, d1, a1), (ph2, n2, _, t2, d2, _) = tr.events()
    assert (ph1, n1, t1, d1, a1) == ("X", "prefill", 0.0, 0.25, {"rows": 2})
    assert (ph2, n2, t2, d2) == ("i", "compile:decode", 0.35, 0.0)


def test_tracer_ring_overflow():
    clk = FakeClock()
    tr = StepTracer(capacity=4, clock=clk)
    for i in range(10):
        clk.advance(1.0)
        tr.instant(f"e{i}")
    assert tr.dropped == 6
    names = [e[1] for e in tr.events()]
    assert names == ["e6", "e7", "e8", "e9"]    # last `capacity`, oldest first
    assert [e[1] for e in tr.last(2)] == ["e8", "e9"]


def test_chrome_trace_schema():
    clk = FakeClock(100.0)          # nonzero origin: ts must be rebased
    tr = StepTracer(capacity=16, clock=clk)
    for i in range(3):
        with tr.span("decode", bucket=[8]):
            clk.advance(0.002)
        clk.advance(0.001)
    tr.counter("lamp_recompute_rate", layer0=0.5, layer1=0.25)
    doc = tr.to_chrome_trace()
    blob = json.dumps(doc)                       # must be JSON-serializable
    doc = json.loads(blob)
    evs = doc["traceEvents"]
    assert len(evs) == 4
    last_ts = -1.0
    for ev in evs:
        assert {"name", "cat", "ph", "pid", "tid", "ts"} <= set(ev)
        assert ev["ph"] in ("X", "i", "C")
        assert ev["ts"] >= 0.0
        assert ev["ts"] >= last_ts               # recorded in time order
        last_ts = ev["ts"]
        if ev["ph"] == "X":
            assert ev["dur"] == pytest.approx(2000.0)   # 2ms in us
    assert evs[0]["ts"] == 0.0                   # rebased to first event
    assert evs[-1]["args"] == {"layer0": 0.5, "layer1": 0.25}
    assert doc["otherData"]["dropped_events"] == 0


def test_null_tracer_surface():
    with NULL_TRACER.span("x") as sp:
        pass
    assert sp.elapsed == 0.0
    NULL_TRACER.instant("y")
    assert NULL_TRACER.events() == []
    assert not NULL_TRACER.enabled
    with pytest.raises(RuntimeError):
        NULL_TRACER.write("/tmp/nope.json")


def test_tracer_write(tmp_path):
    clk = FakeClock()
    tr = StepTracer(capacity=8, clock=clk)
    with tr.span("s"):
        clk.advance(0.5)
    p = tr.write(str(tmp_path / "t.json"))
    assert json.load(open(p))["traceEvents"][0]["name"] == "s"


# ------------------------------------------------------------- Observability

def test_obs_span_always_feeds_histograms():
    clk = FakeClock()
    obs = Observability(ObsConfig(trace=False), clock=clk)
    with obs.span("decode"):
        clk.advance(0.01)
    h = obs.phase_hist("decode")
    assert h.count == 1 and h.sum == pytest.approx(0.01)
    assert obs.tracer is NULL_TRACER             # no events recorded


def test_obs_span_traces_when_enabled():
    clk = FakeClock()
    obs = Observability(ObsConfig(trace=True), clock=clk)
    with obs.span("prefill", rows=3):
        clk.advance(0.02)
    assert obs.phase_hist("prefill").count == 1
    (ph, name, cat, t0, dur, args), = obs.tracer.events()
    assert (ph, name, dur, args) == ("X", "prefill", 0.02, {"rows": 3})


def test_obs_compile_events():
    clk = FakeClock()
    obs = Observability(ObsConfig(trace=True, compile_log_capacity=2),
                        clock=clk)
    for i in range(3):
        obs.record_compile("decode", (8,), 0.5, step=i)
    assert len(obs.compile_events) == 2          # bounded log
    assert obs.compile_events[-1]["step"] == 2
    assert obs.registry.get("engine_compiles_total") \
        .labels("decode").value == 3
    names = [e[1] for e in obs.tracer.events()]
    assert names == ["compile:decode"] * 3


def test_obs_write_trace_requires_path():
    obs = Observability(ObsConfig(trace=True))
    with pytest.raises(ValueError):
        obs.write_trace()


# --------------------------------------------------------- engine integration

@pytest.fixture(scope="module")
def model():
    cfg = reduce_cfg(get_config("gpt2")).replace(vocab=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run(cfg, params, *, obs=ObsConfig(), clock=None, n=3, spec=False):
    eng = LampEngine(cfg, params, EngineConfig(
        block_size=4, n_blocks=64, max_model_len=64, obs=obs,
        speculative=spec, draft_len=2), clock=clock)
    rng = np.random.default_rng(7)
    for i in range(n):
        eng.add_request(rng.integers(0, cfg.vocab, size=5 + 3 * i).tolist(),
                        SamplingParams(max_new_tokens=6, seed=i))
    return eng, eng.run_to_completion()


def test_engine_per_layer_sums_to_totals(model):
    cfg, params = model
    eng, outs = _run(cfg, params)
    for o in outs:
        assert len(o.lamp_layer_selected) == cfg.n_layers
        assert sum(o.lamp_layer_selected) == pytest.approx(o.lamp_selected)
        assert sum(o.lamp_layer_valid) == pytest.approx(o.lamp_valid)
        assert all(0.0 <= r <= 1.0 for r in o.lamp_layer_rates)
    rates = eng.stats()["lamp_layer_rates"]
    assert len(rates) == cfg.n_layers and all(0.0 < r <= 1.0 for r in rates)
    assert eng.agg_lamp_selected == pytest.approx(
        sum(o.lamp_selected for o in outs))
    # the registry's per-layer counters agree with the numpy accumulators
    fam = eng.obs.registry.get("lamp_kq_products_total")
    for l in range(cfg.n_layers):
        assert fam.labels(str(l), "selected").value == pytest.approx(
            eng._layer_sel[l])
    assert len(eng.layer_rate_series) > 0


def test_engine_trace_on_token_identity_and_stats_compat(model):
    cfg, params = model
    eng_off, outs_off = _run(cfg, params, obs=ObsConfig(trace=False))
    eng_on, outs_on = _run(cfg, params, obs=ObsConfig(trace=True))
    assert {o.req_id: o.tokens for o in outs_on} \
        == {o.req_id: o.tokens for o in outs_off}
    # stats() keeps its public key surface regardless of tracing
    expected = {
        "num_finished", "elapsed_s", "tokens_per_s", "requests_per_s",
        "latency_p50_s", "latency_p99_s", "ttft_p50_s", "steps",
        "prefill_steps", "decode_steps", "prefill_chunks", "preemptions",
        "blocks_allocated", "blocks_saved", "cached_tokens",
        "prefill_tokens_run", "cache_hit_rate", "cow_copies",
        "cache_evictions", "kv_util_mean", "kv_util_peak",
        "lamp_recompute_rate", "lamp_layer_rates", "compiles",
        "compile_time_s", "phase", "live_requests", "spec_rounds",
        "spec_drafted_tokens", "spec_accepted_tokens",
        "spec_acceptance_rate", "spec_tokens_per_round",
        "verify_recompute_rate",
    }
    for eng in (eng_off, eng_on):
        s = eng.stats()
        assert expected <= set(s)
        assert s["live_requests"] == 0
    assert eng_off.obs.tracer.events() == []
    assert len(eng_on.obs.tracer.events()) > 0


def test_engine_compile_events_and_phase_histograms(model):
    cfg, params = model
    eng, _ = _run(cfg, params, obs=ObsConfig(trace=True))
    # the jit caches are process-global, so a warm cache may legitimately
    # record zero compiles here; every recorded event carries shape + wall
    # time and the stats() count matches the log
    for e in eng.compile_events:
        assert e["kind"] in ("prefill", "decode", "draft", "verify",
                             "mixed", "audit")
        assert isinstance(e["shape"], tuple) and e["wall_s"] >= 0.0
    assert eng.stats()["compiles"] == len(eng.compile_events)
    for must in ("schedule", "emit", "sync"):
        assert eng.obs.phase_hist(must).count > 0
    # fused default: every step is one mixed launch; the split engine still
    # feeds the per-phase histograms
    assert eng.obs.phase_hist("mixed").count == eng.mixed_steps \
        == eng.total_steps
    split = LampEngine(cfg, params, EngineConfig(
        block_size=4, n_blocks=64, max_model_len=64, fused_step=False,
        obs=ObsConfig(trace=True)))
    split.add_request(list(range(8)), SamplingParams(max_new_tokens=3))
    split.run_to_completion()
    assert split.obs.phase_hist("prefill").count == split.prefill_steps > 0
    assert split.obs.phase_hist("decode").count == split.decode_steps > 0


def test_engine_fake_clock_latencies(model):
    cfg, params = model
    clk = FakeClock(1000.0)
    eng = LampEngine(cfg, params, EngineConfig(
        block_size=4, n_blocks=64, max_model_len=64,
        obs=ObsConfig(trace=True)), clock=clk)
    eng.add_request(list(range(8)), SamplingParams(max_new_tokens=3))
    outs = []
    while eng.has_unfinished():
        outs.extend(eng.step())
        clk.advance(1.0)                # one fake second per step
    (o,) = outs
    # prefill step ends at t=1000 (clock advances after), first token there
    assert o.ttft == pytest.approx(0.0)
    assert o.latency == pytest.approx(2.0)     # 3 tokens = 3 steps, emit @ +2
    # every trace timestamp comes from the same fake clock
    assert all(1000.0 <= e[3] <= clk.t for e in eng.obs.tracer.events())


def test_engine_metrics_snapshot_and_prometheus(model):
    cfg, params = model
    eng, outs = _run(cfg, params)
    snap = eng.metrics_snapshot()
    json.dumps(snap)                             # JSON-serializable
    assert snap["engine_requests_finished_total"] == len(outs)
    assert snap["engine_generated_tokens_total"] == eng.generated_tokens
    assert snap["engine_live_requests"] == 0
    assert snap["engine_request_latency_seconds"]["count"] == len(outs)
    text = eng.obs.registry.to_prometheus()
    assert "engine_steps_total" in text and "lamp_kq_products_total" in text
    # streaming percentiles stay within the exact ones' histogram bounds
    s_stream, s_exact = eng.stats(exact=False), eng.stats(exact=True)
    h = eng._h_latency
    for s in (s_stream, s_exact):
        assert h.vmin - 1e-9 <= s["latency_p50_s"] <= h.vmax + 1e-9


def test_engine_spec_per_layer(model):
    cfg, params = model
    eng, outs = _run(cfg, params, spec=True, n=2)
    assert eng.spec_rounds > 0
    for o in outs:
        assert sum(o.lamp_layer_selected) == pytest.approx(o.lamp_selected)
    assert eng.spec_verify_valid > 0


def test_run_to_completion_hang_diagnostic(model):
    cfg, params = model
    eng = LampEngine(cfg, params, EngineConfig(
        block_size=4, n_blocks=64, max_model_len=64,
        obs=ObsConfig(trace=True)))
    eng.add_request(list(range(6)), SamplingParams(max_new_tokens=20))
    with pytest.raises(RuntimeError, match=r"1 request\(s\) still live") \
            as exc:
        eng.run_to_completion(max_steps=2)
    msg = str(exc.value)
    assert "registry snapshot:" in msg
    assert "trace events:" in msg
    assert "req 0" in msg


def test_serve_stream_fake_clock(model):
    from repro.launch.serve import metrics_line, serve_stream
    cfg, params = model
    clk = FakeClock()
    eng = LampEngine(cfg, params, EngineConfig(
        block_size=4, n_blocks=64, max_model_len=64), clock=clk)
    stream = [(0.0, list(range(6)), SamplingParams(max_new_tokens=2)),
              (5.0, list(range(4)), SamplingParams(max_new_tokens=2))]
    lines = []
    outs = serve_stream(eng, stream, metrics_every=1.0,
                        sleep=clk.advance, log=lines.append,
                        per_request=False)
    assert len(outs) == 2
    # the idle gap to the second arrival was crossed by the fake sleep
    # advancing the same clock the arrivals are timed against
    assert outs[1].ttft >= 0.0 and clk.t >= 5.0
    assert any(line.startswith("[serve] t=") for line in lines)
    assert "live=" in metrics_line(eng, clk.t)
