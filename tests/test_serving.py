"""Tests for the continuous-batching serving subsystem.

Covers: paged-vs-dense cache equivalence (same logits/tokens), scheduler
invariants under a randomized request stream (no block leaks, no starvation,
preempted requests resume identically), pool defrag, and engine smoke with
LAMP on/off.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import api, transformer
from repro.serving import (EngineConfig, LampEngine, PagedKVPool,
                           SamplingParams, Sequence)


@pytest.fixture(scope="module")
def model():
    cfg = reduce_cfg(get_config("gpt2")).replace(vocab=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompt(rng, cfg, n):
    return rng.integers(0, cfg.vocab, size=n).tolist()


# ---------------------------------------------------------------- equivalence

@pytest.mark.parametrize("use_lamp", [False, True])
def test_paged_prefill_matches_dense(model, use_lamp):
    cfg, params = model
    rng = np.random.default_rng(1)
    lens = [5, 9]
    prompts = [_prompt(rng, cfg, n) for n in lens]
    bs = 4

    dense = []
    for p in prompts:
        cache = api.init_cache(cfg, 1, 32, jnp.float32)
        dl, _ = api.prefill(cfg, params, {"tokens": jnp.asarray([p])}, cache,
                            use_lamp=use_lamp, attn_impl="full")
        dense.append(np.asarray(dl)[0])

    arena = transformer.init_paged_cache(cfg, 16, bs, jnp.float32)
    S = 16
    tokens = np.zeros((2, S), np.int32)
    bt = np.zeros((2, 8), np.int32)
    nxt = 1
    for i, p in enumerate(prompts):
        tokens[i, :len(p)] = p
        nb = -(-len(p) // bs)
        bt[i, :nb] = range(nxt, nxt + nb)
        nxt += nb
    pl, arena, (nsel, nval) = transformer.paged_prefill(
        cfg, params, jnp.asarray(tokens), arena, jnp.asarray(bt),
        jnp.asarray(lens, jnp.int32), use_lamp=use_lamp)
    pl = np.asarray(pl)
    for i in range(2):
        np.testing.assert_allclose(pl[i], dense[i], atol=1e-5)
    nsel, nval = np.asarray(nsel), np.asarray(nval)
    if use_lamp:
        # per-request valid counts: causal products over the true prompt only
        for i, n in enumerate(lens):
            expect = cfg.n_layers * cfg.n_heads * n * (n + 1) / 2
            assert nval[i] == pytest.approx(expect)
        assert (nsel > 0).all() and (nsel <= nval).all()
    else:
        assert (nsel == 0).all()


@pytest.mark.parametrize("use_lamp", [False, True])
def test_paged_decode_matches_dense(model, use_lamp):
    cfg, params = model
    rng = np.random.default_rng(2)
    prompt = _prompt(rng, cfg, 9)
    bs = 4

    cache = api.init_cache(cfg, 1, 32, jnp.float32)
    dl, cache = api.prefill(cfg, params, {"tokens": jnp.asarray([prompt])},
                            cache, use_lamp=use_lamp, attn_impl="full")

    arena = transformer.init_paged_cache(cfg, 16, bs, jnp.float32)
    bt = np.zeros((1, 8), np.int32)
    bt[0, :3] = [1, 2, 3]
    tokens = np.zeros((1, 16), np.int32)
    tokens[0, :9] = prompt
    pl, arena, _ = transformer.paged_prefill(
        cfg, params, jnp.asarray(tokens), arena, jnp.asarray(bt),
        jnp.asarray([9], jnp.int32), use_lamp=use_lamp)

    tok = jnp.argmax(dl[:, -1], axis=-1)[:, None]
    length = 9
    for _ in range(5):
        dl, cache = api.decode_step(cfg, params, cache, tok,
                                    use_lamp=use_lamp)
        nb = -(-(length + 1) // bs)
        if nb > np.sum(bt[0] > 0):
            bt[0, nb - 1] = 3 + nb
        pl, arena, _ = transformer.paged_decode_step(
            cfg, params, arena, jnp.asarray(bt),
            jnp.asarray([length], jnp.int32), tok, use_lamp=use_lamp)
        np.testing.assert_allclose(np.asarray(pl), np.asarray(dl), atol=1e-5)
        t_dense = int(jnp.argmax(dl[:, -1], axis=-1)[0])
        t_paged = int(jnp.argmax(pl[:, -1], axis=-1)[0])
        assert t_dense == t_paged
        tok = jnp.asarray([[t_dense]])
        length += 1


def test_per_row_lamp_counts_match_scalar(model):
    cfg, params = model
    from repro.core import attention as A
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 3, 6, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 3, 6, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 3, 6, 8)), jnp.float32)
    site = cfg.lamp.kq
    o1, a1 = A.attention_lamp(q, k, v, site, causal=True)
    o2, a2 = A.attention_lamp(q, k, v, site, causal=True, reduce=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))
    assert a2.n_selected.shape == (2, 6)
    assert float(jnp.sum(a2.n_selected)) == pytest.approx(float(a1.n_selected))
    assert float(jnp.sum(a2.n_valid)) == pytest.approx(float(a1.n_valid))

    lengths = jnp.asarray([4, 6], jnp.int32)
    o1, a1 = A.decode_attention_lamp(q[:, :, :1], k, v, lengths, site)
    o2, a2 = A.decode_attention_lamp(q[:, :, :1], k, v, lengths, site,
                                     reduce=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))
    assert a2.n_selected.shape == (2,)
    assert float(jnp.sum(a2.n_selected)) == pytest.approx(float(a1.n_selected))
    assert float(jnp.sum(a2.n_valid)) == pytest.approx(float(a1.n_valid))


# ---------------------------------------------------------------- engine

def _run_engine(cfg, params, requests, **ekw):
    kw = dict(block_size=4, max_model_len=64, max_prefill_tokens=64,
              max_prefill_batch=4, max_decode_batch=8)
    kw.update(ekw)
    engine = LampEngine(cfg, params, EngineConfig(**kw))
    for prompt, sampling in requests:
        engine.add_request(prompt, sampling)
    outs = engine.run_to_completion()
    return engine, {o.req_id: o for o in outs}


@pytest.mark.parametrize("use_lamp", [False, True])
def test_engine_smoke(model, use_lamp):
    cfg, params = model
    rng = np.random.default_rng(4)
    reqs = [(_prompt(rng, cfg, int(rng.integers(3, 20))),
             SamplingParams(max_new_tokens=int(rng.integers(2, 8)), seed=i))
            for i in range(6)]
    engine, outs = _run_engine(cfg, params, reqs, use_lamp=use_lamp)
    assert len(outs) == 6
    for i, (prompt, sampling) in enumerate(reqs):
        assert len(outs[i].tokens) == sampling.max_new_tokens
        assert outs[i].finish_reason == "length"
        assert outs[i].latency >= 0 and outs[i].ttft >= 0
    s = engine.stats()
    assert s["num_finished"] == 6
    assert 0.0 <= s["kv_util_mean"] <= 1.0
    if use_lamp:
        assert s["lamp_recompute_rate"] > 0
        assert all(o.lamp_recompute_rate > 0 for o in outs.values())
    else:
        assert s["lamp_recompute_rate"] == 0


def test_engine_pallas_kernel_differential(model):
    """End-to-end fused-kernel differential: the same request stream served
    with kernel="pallas" (fused paged attention, interpret mode on CPU) and
    kernel="gather" (reference) produces identical tokens and identical
    per-request LAMP recompute telemetry -- through chunked prefill, prefix
    sharing, and continuous-batch decode."""
    cfg, params = model
    rng = np.random.default_rng(11)
    shared = _prompt(rng, cfg, 9)   # shared prefix: exercises starts > 0
    reqs = []
    for i in range(6):
        prompt = (shared if i % 2 else []) + _prompt(
            rng, cfg, int(rng.integers(3, 18)))
        reqs.append((prompt,
                     SamplingParams(max_new_tokens=int(rng.integers(2, 7)),
                                    seed=i)))
    runs = {}
    for kernel in ("gather", "pallas"):
        engine, outs = _run_engine(cfg, params, reqs, kernel=kernel,
                                   max_prefill_tokens=8)  # force chunking
        assert len(outs) == len(reqs)
        runs[kernel] = (outs, engine.stats())
    g_outs, g_stats = runs["gather"]
    p_outs, p_stats = runs["pallas"]
    for i in g_outs:
        assert p_outs[i].tokens == g_outs[i].tokens
        # strict-rule selection thresholds on the softmax normalizer, which
        # the fused kernel accumulates blockwise: allow one ulp-flip of
        # slack per request (real telemetry bugs diverge by far more)
        assert abs(p_outs[i].lamp_selected - g_outs[i].lamp_selected) <= 1
        assert p_outs[i].lamp_valid == g_outs[i].lamp_valid
    assert abs(p_stats["lamp_recompute_rate"]
               - g_stats["lamp_recompute_rate"]) < 1e-4
    assert p_stats["lamp_recompute_rate"] > 0


def test_engine_rejects_unknown_kernel(model):
    cfg, params = model
    with pytest.raises(ValueError, match="kernel"):
        LampEngine(cfg, params, EngineConfig(kernel="fused"))


def test_stop_token_finishes_early(model):
    cfg, params = model
    # greedy decode with stop_token = whatever greedy produces first
    rng = np.random.default_rng(5)
    prompt = _prompt(rng, cfg, 7)
    _, outs = _run_engine(cfg, params,
                          [(prompt, SamplingParams(max_new_tokens=8))])
    first = outs[0].tokens[0]
    _, outs2 = _run_engine(
        cfg, params,
        [(prompt, SamplingParams(max_new_tokens=8, stop_token=first))])
    assert outs2[0].finish_reason == "stop_token"
    assert outs2[0].tokens == [first]


def test_scheduler_invariants_random_stream(model):
    """Randomized stream through a deliberately tiny pool: every request
    finishes (no starvation), blocks are all returned (no leak), and
    preemption actually happened."""
    cfg, params = model
    rng = np.random.default_rng(6)
    reqs = [(_prompt(rng, cfg, int(rng.integers(2, 30))),
             SamplingParams(max_new_tokens=int(rng.integers(1, 12)), seed=i,
                            temperature=float(rng.choice([0.0, 0.8]))))
            for i in range(12)]
    # pool barely above one max sequence -> heavy preemption churn
    engine, outs = _run_engine(cfg, params, reqs, n_blocks=20)
    assert len(outs) == 12
    for i, (prompt, sampling) in enumerate(reqs):
        assert len(outs[i].tokens) == sampling.max_new_tokens
    assert engine.num_preemptions > 0
    assert engine.pool.num_used == 0, "leaked KV blocks"
    assert engine.pool.num_free == engine.pool.num_total
    assert not engine.scheduler.running and not engine.scheduler.waiting


def test_preempted_requests_resume_identically(model):
    """Recompute-style preemption must not change any request's output
    (greedy decode is deterministic; sampling keys depend only on
    (seed, position))."""
    cfg, params = model
    rng = np.random.default_rng(7)
    reqs = [(_prompt(rng, cfg, int(rng.integers(4, 24))),
             SamplingParams(max_new_tokens=10, seed=i,
                            temperature=0.7 if i % 2 else 0.0))
            for i in range(8)]
    big, big_outs = _run_engine(cfg, params, reqs, n_blocks=200)
    small, small_outs = _run_engine(cfg, params, reqs, n_blocks=20)
    assert big.num_preemptions == 0
    assert small.num_preemptions > 0
    for i in range(len(reqs)):
        assert big_outs[i].tokens == small_outs[i].tokens, f"req {i}"


def test_kv_pool_alloc_free_defrag(model):
    cfg, params = model
    pool = PagedKVPool(cfg, n_blocks=10, block_size=4)
    assert pool.num_total == 9
    a = pool.alloc(3)
    b = pool.alloc(2)
    c = pool.alloc(2)
    assert pool.num_free == 2 and pool.utilization == pytest.approx(7 / 9)
    assert not pool.can_alloc(3)
    pool.free_blocks(b)
    # tag each live block's arena row with its id to track the permutation
    ids = jnp.arange(pool.n_blocks, dtype=jnp.float32)
    pool.k = jnp.ones_like(pool.k) * ids[None, :, None, None, None]
    sa = Sequence(0, [1], SamplingParams(), 0.0)
    sa.block_ids = list(a)
    sc = Sequence(1, [1], SamplingParams(), 0.0)
    sc.block_ids = list(c)
    mapping = pool.defrag([sa, sc])
    assert sorted(sa.block_ids + sc.block_ids) == list(range(1, 6))
    for old, new in mapping.items():
        assert float(pool.k[0, new, 0, 0, 0]) == old
    assert pool.num_free == 4
    pool.free_blocks(sa.block_ids + sc.block_ids)
    assert pool.num_free == pool.num_total


def test_engine_defrag_mid_run(model):
    """defrag() during serving must not change subsequent outputs."""
    cfg, params = model
    rng = np.random.default_rng(8)
    reqs = [(_prompt(rng, cfg, int(rng.integers(4, 16))),
             SamplingParams(max_new_tokens=6, seed=i)) for i in range(4)]

    def run(defrag_every):
        engine = LampEngine(cfg, params, EngineConfig(
            block_size=4, max_model_len=64, n_blocks=40))
        for prompt, sampling in reqs:
            engine.add_request(prompt, sampling)
        outs = []
        step = 0
        while engine.has_unfinished():
            outs.extend(engine.step())
            step += 1
            if defrag_every and step % defrag_every == 0:
                engine.defrag()
        return {o.req_id: o.tokens for o in outs}

    assert run(0) == run(2)


def test_decode_closure_cache_reuse(model):
    cfg, params = model
    from repro.runtime import serve_loop
    f1 = serve_loop.decode_fn(cfg, True)
    f2 = serve_loop.decode_fn(cfg, True)
    f3 = serve_loop.decode_fn(cfg, False)
    assert f1 is f2
    assert f1 is not f3
