"""Property tests for the LAMP selection rules against the paper's exact
kappa formulas (Props 3.1-3.3, App B)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import lamp as L

vecs = hnp.arrays(np.float32, st.integers(4, 48),
                  elements=st.floats(-20, 20, width=32)).filter(
    lambda v: np.all(np.isfinite(v)))


# ---------------------------------------------------------------- softmax

@given(y=vecs, tau=st.floats(1e-3, 2.0))
@settings(max_examples=150, deadline=None)
def test_strict_rule_satisfies_kappa1(y, tau):
    """Rule (8) mask achieves kappa_1 <= tau (Prop 3.3) and is optimal:
    removing any selected index violates the bound."""
    yj = jnp.asarray(y)
    q = L.select_softmax_strict(yj, tau)
    qn = np.asarray(q)
    if qn.all():
        return
    k = float(L.kappa_1_softmax(yj, q))
    assert k <= tau + 1e-5
    # minimality: every selected index is necessary
    z = np.asarray(jax.nn.softmax(yj))
    crit = 2 * z * (1 - z) * np.abs(y)
    for i in np.where(qn)[0]:
        q2 = qn.copy()
        q2[i] = False
        assert float(L.kappa_1_softmax(yj, jnp.asarray(q2))) > tau - 1e-6
        assert crit[i] > tau  # the closed-form is exactly the threshold rule


@given(y=vecs)
@settings(max_examples=100, deadline=None)
def test_kappa1_matches_bruteforce(y):
    """Prop 3.3 closed form == brute-force ||K (I - diag q)||_1,1 / ||f||_1."""
    yj = jnp.asarray(y, jnp.float32)
    n = y.shape[0]
    rng = np.random.default_rng(int(abs(y).sum() * 100) % 2**31)
    q = rng.random(n) < 0.3
    if q.all():
        q[rng.integers(n)] = False
    # f64 closed form vs f64 brute force: tests the FORMULA (Prop 3.3)
    # exactly, independent of f32 softmax cancellation in (1 - z).
    yd = y.astype(np.float64)
    z = np.exp(yd - yd.max())
    z /= z.sum()
    K = (np.diag(z) - np.outer(z, z)) @ np.diag(yd)
    Kq = K @ np.diag(1.0 - q.astype(np.float64))
    # ||A||_{1,1} = max column abs sum; ||softmax||_1 = 1
    brute = np.abs(Kq).sum(axis=0).max()
    closed64 = (2 * z * (1 - z) * np.abs(yd))[~q].max()
    np.testing.assert_allclose(closed64, brute, rtol=1e-6, atol=1e-30)
    # and the f32 implementation agrees up to cancellation noise
    closed32 = float(L.kappa_1_softmax(yj, jnp.asarray(q)))
    np.testing.assert_allclose(closed32, closed64, rtol=5e-2, atol=1e-4)


@given(y=vecs)
@settings(max_examples=100, deadline=None)
def test_kappa_c_softmax_matches_bruteforce(y):
    """App B closed form == brute-force ||M (I - diag q)||_inf,inf."""
    yj = jnp.asarray(y, jnp.float32)
    n = y.shape[0]
    rng = np.random.default_rng(int(abs(y).sum() * 37) % 2**31)
    q = rng.random(n) < 0.3
    if q.all():
        q[rng.integers(n)] = False
    z = np.asarray(jax.nn.softmax(yj)).astype(np.float64)
    if (z == 0).any():
        return  # M needs 1/z; f32 softmax underflow makes the brute force UB
    J = np.diag(z) - np.outer(z, z)
    M = np.diag(1.0 / z) @ J @ np.diag(y.astype(np.float64))
    Mq = M @ np.diag(1.0 - q.astype(np.float64))
    brute = np.abs(Mq).sum(axis=1).max()
    closed = float(L.kappa_c_softmax(yj, jnp.asarray(q)))
    np.testing.assert_allclose(closed, brute, rtol=1e-3, atol=1e-5)


@given(y=vecs, tau=st.floats(0.01, 0.9))
@settings(max_examples=150, deadline=None)
def test_relaxed_superset_property(y, tau):
    """Rule (9) vs (8): relaxed criterion |y|e^y / max == strict criterion
    with the (1-z_j) factor dropped and normalizer cancelled. Check the
    documented containment: every index selected by strict-with-threshold
    tau*max_crit is selected by a relaxed rule of matching tau (both
    normalized to relative scales)."""
    yj = jnp.asarray(y)
    rel = np.asarray(L.select_softmax_relaxed(yj, tau))
    # relaxed in log-space equals direct evaluation
    s = np.abs(y.astype(np.float64)) * np.exp(y.astype(np.float64))
    direct = s > tau * s.max()
    np.testing.assert_array_equal(rel, direct)


def test_relaxed_tau_monotone():
    y = jnp.asarray(np.random.default_rng(0).normal(size=64) * 3, jnp.float32)
    prev = None
    for tau in [0.9, 0.5, 0.1, 0.01]:
        m = np.asarray(L.select_softmax_relaxed(y, tau))
        if prev is not None:
            assert (m | prev).sum() == m.sum()  # smaller tau => superset
        prev = m


def test_length_normalized_rule():
    """App C.5: shorter rows get a larger threshold -> fewer selections."""
    y = jnp.asarray(np.random.default_rng(1).normal(size=(4, 256)) * 2, jnp.float32)
    short = L.select_softmax_relaxed_ln(y, 0.05, jnp.full((4,), 64.0))
    long_ = L.select_softmax_relaxed_ln(y, 0.05, jnp.full((4,), 4096.0))
    assert int(short.sum()) <= int(long_.sum())


# ---------------------------------------------------------------- rmsnorm

@given(y=vecs, tau=st.floats(0.01, 1.95))
@settings(max_examples=150, deadline=None)
def test_rmsnorm_greedy_satisfies_constraint(y, tau):
    """Prop 3.2: the greedy prefix mask satisfies kappa_c <= tau whenever it
    does not select everything."""
    if np.allclose(y, 0):
        return
    yj = jnp.asarray(y)
    q = L.select_rmsnorm(yj, tau)
    if bool(q.all()):
        return
    k = float(L.kappa_c_rmsnorm(yj, q))
    assert k <= tau + 1e-4


@given(y=vecs, tau=st.floats(0.01, 1.95))
@settings(max_examples=100, deadline=None)
def test_rmsnorm_greedy_near_optimal(y, tau):
    """Prop 3.2: greedy size <= optimal size + 1 (brute force on small n)."""
    if y.shape[0] > 14 or np.allclose(y, 0):
        return
    yj = jnp.asarray(y)
    q = L.select_rmsnorm(yj, tau)
    s_greedy = int(q.sum())
    n = y.shape[0]
    import itertools
    best = n
    # optimal: smallest support size with kappa <= tau (search by size)
    found = False
    for size in range(0, n):
        for idx in itertools.combinations(range(n), size):
            qq = np.zeros(n, bool)
            qq[list(idx)] = True
            if float(L.kappa_c_rmsnorm(yj, jnp.asarray(qq))) <= tau + 1e-6:
                best = size
                found = True
                break
        if found:
            break
    if not found:
        best = n
    assert s_greedy <= best + 1


def test_rmsnorm_paper_examples():
    """Paper Sec 3.2 closed-form examples: spread-out vs single-outlier."""
    n = 65
    y = np.ones(n, np.float32)
    y[-1] = 0.0
    tau = 0.5
    q = L.select_rmsnorm(jnp.asarray(y), tau)
    s_expected = int(np.ceil((2 - tau) * (n - 1)))  # paper: s = ceil((2-tau)(n-1))
    assert int(q.sum()) == min(s_expected, n)
    # massive outlier: s = 1 requires tau >= 1 (the greedy condition
    # 1 + 2*0 >= (2 - tau) * 1 is infeasible below tau = 1)
    y2 = np.zeros(n, np.float32)
    y2[0] = 1.0
    q2 = L.select_rmsnorm(jnp.asarray(y2), 1.0)
    assert int(q2.sum()) == 1


# ------------------------------------------------------------- activations

def test_activation_rule_relu2_is_constant():
    """DESIGN.md Sec 6: relu^2 has condition number exactly 2 for y > 0."""
    y = jnp.asarray(np.linspace(0.1, 10, 64), jnp.float32)
    phi = lambda t: jnp.maximum(t, 0) ** 2
    dphi = lambda t: 2 * jnp.maximum(t, 0)
    m_lo = L.select_activation(y, 1.99, phi, dphi)
    m_hi = L.select_activation(y, 2.01, phi, dphi)
    assert bool(m_lo.all()) and not bool(m_hi.any())


def test_activation_rule_gelu():
    """GELU: condition number exceeds any tau for very negative inputs
    (phi -> 0 faster than phi' y), small for large positive inputs."""
    from repro.core.lamp import gelu_criterion
    crit_neg = float(gelu_criterion(jnp.float32(-8.0)))
    crit_pos = float(gelu_criterion(jnp.float32(8.0)))
    assert crit_neg > 10.0 and crit_pos < 1.1
