"""Chaos / fault-tolerance tests for the serving engine.

The contract under test (serving/faults.py + the engine's health guard,
recovery ladder, deadlines, admission bound, and stall watchdog):

  * fault injection is deterministic: same (salt, rates, stream) injects
    the same faults at the same steps, replayable bit-for-bit;
  * an injected fault NEVER crashes the engine: it is absorbed (recovery
    ladder, allocation deferral, split fallback, watchdog) or -- when
    recovery is impossible -- fails that one request with a diagnostic
    `RequestOutput.error`, leaving every other request untouched;
  * recovered requests are token-identical to the fault-free run (the
    retry replays the same (seed, num_generated)-keyed sampling stream);
  * the KV pool's invariants (serving/kv_pool.check_invariants) hold after
    every recovery path.

Draft-corruption scenarios run greedy (temperature=0): the verifier
provably rejects corrupted greedy drafts, while a sampled stream's accept
coin may legitimately keep a corrupt-but-plausible token (see
faults.py docstring) -- that boundary is deliberately not asserted here.

The hypothesis stateful machine at the bottom drives a fault-enabled
engine through random admit/step interleavings with the pool invariants
as a machine invariant; a seeded fallback walk covers the same ground
when hypothesis is not installed.
"""

import numpy as np
import pytest

import jax

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import api
from repro.serving import (ArenaAllocFault, EngineConfig, FaultConfig,
                           FaultInjector, LampEngine, PagedKVPool,
                           QueueFullError, SamplingParams, fault_hash)
from repro.serving.faults import FAULT_SITES


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ================================================================= injector

def test_fault_hash_deterministic_and_site_separated():
    for site in FAULT_SITES:
        assert fault_hash(3, site) == fault_hash(3, site)
        assert fault_hash(3, site, salt=1) != fault_hash(3, site, salt=2)
    # different sites at the same step draw independent coins
    draws = {site: fault_hash(11, site) for site in FAULT_SITES}
    assert len(set(draws.values())) == len(FAULT_SITES)


def test_fault_config_validation():
    with pytest.raises(ValueError):
        FaultConfig(nan_rate=1.5)
    with pytest.raises(ValueError):
        FaultConfig(alloc_rate=-0.1)
    with pytest.raises(ValueError):
        FaultConfig(stall_steps=0)
    assert not FaultConfig().any_rate
    assert FaultConfig(nan_rate=0.5).any_rate


def test_injector_fires_deterministically():
    a = FaultInjector(FaultConfig(enabled=True, nan_rate=0.3, salt=5))
    b = FaultInjector(FaultConfig(enabled=True, nan_rate=0.3, salt=5))
    seq_a = [a.fires(s, "nan") for s in range(200)]
    seq_b = [b.fires(s, "nan") for s in range(200)]
    assert seq_a == seq_b
    assert 0 < sum(seq_a) < 200          # rate 0.3 is neither never nor always
    zero = FaultInjector(FaultConfig(enabled=True, salt=5))
    assert not any(zero.fires(s, "nan") for s in range(200))


def test_injector_budget_and_latch():
    inj = FaultInjector(FaultConfig(enabled=True, nan_rate=1.0, max_faults=2))
    fired = 0
    for s in range(10):
        if inj.fires(s, "nan"):
            inj.record(s, "nan")
            fired += 1
            # one-per-(site, step) latch: recording consumes this step
            assert not inj.fires(s, "nan")
    assert fired == 2                    # budget caps total injections
    assert inj.stats()["injected"] == 2


def test_pick_row_deterministic():
    inj = FaultInjector(FaultConfig(enabled=True, nan_rate=1.0))
    reqs = [4, 9, 17]
    assert inj.pick_row(7, "nan", reqs) == inj.pick_row(7, "nan", reqs)
    assert inj.pick_row(7, "nan", []) is None
    picks = {inj.pick_row(s, "nan", reqs) for s in range(50)}
    assert picks == {0, 1, 2}            # the min-hash spreads over rows


# ================================================================= kv pool

def _pool(model, n_blocks=8, block_size=4):
    return PagedKVPool(model[0], n_blocks=n_blocks, block_size=block_size)


def test_arm_alloc_failure_raises_once(model):
    pool = _pool(model)
    pool.arm_alloc_failure()
    with pytest.raises(ArenaAllocFault):
        pool.alloc(1)
    blocks = pool.alloc(2)               # one-shot: the next alloc succeeds
    assert len(blocks) == 2
    pool.check_invariants()


def test_check_invariants_detects_corruption(model):
    pool = _pool(model)
    blocks = pool.alloc(3)
    pool.check_invariants()
    pool.refcount[blocks[0]] = 0         # corrupt: owned block with rc 0
    with pytest.raises(RuntimeError, match="invariant"):
        pool.check_invariants()
    pool.refcount[blocks[0]] = 1
    pool.check_invariants()
    pool._free.append(blocks[1])         # corrupt: block both owned and free
    pool._free_set.add(blocks[1])
    with pytest.raises(RuntimeError, match="invariant"):
        pool.check_invariants()


# ================================================================== engine

@pytest.fixture(scope="module")
def model():
    cfg = reduce_cfg(get_config("gpt2")).replace(vocab=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _requests(seed, n=6, temperature=0.0):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, 128, size=int(rng.integers(3, 20))).tolist(),
             SamplingParams(max_new_tokens=int(rng.integers(2, 8)), seed=i,
                            temperature=temperature))
            for i in range(n)]


def _run(cfg, params, reqs, clock=None, **ekw):
    kw = dict(block_size=4, max_model_len=64, max_prefill_tokens=64,
              max_prefill_batch=4, max_decode_batch=8, paranoid=True)
    kw.update(ekw)
    engine = LampEngine(cfg, params, EngineConfig(**kw), clock=clock)
    for prompt, sampling in reqs:
        engine.add_request(prompt, sampling)
    outs = engine.run_to_completion()
    engine.pool.check_invariants(engine._seqs.values())
    return engine, {o.req_id: o for o in outs}


def _assert_absorbed(base, chaos):
    """Every chaos request finished; non-failed ones token-identical."""
    assert set(chaos) == set(base)
    for rid, o in chaos.items():
        assert o.finish_reason is not None
        if o.error is None:
            assert o.tokens == base[rid].tokens, rid
        else:
            assert o.finish_reason in ("unhealthy", "timeout", "stalled")


@pytest.mark.parametrize("temperature,kernel", [
    (0.0, "gather"), (0.8, "gather"), (0.0, "pallas")])
def test_chaos_differential_plain(model, temperature, kernel):
    """NaN + alloc + stall faults on the plain engine, both kernels: zero
    crashes, every request recovered token-identically (rung-0 retry
    replays the keyed sampling stream, so this holds for sampled runs
    too)."""
    cfg, params = model
    reqs = _requests(4, temperature=temperature)
    _, base = _run(cfg, params, reqs, kernel=kernel)
    fc = FaultConfig(enabled=True, salt=7, nan_rate=0.25, alloc_rate=0.15,
                     stall_rate=0.05, stall_steps=2, stall_s=0.0)
    eng, chaos = _run(cfg, params, reqs, kernel=kernel, faults=fc,
                      stall_patience=8)
    _assert_absorbed(base, chaos)
    s = eng.stats()
    assert s["faults"]["injected"] > 0
    assert s["failed_requests"] == sum(
        1 for o in chaos.values() if o.error is not None)


def test_chaos_differential_spec_fused(model):
    """All five sites against the fused speculative step (greedy): draft
    corruption is rejected by the verifier, the injected fused-step fault
    degrades to the split twin, NaN rows recover through the ladder."""
    cfg, params = model
    reqs = _requests(11)
    _, base = _run(cfg, params, reqs, speculative=True, draft_len=3)
    fc = FaultConfig(enabled=True, salt=3, nan_rate=0.3, draft_rate=0.3,
                     step_rate=0.2, alloc_rate=0.1, stall_rate=0.05,
                     stall_steps=2, stall_s=0.0)
    eng, chaos = _run(cfg, params, reqs, speculative=True, draft_len=3,
                      faults=fc, stall_patience=8)
    _assert_absorbed(base, chaos)
    assert eng.stats()["faults"]["injected"] > 0


def test_chaos_replays_bit_for_bit(model):
    cfg, params = model
    reqs = _requests(4)
    fc = FaultConfig(enabled=True, salt=9, nan_rate=0.3, alloc_rate=0.2)
    e1, r1 = _run(cfg, params, reqs, faults=fc)
    e2, r2 = _run(cfg, params, reqs, faults=fc)
    assert {k: o.tokens for k, o in r1.items()} == \
        {k: o.tokens for k, o in r2.items()}
    assert e1.stats()["faults"] == e2.stats()["faults"]
    assert e1.stats()["recoveries"] == e2.stats()["recoveries"]


def test_guard_off_survives_nan(model):
    """With the health guard off, injected NaN propagates like a real
    kernel fault -- the engine must still complete every request (garbage
    tokens, no crash), which is exactly why the guard defaults on."""
    cfg, params = model
    reqs = _requests(4)
    fc = FaultConfig(enabled=True, salt=7, nan_rate=0.5, max_faults=2)
    eng, outs = _run(cfg, params, reqs, faults=fc, health_guard=False)
    assert len(outs) == len(reqs)
    assert all(o.error is None for o in outs.values())
    assert eng.stats()["faults"]["by_site"]["nan"] == 2


def test_ladder_exhaustion_fails_request_alone(model):
    """An impossible health bound exhausts every recovery rung: each
    request fails individually with a diagnostic error naming the rungs
    tried; the engine itself completes and the pool stays consistent."""
    cfg, params = model
    reqs = _requests(4, n=3)
    eng, outs = _run(cfg, params, reqs, health_max_abs=1e-9, max_retries=2)
    assert len(outs) == len(reqs)
    for o in outs.values():
        assert o.finish_reason == "unhealthy"
        assert "recovery rung" in o.error
    s = eng.stats()
    assert s["failed_requests"] == len(reqs)
    assert not eng.has_unfinished()


def test_deadline_expires_request(model):
    cfg, params = model
    clk = FakeClock(1000.0)
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=4, max_model_len=64, paranoid=True), clock=clk)
    engine.add_request(list(range(8)),
                       SamplingParams(max_new_tokens=32, deadline_s=5.0))
    engine.add_request(list(range(8, 16)),
                       SamplingParams(max_new_tokens=4))
    engine.step()                        # both admitted and prefilled
    clk.advance(10.0)                    # past the first request's TTL
    outs = []
    while engine.has_unfinished():
        outs.extend(engine.step())
    by_id = {o.req_id: o for o in outs}
    assert by_id[0].finish_reason == "timeout"
    assert "deadline_s=5.0" in by_id[0].error
    assert by_id[1].finish_reason == "length" and by_id[1].error is None
    engine.pool.check_invariants(engine._seqs.values())
    assert engine.stats()["failed_requests"] == 1


def test_queue_full_rejects_admission(model):
    cfg, params = model
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=4, max_model_len=64, max_queue=2))
    engine.add_request(list(range(6)), SamplingParams(max_new_tokens=2))
    engine.add_request(list(range(6)), SamplingParams(max_new_tokens=2))
    with pytest.raises(QueueFullError):
        engine.add_request(list(range(6)), SamplingParams(max_new_tokens=2))
    outs = engine.run_to_completion()
    assert len(outs) == 2 and all(o.error is None for o in outs)


def test_watchdog_clears_long_stall(model):
    """A stall longer than the watchdog's patience: run_to_completion must
    clear it (recovery, not the hang raise) and finish identically."""
    cfg, params = model
    reqs = _requests(4, n=3)
    _, base = _run(cfg, params, reqs)
    fc = FaultConfig(enabled=True, salt=1, stall_rate=1.0, max_faults=1,
                     stall_steps=500, stall_s=0.0)
    eng, outs = _run(cfg, params, reqs, faults=fc, stall_patience=4)
    _assert_absorbed(base, outs)
    assert all(o.error is None for o in outs.values())
    s = eng.stats()
    assert s["faults"]["by_site"]["stall"] == 1
    assert s["recoveries"] >= 1          # includes the stall_clear action


def test_alloc_faults_degrade_not_crash(model):
    cfg, params = model
    reqs = _requests(4)
    _, base = _run(cfg, params, reqs)
    fc = FaultConfig(enabled=True, salt=2, alloc_rate=1.0, max_faults=3)
    eng, chaos = _run(cfg, params, reqs, faults=fc, stall_patience=16)
    _assert_absorbed(base, chaos)
    assert all(o.error is None for o in chaos.values())
    assert eng.stats()["faults"]["by_site"]["alloc"] == 3


# ===================================================== randomized walks

def _chaos_engine(cfg, params, salt):
    fc = FaultConfig(enabled=True, salt=salt, nan_rate=0.2, alloc_rate=0.1,
                     draft_rate=0.2, step_rate=0.1, stall_rate=0.05,
                     stall_steps=2, stall_s=0.0)
    return LampEngine(cfg, params, EngineConfig(
        block_size=4, max_model_len=64, max_prefill_tokens=32,
        max_prefill_batch=4, max_decode_batch=8, speculative=True,
        draft_len=2, max_queue=8, paranoid=True, faults=fc,
        stall_patience=8))


def test_chaos_walk_seeded(model):
    """Seeded fallback walk (runs without hypothesis): random interleaving
    of admissions and steps over a fault-enabled engine; the pool must stay
    consistent throughout and every request must finish or fail alone."""
    cfg, params = model
    rng = np.random.default_rng(0)
    eng = _chaos_engine(cfg, params, salt=13)
    outs, admitted = [], 0
    for _ in range(60):
        if admitted < 10 and rng.random() < 0.4:
            plen = int(rng.integers(3, 16))
            try:
                eng.add_request(rng.integers(0, 128, size=plen).tolist(),
                                SamplingParams(
                                    max_new_tokens=int(rng.integers(2, 6)),
                                    seed=admitted))
                admitted += 1
            except QueueFullError:
                pass
        outs.extend(eng.step())
    outs.extend(eng.run_to_completion())
    assert len(outs) == admitted
    for o in outs:
        assert o.finish_reason is not None
    eng.pool.check_invariants(eng._seqs.values())


try:
    import hypothesis
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    class EngineChaosMachine(RuleBasedStateMachine):
        """Random admit/step interleavings over a fault-enabled engine
        (all five sites active). The machine asserts the absorb contract
        after every rule: no crash escapes, the pool invariants hold, and
        teardown drains the engine to a finish-or-fail for every request.
        Kept deliberately small: each step is a jitted launch."""
        cfg = None
        params = None

        @initialize(salt=st.integers(0, 7))
        def setup(self, salt):
            self.eng = _chaos_engine(type(self).cfg, type(self).params,
                                     salt=salt)
            self.admitted = 0
            self.finished = 0

        @rule(plen=st.integers(3, 14), new=st.integers(2, 5))
        def admit(self, plen, new):
            if self.admitted >= 8:
                return
            try:
                self.eng.add_request(
                    [(plen * 7 + i) % 128 for i in range(plen)],
                    SamplingParams(max_new_tokens=new, seed=self.admitted))
                self.admitted += 1
            except QueueFullError:
                pass

        @rule(n=st.integers(1, 4))
        def step(self, n):
            for _ in range(n):
                self.finished += len(self.eng.step())

        @invariant()
        def pool_consistent(self):
            if hasattr(self, "eng"):
                self.eng.pool.check_invariants(self.eng._seqs.values())

        def teardown(self):
            if hasattr(self, "eng"):
                outs = self.eng.run_to_completion()
                assert self.finished + len(outs) == self.admitted
                assert all(o.finish_reason is not None for o in outs)
                self.eng.pool.check_invariants(self.eng._seqs.values())


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_engine_chaos_machine(model):
    cfg, params = model
    EngineChaosMachine.cfg = cfg
    EngineChaosMachine.params = params
    # explicit small settings override the ci/dev profiles: every machine
    # step is a real jitted engine step, so the deep-fuzz budget lives in
    # the seeded walk above and the chaos differential tests, not here
    hypothesis.stateful.run_state_machine_as_test(
        EngineChaosMachine,
        settings=hypothesis.settings(max_examples=5, stateful_step_count=12,
                                     deadline=None, derandomize=True))
