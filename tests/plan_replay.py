"""Recorded-StepPlan trace capture and replay for differential testing.

The fused-step equivalence claim is *per plan*: executing one mixed
StepPlan through the single fused launch must produce exactly what the
legacy phase-segregated sub-steps produce for the same plan. To assert
that end-to-end we need both engines to see the same plan stream -- so
the harness records the exact descriptor sequence a live engine's
scheduler emits, then replays a twin engine under a checker that fails
loudly the moment its scheduler deviates from the recorded trace.

Scheduling is deterministic (FCFS + fixed tie-breaks off explicit arrival
times), so a twin configured identically reproduces the trace naturally;
the checker turns any silent divergence (which would void the token
comparison downstream) into an immediate assertion with the step index
and both descriptors. The schedulers keep doing their real work -- block
allocation, prefix matching, preemption -- because a plan's correctness
depends on that pool state; only the *observation* is instrumented.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class PlanRecord:
    """Engine-independent descriptor of one StepPlan (request ids instead
    of Sequence objects, so records compare across engine instances)."""
    kind: str
    req_ids: Tuple[int, ...]
    windows: Optional[Tuple[int, ...]]
    draft_lens: Optional[Tuple[int, ...]]
    roles: Optional[Tuple[str, ...]]


def describe(plan) -> Optional[PlanRecord]:
    if plan is None:
        return None
    return PlanRecord(
        kind=plan.kind,
        req_ids=tuple(s.req_id for s in plan.seqs),
        windows=tuple(plan.windows) if plan.windows is not None else None,
        draft_lens=(tuple(plan.draft_lens)
                    if plan.draft_lens is not None else None),
        roles=tuple(plan.roles) if plan.roles is not None else None)


def record_plans(engine) -> List[Optional[PlanRecord]]:
    """Wrap `engine.scheduler.schedule` so every emitted plan appends its
    descriptor to the returned list (None entries mark idle steps)."""
    trace: List[Optional[PlanRecord]] = []
    inner = engine.scheduler.schedule

    def recording():
        plan = inner()
        trace.append(describe(plan))
        return plan

    engine.scheduler.schedule = recording
    return trace


def check_replay(engine, trace: List[Optional[PlanRecord]]
                 ) -> List[Optional[PlanRecord]]:
    """Wrap `engine.scheduler.schedule` to assert, plan by plan, that the
    twin reproduces `trace` exactly. Returns the twin's own trace (equal
    to the prefix of `trace` it has consumed so far)."""
    seen: List[Optional[PlanRecord]] = []
    inner = engine.scheduler.schedule

    def checking():
        plan = inner()
        rec = describe(plan)
        i = len(seen)
        seen.append(rec)
        assert i < len(trace), (
            f"replay step {i}: twin scheduled {rec} past the end of the "
            f"recorded trace ({len(trace)} plans)")
        assert rec == trace[i], (
            f"replay diverged at step {i}:\n  recorded: {trace[i]}\n"
            f"  twin:     {rec}")
        return plan

    engine.scheduler.schedule = checking
    return seen
