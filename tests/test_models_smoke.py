"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, shape and finiteness asserts; decode == teacher-forced forward.

The FULL published configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation) -- see launch/dryrun.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.core.policy import LampPolicy
from repro.models import api
from repro.optim import adamw

B, S = 2, 24


def _batch(cfg, key, seq=S):
    b = {"tokens": jax.random.randint(key, (B, seq), 0, cfg.vocab)}
    if cfg.family == "whisper":
        b["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model)) * 0.1
    if cfg.family == "llava":
        b["image_embeds"] = jax.random.normal(key, (B, cfg.n_patches, cfg.d_model)) * 0.1
    return b


@pytest.fixture(scope="module", params=ASSIGNED_ARCHS)
def arch_setup(request):
    cfg = reduced(get_config(request.param))
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    return cfg, params, _batch(cfg, key)


def test_forward_shapes_and_finite(arch_setup):
    cfg, params, batch = arch_setup
    logits = api.forward_logits(cfg, params, batch)
    exp_len = S + (cfg.n_patches if cfg.family == "llava" else 0)
    assert logits.shape == (B, exp_len, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_one_train_step(arch_setup):
    cfg, params, batch = arch_setup
    opt = adamw.init_state(params)

    def step(p, o, b):
        (loss, m), g = jax.value_and_grad(
            lambda pp: api.loss_fn(cfg, pp, b), has_aux=True)(p)
        p2, o2, om = adamw.apply_updates(adamw.AdamWConfig(lr=1e-3), p, g, o)
        return p2, o2, loss

    p2, o2, loss = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(loss))
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


def test_decode_consistency(arch_setup):
    """prefill(S-1) + decode(1) logits == teacher-forced forward at pos S-1."""
    cfg, params, batch = arch_setup
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=8.0)  # dropless for exactness
    cfg = cfg.replace(lamp=LampPolicy.disabled())
    toks = batch["tokens"]
    full = api.forward_logits(cfg, params, batch)
    pos = S - 1 + (cfg.n_patches if cfg.family == "llava" else 0)
    cache = api.init_cache(cfg, B, 64, jnp.float32)
    pb = dict(batch)
    pb["tokens"] = toks[:, : S - 1]
    _, cache = api.prefill(cfg, params, pb, cache, use_lamp=False)
    ld, cache2 = api.decode_step(cfg, params, cache, toks[:, S - 1: S],
                                 use_lamp=False)
    np.testing.assert_allclose(np.asarray(ld[:, 0]), np.asarray(full[:, pos]),
                               rtol=2e-3, atol=2e-4)
    # cache length advanced
    if "length" in cache2:
        assert int(cache2["length"][0]) == int(cache["length"][0]) + 1


def test_lamp_serving_close_to_exact(arch_setup):
    """Serving with the LAMP policy stays close to exact serving (the
    policy's purpose: low-precision accumulate + tiny recompute ~ FP32)."""
    cfg, params, batch = arch_setup
    if cfg.is_attention_free:
        pytest.skip("KQ-LAMP inapplicable (rwkv6); covered by logits-site test")
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=8.0)
    cache = api.init_cache(cfg, B, 64, jnp.float32)
    _, cache = api.prefill(cfg, params, batch, cache, use_lamp=False)
    l_exact, _ = api.decode_step(cfg, params, cache,
                                 batch["tokens"][:, -1:], use_lamp=False)
    cache2 = api.init_cache(cfg, B, 64, jnp.float32)
    _, cache2 = api.prefill(cfg, params, batch, cache2, use_lamp=True)
    l_lamp, _ = api.decode_step(cfg, params, cache2,
                                batch["tokens"][:, -1:], use_lamp=True)
    p = jax.nn.softmax(l_exact[:, 0])
    q = jax.nn.softmax(l_lamp[:, 0])
    kl = float(jnp.mean(jnp.sum(p * (jnp.log(p + 1e-20) - jnp.log(q + 1e-20)), -1)))
    assert kl < 0.5  # same model, mild precision drift only


def test_reduced_preserves_family_features():
    for name in ASSIGNED_ARCHS:
        full, red = get_config(name), reduced(get_config(name))
        assert red.family == full.family
        assert (red.n_experts > 0) == (full.n_experts > 0)
        assert (red.window is not None) == (full.window is not None)
        assert (red.n_meta_tokens > 0) == (full.n_meta_tokens > 0)
        assert (red.enc_seq > 0) == (full.enc_seq > 0)
