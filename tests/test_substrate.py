"""Substrate tests: data determinism, checkpoint roundtrip/atomicity/
resharding, straggler policy, gradient compression, quantized collectives,
pipeline parallelism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import DataConfig, SyntheticDataset
from repro.data import tokenizer as tok
from repro.distributed.straggler import StragglerMonitor, StragglerPolicy
from repro.optim import adamw, compression


# ------------------------------------------------------------------ data

def test_data_deterministic_and_host_sharded():
    cfg = DataConfig(vocab=97, seq_len=32, global_batch=8, seed=3)
    ds = SyntheticDataset(cfg)
    b1 = ds.batch_at(5)
    b2 = ds.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host shards tile the global batch exactly
    h0 = ds.batch_at(5, host_id=0, n_hosts=2)["tokens"]
    h1 = ds.batch_at(5, host_id=1, n_hosts=2)["tokens"]
    np.testing.assert_array_equal(np.concatenate([h0, h1]), b1["tokens"])
    # different steps differ
    assert not np.array_equal(ds.batch_at(6)["tokens"], b1["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 97


def test_markov_stream_is_learnable_structure():
    """Markov data has sub-uniform next-token entropy (something to learn)."""
    cfg = DataConfig(vocab=64, seq_len=256, global_batch=4, seed=0, branching=4)
    ds = SyntheticDataset(cfg)
    toks = ds.batch_at(0)["tokens"]
    # successors per token should be limited to `branching` values
    succ = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(b))
    counts = [len(v) for v in succ.values()]
    assert np.mean(counts) <= cfg.branching + 1e-9


def test_tokenizer_roundtrip_and_pack():
    s = "hello LAMP é中"
    ids = tok.encode(s)
    assert tok.decode(ids) == s
    rows = tok.pack(["abc", "defg", "hi"], seq_len=8)
    assert rows.shape[1] == 8 and rows.dtype == np.int32


# ------------------------------------------------------------ checkpoint

def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda t: t + step, tree), blocking=True)
    assert mgr.latest_step() == 3
    got = mgr.restore(jax.eval_shape(lambda: tree))
    np.testing.assert_allclose(np.asarray(got["a"], np.float32),
                               np.asarray(tree["a"]) + 3)
    # GC kept only 2
    assert len(list(tmp_path.glob("step_*"))) == 2


def test_checkpoint_atomic_on_partial_write(tmp_path):
    """A leftover .tmp dir (simulated crash) must not break restore."""
    mgr = CheckpointManager(tmp_path, keep_last=3)
    tree = {"w": jnp.ones((3,))}
    mgr.save(1, tree, blocking=True)
    (tmp_path / "step_00000002.tmp").mkdir()
    (tmp_path / "step_00000002.tmp" / "junk").write_text("partial")
    assert mgr.latest_step() == 1
    got = mgr.restore(jax.eval_shape(lambda: tree))
    np.testing.assert_allclose(np.asarray(got["w"]), 1.0)


def test_checkpoint_latest_pointer_fallback(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=3)
    mgr.save(1, {"w": jnp.ones(2)}, blocking=True)
    mgr.save(2, {"w": jnp.ones(2) * 2}, blocking=True)
    (tmp_path / "LATEST").write_text("step_99999999")  # corrupt pointer
    assert mgr.latest_step() == 2


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.ones((2, 2))}, blocking=True)
    with pytest.raises(ValueError):
        mgr.restore(jax.eval_shape(lambda: {"w": jnp.ones((3, 3))}))


def test_elastic_restore_on_host_mesh(tmp_path):
    """Save -> restore with explicit shardings on the 1-device host mesh
    (the resharding path; mesh size is irrelevant to the mechanics)."""
    from repro.checkpoint.elastic import elastic_restore, validate_batch
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    params = {"mlp": {"wi": jnp.ones((8, 16)), "wo": jnp.ones((16, 8))}}
    opt = adamw.init_state(params)
    mgr = CheckpointManager(tmp_path)
    mgr.save(7, {"params": params, "opt": opt}, blocking=True)
    p_shape = jax.eval_shape(lambda: params)
    o_shape = jax.eval_shape(lambda: opt)
    p2, o2, _, _ = elastic_restore(mgr, p_shape, o_shape, mesh)
    np.testing.assert_allclose(np.asarray(p2["mlp"]["wi"]), 1.0)
    ok, _ = validate_batch(8, mesh)
    assert ok


# -------------------------------------------------------------- straggler

def test_straggler_detection_and_escalation():
    mon = StragglerMonitor(StragglerPolicy(slow_factor=2.0, window=8,
                                           max_consecutive_slow=2))
    for _ in range(8):
        assert mon.record_step(0.1) is None
    assert mon.record_step(0.5) == "warn_slow"
    assert mon.record_step(0.5) == "checkpoint_and_replace"
    assert mon.record_step(0.1) is None  # reset


def test_heartbeat_timeout():
    t = [0.0]
    mon = StragglerMonitor(StragglerPolicy(heartbeat_timeout_s=10),
                           clock=lambda: t[0])
    mon.heartbeat(0)
    mon.heartbeat(1)
    t[0] = 5.0
    mon.heartbeat(0)
    t[0] = 12.0
    assert mon.dead_hosts() == [1]
    assert mon.should_shrink()


# ------------------------------------------------------------ compression

def test_topk_error_feedback_conserves_mass():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                          jnp.float32)}
    err = compression.init_error(g)
    sent, err2, stats = compression.topk_compress(g, err, 0.1)
    assert 0.05 < stats["density"] < 0.2
    np.testing.assert_allclose(np.asarray(sent["w"] + err2["w"]),
                               np.asarray(g["w"]), rtol=1e-6)
    # after a second round the residual is re-sent: cumulative sum converges
    sent2, err3, _ = compression.topk_compress(
        jax.tree.map(jnp.zeros_like, g), err2, 0.5)
    total = np.asarray(sent["w"] + sent2["w"] + err3["w"])
    np.testing.assert_allclose(total, np.asarray(g["w"]), rtol=1e-5)


def test_int8_quantization_roundtrip():
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(128,)),
                          jnp.float32)}
    q, s = compression.quantize_int8(g)
    back = compression.dequantize_int8(q, s)
    rel = float(jnp.max(jnp.abs(back["w"] - g["w"])) / jnp.max(jnp.abs(g["w"])))
    assert rel < 1.0 / 127 + 1e-6


def test_quantized_psum_single_device():
    from repro.distributed.collectives import quantized_psum
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    g = {"w": jnp.asarray([1.0, -2.0, 0.5])}
    out = quantized_psum(mesh, g, axis="data")
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=2.0 / 127)


# ---------------------------------------------------------------- pipeline

def test_pipeline_single_stage_identity():
    """S=1 degenerate pipeline == plain microbatch map (host mesh)."""
    from repro.distributed.pipeline import pipeline_apply, split_stages
    mesh = jax.make_mesh((1,), ("stage",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    L, d = 4, 8
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(L, d, d)) * 0.1,
                               jnp.float32)}
    x = jnp.asarray(np.random.default_rng(1).normal(size=(3, 2, d)), jnp.float32)

    def stage_fn(p, xin):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, xin, p["w"])
        return y

    staged = split_stages(params, 1)
    out = pipeline_apply(mesh, stage_fn, staged, x)
    want = jax.vmap(lambda mb: stage_fn(params, mb))(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# ----------------------------------------------------------- train loop

def test_train_loop_resume_and_preemption(tmp_path):
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.train_loop import TrainLoopConfig, train
    cfg = reduced(get_config("glm4-9b"), layers=1, d_model=32, vocab=64)
    mesh = make_host_mesh()
    loop = TrainLoopConfig(total_steps=6, checkpoint_every=3, log_every=100,
                           checkpoint_dir=str(tmp_path))
    data = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=2)
    out1 = train(cfg, mesh, loop, data_cfg=data)
    assert len(out1["metrics"]) == 6
    # resume: runs only the remaining steps
    loop2 = TrainLoopConfig(total_steps=8, checkpoint_every=3, log_every=100,
                            checkpoint_dir=str(tmp_path))
    out2 = train(cfg, mesh, loop2, data_cfg=data)
    assert len(out2["metrics"]) == 2
