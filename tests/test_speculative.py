"""Tests for LAMP self-draft speculative decoding.

Layers:

  * Accept-rule units: greedy acceptance chains, the kd budget mask, the
    bonus position, and a statistical check that the accept/residual-
    resample rule reproduces the target distribution for an arbitrary
    draft distribution (the correctness property of Leviathan et al.).
  * Verify-window unit: `paged_verify_window` position-by-position logits
    match sequential `paged_decode_step` logits (same tokens, same cache).
  * Engine differential (the acceptance criterion): the speculative engine
    at temp=0 produces bit-identical token streams to the non-speculative
    engine for kernel="gather" and kernel="pallas", across chunked prefill
    + prefix sharing, with per-request acceptance rates in [0, 1] and mean
    acceptance > 0.5 on the reduced-GPT-2 smoke config.
  * Robustness: preemption pressure under a tiny pool, stop-token
    truncation mid-accepted-run, draft budgets clamped by the token limit,
    temperature/top-k streams, and block-leak checks after every run.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import api, transformer
from repro.serving import (EngineConfig, LampEngine, SamplingParams,
                           SpecConfig)
from repro.serving import sampling as SAMP
from repro.serving.speculative import (draft_model_config, spec_step_fns,
                                       speculative_accept)


@pytest.fixture(scope="module")
def model():
    cfg = reduce_cfg(get_config("gpt2")).replace(vocab=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompt(rng, cfg, n):
    return rng.integers(0, cfg.vocab, size=n).tolist()


def _run_engine(cfg, params, requests, **ekw):
    kw = dict(block_size=4, max_model_len=64, max_prefill_tokens=16,
              max_prefill_batch=4, max_decode_batch=8)
    kw.update(ekw)
    engine = LampEngine(cfg, params, EngineConfig(**kw))
    for prompt, sp in requests:
        engine.add_request(prompt, sp)
    outs = engine.run_to_completion()
    assert engine.pool.num_used == 0, "leaked KV blocks"
    return engine, {o.req_id: o for o in outs}


# ------------------------------------------------------------ accept rule

def _accept(verify_logits, draft_tokens, draft_logits, kd, temps, top_k=None,
            seeds=None, counts=None):
    draft_tokens = np.asarray(draft_tokens, np.int32)
    R, k = draft_tokens.shape
    if seeds is None:
        seeds = np.arange(R, dtype=np.int32)
    if counts is None:
        counts = np.zeros(R, np.int32)
    if top_k is None:
        top_k = np.zeros(R, np.int32)
    emit, n_acc = speculative_accept(
        jnp.asarray(verify_logits, jnp.float32),
        jnp.asarray(draft_tokens, jnp.int32),
        jnp.asarray(draft_logits, jnp.float32),
        jnp.asarray(kd, jnp.int32), jnp.asarray(seeds, jnp.int32),
        jnp.asarray(counts, jnp.int32), jnp.asarray(temps, jnp.float32),
        jnp.asarray(top_k, jnp.int32))
    return np.asarray(emit), np.asarray(n_acc)


def test_accept_greedy_chains():
    """Greedy: accept while the draft equals the verifier's argmax; the
    emitted token at the cut is the verifier's argmax there."""
    V, k = 8, 3
    p = np.full((1, k + 1, V), -10.0, np.float32)
    argmaxes = [2, 5, 1, 7]
    for j, t in enumerate(argmaxes):
        p[0, j, t] = 0.0
    q = np.zeros((1, k, V), np.float32)

    # all drafts match -> accept all + bonus argmax
    emit, n = _accept(p, [[2, 5, 1]], q, [k], [0.0])
    assert n[0] == 3 and emit[0, :4].tolist() == [2, 5, 1, 7]
    # mismatch at j=1 -> one accepted, correction is argmax at position 1
    emit, n = _accept(p, [[2, 4, 1]], q, [k], [0.0])
    assert n[0] == 1 and emit[0, :2].tolist() == [2, 5]
    # immediate mismatch -> plain-decode progress (verifier's first argmax)
    emit, n = _accept(p, [[0, 5, 1]], q, [k], [0.0])
    assert n[0] == 0 and emit[0, 0] == 2
    # the kd budget caps acceptance even when everything matches
    emit, n = _accept(p, [[2, 5, 1]], q, [1], [0.0])
    assert n[0] == 1 and emit[0, :2].tolist() == [2, 5]
    # kd = 0: verify-only round == one plain decode step
    emit, n = _accept(p, [[0, 0, 0]], q, [0], [0.0])
    assert n[0] == 0 and emit[0, 0] == 2


def test_accept_matches_target_distribution():
    """With p != q at temperature 1, the emitted first token of each round
    must be distributed as p (accept + residual resample == exact target
    sampling). Empirical check over many independent rows."""
    V, R, k = 4, 4096, 1
    rng = np.random.default_rng(0)
    p_logits = np.array([0.5, -0.6, 1.2, -2.0], np.float32)
    q_logits = np.array([-1.0, 1.0, 0.0, 0.3], np.float32)
    temps = np.ones(R, np.float32)
    seeds = np.arange(R, dtype=np.int32)
    counts = np.zeros(R, np.int32)
    # draft proposals sampled from q exactly like the drafter would
    d = np.asarray(SAMP.sample_rows(
        jnp.broadcast_to(jnp.asarray(q_logits), (R, V)),
        jnp.asarray(seeds), jnp.asarray(counts), jnp.asarray(temps),
        salt=SAMP.SALT_DRAFT))[:, None]
    verify = np.broadcast_to(p_logits, (R, k + 1, V)).copy()
    draft = np.broadcast_to(q_logits, (R, k, V)).copy()
    emit, n_acc = _accept(verify, d, draft, np.ones(R, np.int32), temps,
                          seeds=seeds, counts=counts)
    first = np.where(n_acc > 0, d[:, 0], emit[np.arange(R), n_acc])
    counts_emp = np.bincount(first, minlength=V) / R
    p = np.exp(p_logits) / np.exp(p_logits).sum()
    assert (n_acc > 0).any() and (n_acc == 0).any()
    np.testing.assert_allclose(counts_emp, p, atol=0.035)


def test_accept_statistical_independent_of_draft_dist():
    """Same check with q == p (acceptance ~ 1) and with a near-disjoint q
    (acceptance ~ 0): the output marginal stays p either way."""
    V, R = 4, 4096
    p_logits = np.array([1.0, 0.0, -1.0, 0.5], np.float32)
    p = np.exp(p_logits) / np.exp(p_logits).sum()
    temps = np.ones(R, np.float32)
    seeds = np.arange(R, dtype=np.int32)
    counts = np.zeros(R, np.int32)
    for q_logits, lo, hi in [(p_logits, 0.95, 1.01),
                             (np.array([-8, -8, 8, -8], np.float32),
                              0.0, 0.35)]:
        d = np.asarray(SAMP.sample_rows(
            jnp.broadcast_to(jnp.asarray(q_logits), (R, V)),
            jnp.asarray(seeds), jnp.asarray(counts), jnp.asarray(temps),
            salt=SAMP.SALT_DRAFT))[:, None]
        emit, n_acc = _accept(np.broadcast_to(p_logits, (R, 2, V)).copy(),
                              d, np.broadcast_to(q_logits, (R, 1, V)).copy(),
                              np.ones(R, np.int32), temps,
                              seeds=seeds, counts=counts)
        rate = float(np.mean(n_acc))
        assert lo <= rate <= hi, rate
        first = np.where(n_acc > 0, d[:, 0], emit[np.arange(R), n_acc])
        emp = np.bincount(first, minlength=V) / R
        np.testing.assert_allclose(emp, p, atol=0.035)


def test_top_k_filter_applies_to_both_distributions():
    """top_k=1 makes both p and q degenerate at their argmax: greedy
    behavior at any temperature."""
    V = 6
    p = np.random.default_rng(1).normal(size=(64, 2, V)).astype(np.float32)
    q = np.random.default_rng(2).normal(size=(64, 1, V)).astype(np.float32)
    d = np.argmax(q[:, 0], axis=-1)[:, None].astype(np.int32)
    emit, n_acc = _accept(p, d, q, np.ones(64, np.int32),
                          np.full(64, 0.9, np.float32),
                          top_k=np.ones(64, np.int32))
    p_arg = np.argmax(p, axis=-1)
    for r in range(64):
        expect_acc = int(p_arg[r, 0] == d[r, 0])
        assert n_acc[r] == expect_acc
        assert emit[r, n_acc[r]] == p_arg[r, n_acc[r]]


# ------------------------------------------------------- verify window unit

@pytest.mark.parametrize("kernel", ["gather", "pallas"])
def test_verify_window_matches_sequential_decode(model, kernel):
    """One multi-token verify window over tokens t1..t3 must reproduce the
    logits of three sequential decode steps feeding those same tokens."""
    cfg, params = model
    rng = np.random.default_rng(3)
    prompt = _prompt(rng, cfg, 9)
    bs = 4
    arenas = [transformer.init_paged_cache(cfg, 16, bs, jnp.float32)
              for _ in range(2)]
    bt = jnp.asarray(np.array([[1, 2, 3, 4, 0, 0, 0, 0]], np.int32))
    tokens = np.zeros((1, 16), np.int32)
    tokens[0, :9] = prompt
    steps = [int(x) for x in rng.integers(0, cfg.vocab, size=4)]
    seq_logits = []
    for name, arena in (("seq", arenas[0]), ("win", arenas[1])):
        _, arena, _ = transformer.paged_prefill(
            cfg, params, jnp.asarray(tokens), arena, bt,
            jnp.asarray([9], jnp.int32), kernel=kernel)
        if name == "seq":
            length = 9
            for t in steps[:3]:
                lg, arena, _ = transformer.paged_decode_step(
                    cfg, params, arena, bt, jnp.asarray([length], jnp.int32),
                    jnp.asarray([[t]], jnp.int32), kernel=kernel)
                seq_logits.append(np.asarray(lg)[0, 0])
                length += 1
        else:
            win = np.zeros((1, 4), np.int32)
            win[0, :3] = steps[:3]
            wlg, arena, _ = transformer.paged_verify_window(
                cfg, params, jnp.asarray(win), arena, bt,
                jnp.asarray([9], jnp.int32), jnp.asarray([3], jnp.int32),
                kernel=kernel)
            win_logits = np.asarray(wlg)[0]
    for j in range(3):
        np.testing.assert_allclose(win_logits[j], seq_logits[j],
                                   atol=2e-4, rtol=2e-4)
        assert np.argmax(win_logits[j]) == np.argmax(seq_logits[j])


def test_draft_model_config_rule_none(model):
    cfg, _ = model
    dcfg = draft_model_config(cfg, SpecConfig(draft_len=3))
    assert dcfg.lamp.kq.rule == "none"
    assert dcfg.lamp.kq.mu == cfg.lamp.kq.mu
    off = cfg.replace(lamp=cfg.lamp.replace(
        kq=cfg.lamp.kq.replace(enabled=False)))
    assert draft_model_config(off, SpecConfig()) is off


def test_spec_config_validation():
    with pytest.raises(ValueError, match="draft_len"):
        SpecConfig(draft_len=0)
    with pytest.raises(ValueError, match="draft_rule"):
        SpecConfig(draft_rule="fancy")
    assert SpecConfig(draft_len=4).verify_width == 8
    assert SpecConfig(draft_len=3).verify_width == 4


def test_spec_fns_cached(model):
    cfg, _ = model
    a = spec_step_fns(cfg, True, "gather", SpecConfig(draft_len=3))
    b = spec_step_fns(cfg, True, "gather", SpecConfig(draft_len=3))
    c = spec_step_fns(cfg, True, "gather", SpecConfig(draft_len=4))
    assert a is b and a is not c


# ------------------------------------------------------ engine differential

@pytest.mark.parametrize("kernel", ["gather", "pallas"])
def test_spec_engine_greedy_identity(model, kernel):
    """THE acceptance criterion: bit-identical greedy token streams spec-on
    vs spec-off, through chunked prefill + prefix sharing, on both
    kernels; per-request acceptance in [0, 1], mean acceptance > 0.5."""
    cfg, params = model
    rng = np.random.default_rng(21)
    shared = _prompt(rng, cfg, 9)        # shared prefix: starts > 0 windows
    reqs = []
    for i in range(6):
        prompt = (shared if i % 2 else []) + _prompt(
            rng, cfg, int(rng.integers(3, 18)))
        reqs.append((prompt, SamplingParams(
            max_new_tokens=int(rng.integers(2, 9)), seed=i)))
    base_e, base = _run_engine(cfg, params, reqs, kernel=kernel,
                               max_prefill_tokens=8)     # force chunking
    spec_e, spec = _run_engine(cfg, params, reqs, kernel=kernel,
                               max_prefill_tokens=8,
                               speculative=True, draft_len=3)
    assert len(spec) == len(base) == len(reqs)
    rates = []
    for i in base:
        assert spec[i].tokens == base[i].tokens, f"req {i}"
        assert 0.0 <= spec[i].spec_acceptance_rate <= 1.0
        if spec[i].spec_drafted:
            rates.append(spec[i].spec_acceptance_rate)
    assert rates and float(np.mean(rates)) > 0.5
    s = spec_e.stats()
    assert s["spec_rounds"] > 0
    assert s["spec_accepted_tokens"] <= s["spec_drafted_tokens"]
    assert 0.0 <= s["spec_acceptance_rate"] <= 1.0
    # speculative rounds emit > 1 token/round on average here, so the spec
    # engine must have used strictly fewer decode rounds
    assert s["spec_tokens_per_round"] > 1.0
    assert spec_e.decode_steps < base_e.decode_steps
    # the verify pass runs the real LAMP rule: recompute telemetry flows
    assert s["verify_recompute_rate"] > 0
    assert base_e.stats()["spec_rounds"] == 0


def test_spec_engine_sampled_streams_complete(model):
    """Temperature / top-k rows: correct lengths, sane telemetry (sampled
    streams are distribution-equal, not bit-equal, to non-speculative)."""
    cfg, params = model
    rng = np.random.default_rng(22)
    reqs = [(_prompt(rng, cfg, int(rng.integers(3, 16))),
             SamplingParams(max_new_tokens=6, seed=i, temperature=0.8,
                            top_k=0 if i % 2 else 16))
            for i in range(5)]
    engine, outs = _run_engine(cfg, params, reqs, speculative=True,
                               draft_len=4)
    for i, (prompt, sp) in enumerate(reqs):
        assert len(outs[i].tokens) == sp.max_new_tokens
        assert all(0 <= t < cfg.vocab for t in outs[i].tokens)
        assert 0.0 <= outs[i].spec_acceptance_rate <= 1.0
    assert engine.stats()["spec_drafted_tokens"] > 0


def test_spec_engine_preemption_pressure_identity(model):
    """A tiny pool under speculative decoding (rollbacks + preemptions +
    draft-lookahead shedding) must still match the unconstrained greedy
    stream."""
    cfg, params = model
    rng = np.random.default_rng(23)
    reqs = [(_prompt(rng, cfg, int(rng.integers(12, 36))),
             SamplingParams(max_new_tokens=8, seed=i)) for i in range(6)]
    _, base = _run_engine(cfg, params, reqs, n_blocks=200,
                          max_prefill_tokens=8)
    small, spec = _run_engine(cfg, params, reqs, n_blocks=20,
                              max_prefill_tokens=8, speculative=True,
                              draft_len=4)
    for i in base:
        assert spec[i].tokens == base[i].tokens, f"req {i}"


def test_spec_stop_token_truncates_accepted_run(model):
    """A stop token accepted mid-run ends the request there; surplus
    accepted tokens are dropped and their blocks rolled back."""
    cfg, params = model
    rng = np.random.default_rng(24)
    prompt = _prompt(rng, cfg, 7)
    _, g = _run_engine(cfg, params,
                       [(prompt, SamplingParams(max_new_tokens=8))])
    greedy = g[0].tokens
    stop = greedy[len(greedy) // 2]
    want = greedy[:greedy.index(stop) + 1]
    _, b = _run_engine(cfg, params, [(prompt, SamplingParams(
        max_new_tokens=8, stop_token=stop))])
    _, s = _run_engine(cfg, params, [(prompt, SamplingParams(
        max_new_tokens=8, stop_token=stop))], speculative=True, draft_len=4)
    assert b[0].tokens == s[0].tokens == want
    assert s[0].finish_reason == "stop_token"


def test_spec_draft_budget_clamped_by_token_limit(model):
    """max_new_tokens=1 leaves no draft budget: every round is verify-only
    (kd=0) and still emits the right token."""
    cfg, params = model
    rng = np.random.default_rng(25)
    reqs = [(_prompt(rng, cfg, 6), SamplingParams(max_new_tokens=1, seed=0))]
    _, base = _run_engine(cfg, params, reqs)
    engine, spec = _run_engine(cfg, params, reqs, speculative=True,
                               draft_len=4)
    assert spec[0].tokens == base[0].tokens
    assert spec[0].spec_drafted == 0
    assert engine.stats()["spec_acceptance_rate"] == 0.0


def test_spec_rejects_bad_draft_len(model):
    cfg, params = model
    with pytest.raises(ValueError, match="draft_len"):
        LampEngine(cfg, params, EngineConfig(speculative=True, draft_len=0))


# -------------------------------------------------------------- engine misc

def test_run_to_completion_raises_on_max_steps(model):
    cfg, params = model
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=4, max_model_len=64))
    engine.add_request([1, 2, 3], SamplingParams(max_new_tokens=8))
    with pytest.raises(RuntimeError, match="1 request\\(s\\) still live"):
        engine.run_to_completion(max_steps=2)
    assert engine.stats()["live_requests"] == 1
    # the stream is resumable after the limit fires
    outs = engine.run_to_completion()
    assert len(outs) == 1 and engine.stats()["live_requests"] == 0


def test_shared_sampler_top_k(model):
    """Engine top_k=1 at temperature > 0 equals the greedy stream (the
    filter leaves only the argmax); shared static sampler agrees."""
    cfg, params = model
    rng = np.random.default_rng(26)
    prompt = _prompt(rng, cfg, 8)
    _, greedy = _run_engine(cfg, params, [(prompt, SamplingParams(
        max_new_tokens=6, temperature=0.0))])
    _, k1 = _run_engine(cfg, params, [(prompt, SamplingParams(
        max_new_tokens=6, temperature=1.1, top_k=1))])
    assert k1[0].tokens == greedy[0].tokens
    lg = jnp.asarray(rng.normal(size=(3, 11)), jnp.float32)
    out = SAMP.sample(lg, jax.random.PRNGKey(0), 0.9, top_k=1)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.argmax(lg, -1)))
    # per-row filter: k=0 rows exactly unfiltered, k>0 rows keep top-k
    filt = SAMP.apply_top_k_rows(lg, jnp.asarray([0, 2, 11]))
    np.testing.assert_array_equal(np.asarray(filt[0]), np.asarray(lg[0]))
    np.testing.assert_array_equal(np.asarray(filt[2]), np.asarray(lg[2]))
    assert int(np.sum(np.isfinite(np.asarray(filt[1])))) == 2


def test_serve_loop_sampler_routed_through_shared(model):
    """The static-batch loop's sampler is the shared implementation:
    greedy at temp <= 0 and Gumbel-max (== categorical) above."""
    from repro.runtime.serve_loop import _sample
    rng = np.random.default_rng(27)
    lg = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    key = jax.random.PRNGKey(3)
    np.testing.assert_array_equal(
        np.asarray(_sample(lg, key, 0.0)), np.asarray(jnp.argmax(lg, -1)))
    got = np.asarray(_sample(lg, key, 0.7))
    want = np.asarray(jax.random.categorical(key, lg / 0.7, axis=-1))
    np.testing.assert_array_equal(got, want)
