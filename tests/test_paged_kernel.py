"""Differential harness for the fused Pallas paged-attention kernels.

Every test drives the same triangle of implementations over a shared paged
arena + block tables:

  fused   -- kernels.paged_attention (gather-free, block-table index map)
  gather  -- the serving reference path: arena[block_tables] materialized,
             then core.attention.{decode_attention_lamp, attention_lamp}
  dense   -- the same KV packed into a contiguous per-sequence cache (the
             PR-1 equivalence anchor)

and asserts outputs agree within float32 softmax roundoff and LAMP
selection counts match *exactly* (the two-pass kernel recomputes y_low with
dot_ps-identical rounding, so the look-ahead masks are bit-equal).

Coverage: (block_size, ragged lengths incl. block boundaries, window
offsets/starts, sliding windows, every LAMP rule + lamp-off), NaN-poisoned
dead blocks (fully-masked blocks must be skipped, not summed as zeros), a
hypothesis fuzz over random block tables / lengths (pinned "ci" profile),
and a seeded fallback walk that runs without hypothesis installed.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.attention import (attention_lamp, attention_reference,
                                  decode_attention_lamp)
from repro.core.policy import LampSite
from repro.kernels import ops
from repro.kernels.paged_attention import decode_kv_bytes, supports_site

H, HKV, HD = 4, 2, 16

SITES = {
    "off": LampSite(enabled=False),
    "relaxed-g0": LampSite(enabled=True, rule="relaxed", mu=7, tau=0.05,
                           granularity=0),
    "relaxed-g1": LampSite(enabled=True, rule="relaxed", mu=7, tau=0.1,
                           granularity=1),
    "strict-g1": LampSite(enabled=True, rule="strict", mu=7, tau=0.1,
                          granularity=1),
    "ln-g0": LampSite(enabled=True, rule="relaxed_ln", mu=7, tau=0.2,
                      granularity=0, n_ref=64),
    "rule-none": LampSite(enabled=True, rule="none", mu=5, granularity=0),
}

TOL = dict(rtol=2e-5, atol=2e-6)


def _assert_counts_match(nsel, nsel_ref, site):
    """Selection counts are bit-exact for the max-based rules (relaxed /
    relaxed_ln / none / off): the kernel's y_low and running row max are
    bitwise identical to the reference. The strict rule additionally
    thresholds on the softmax normalizer l, which the kernel accumulates
    blockwise while the reference does one materialized sum -- a criterion
    value landing within an ulp of tau may flip, so strict gets a per-row
    slack of 1 (a real mask bug shifts counts by far more)."""
    nsel, nsel_ref = np.asarray(nsel), np.asarray(nsel_ref)
    if site.enabled and site.rule == "strict":
        np.testing.assert_allclose(nsel, nsel_ref, atol=1)
    else:
        np.testing.assert_array_equal(nsel, nsel_ref)


def _repeat_kv(t, n):
    return jnp.repeat(t, n, axis=1) if n > 1 else t


def make_paged(seed, lengths, bs, n_max, *, span=None):
    """Random arena + per-row block tables. Row r owns ceil(span[r]/bs)
    distinct shuffled blocks (block 0 stays the null block); the rest of the
    table is null-padded. span defaults to lengths (decode); prefill passes
    starts + window width."""
    rng = np.random.default_rng(seed)
    R = len(lengths)
    span = list(lengths) if span is None else list(span)
    n_blocks = 1 + R * n_max
    arena_k = jnp.asarray(rng.normal(size=(n_blocks, bs, HKV, HD)) * 1.5,
                          jnp.float32)
    arena_v = jnp.asarray(rng.normal(size=(n_blocks, bs, HKV, HD)),
                          jnp.float32)
    perm = rng.permutation(np.arange(1, n_blocks))
    bt = np.zeros((R, n_max), np.int32)
    for r in range(R):
        nb = -(-max(int(span[r]), 1) // bs)
        bt[r, :nb] = perm[r * n_max:r * n_max + nb]
    return arena_k, arena_v, jnp.asarray(bt)


def gathered_heads(arena_k, arena_v, bt):
    R = bt.shape[0]
    ks = arena_k[bt].reshape(R, -1, HKV, HD)
    vs = arena_v[bt].reshape(R, -1, HKV, HD)
    kh = _repeat_kv(jnp.moveaxis(ks, 2, 1), H // HKV)
    vh = _repeat_kv(jnp.moveaxis(vs, 2, 1), H // HKV)
    return kh, vh


def check_decode(seed, lengths, bs, n_max, site, *, window=None,
                 check_dense=False):
    """Fused decode vs gather (vs dense) on one random paged layout."""
    arena_k, arena_v, bt = make_paged(seed, lengths, bs, n_max)
    lengths = jnp.asarray(lengths, jnp.int32)
    rng = np.random.default_rng(seed + 7)
    q = jnp.asarray(rng.normal(size=(len(lengths), H, 1, HD)) * 1.5,
                    jnp.float32)

    out, nsel = ops.paged_decode_attention(q, arena_k, arena_v, bt, lengths,
                                           site, window=window)
    kh, vh = gathered_heads(arena_k, arena_v, bt)
    want, aux = decode_attention_lamp(q, kh, vh, lengths, site,
                                      window=window, reduce=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)
    _assert_counts_match(nsel, aux.n_selected, site)
    if check_dense:
        # pack the block walk into a contiguous dense cache: same values at
        # the same absolute positions -> same reference output
        dense_k = kh[:, :, :int(jnp.max(lengths))]
        dense_v = vh[:, :, :int(jnp.max(lengths))]
        want_d, _ = decode_attention_lamp(q, dense_k, dense_v, lengths, site,
                                          window=window, reduce=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want_d), **TOL)
    return out, nsel


def check_prefill(seed, starts, bs, n_max, site, *, W=8, window=None,
                  block_q=None):
    """Fused windowed prefill vs gather attention_lamp at offsets=starts."""
    starts = list(starts)
    span = [s + W for s in starts]
    arena_k, arena_v, bt = make_paged(seed, span, bs, n_max, span=span)
    st = jnp.asarray(starts, jnp.int32)
    rng = np.random.default_rng(seed + 13)
    q = jnp.asarray(rng.normal(size=(len(starts), H, W, HD)) * 1.5,
                    jnp.float32)

    out, nsel = ops.paged_prefill_attention(q, arena_k, arena_v, bt, st, site,
                                            window=window, block_q=block_q)
    kh, vh = gathered_heads(arena_k, arena_v, bt)
    if site.enabled:
        want, aux = attention_lamp(q, kh, vh, site, causal=True,
                                   window=window, offset=st, reduce=False)
        _assert_counts_match(nsel, aux.n_selected, site)
    else:
        want = attention_reference(q, kh, vh, causal=True, window=window,
                                   offset=st)
        np.testing.assert_array_equal(np.asarray(nsel), 0.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)
    return out, nsel


# ------------------------------------------------------- differential grid

@pytest.mark.parametrize("site_name", sorted(SITES))
@pytest.mark.parametrize("bs,lengths", [
    (4, [3, 9, 16]),          # partial, mid-span, full span
    (8, [5, 16, 27]),         # partial block / exact boundary / ragged
])
def test_decode_differential_grid(bs, lengths, site_name):
    check_decode(0, lengths, bs, 4, SITES[site_name],
                 check_dense=site_name == "relaxed-g0")


@pytest.mark.parametrize("site_name", sorted(SITES))
@pytest.mark.parametrize("starts,block_q", [
    ([0, 5, 17], None),       # fresh prompt / mid-block / deep resume
    ([0, 8, 23], 4),          # boundary-aligned resume, tiled queries
])
def test_prefill_differential_grid(starts, block_q, site_name):
    check_prefill(1, starts, 8, 4, SITES[site_name], W=8, block_q=block_q)


@pytest.mark.parametrize("site_name", ["off", "relaxed-g0"])
def test_decode_sliding_window(site_name):
    check_decode(2, [5, 16, 27], 8, 4, SITES[site_name], window=12)


@pytest.mark.parametrize("site_name", ["off", "relaxed-g0"])
def test_prefill_sliding_window(site_name):
    check_prefill(3, [0, 9, 17], 8, 4, SITES[site_name], W=8, window=12,
                  block_q=4)


# --------------------------------------------------- mask/boundary corners

def test_decode_single_block_sequence():
    """A sequence living entirely inside one block (n_max-1 dead blocks)."""
    check_decode(4, [2, 1, 8], 8, 4, SITES["relaxed-g0"], check_dense=True)


def test_decode_length_on_block_boundary():
    check_decode(5, [8, 16, 32], 8, 4, SITES["relaxed-g0"], check_dense=True)


def test_decode_last_partial_block():
    check_decode(6, [9, 17, 31], 8, 4, SITES["strict-g1"], check_dense=True)


def test_decode_skips_fully_masked_trailing_block():
    """Dead table entries point at NaN-poisoned blocks: if the kernel read
    and 'summed them as zeros', 0 * NaN would poison the accumulator. The
    clamped index map + pl.when guard must keep the output clean."""
    bs, n_max = 8, 4
    lengths = [5, 16, 9]
    arena_k, arena_v, bt = make_paged(7, lengths, bs, n_max)
    # point every dead table slot at a real-but-poisoned block
    poison = arena_k.shape[0] - 1
    bt = np.asarray(bt).copy()
    for r, L in enumerate(lengths):
        bt[r, -(-L // bs):] = poison
    bt = jnp.asarray(bt)
    arena_k = arena_k.at[poison].set(jnp.nan)
    arena_v = arena_v.at[poison].set(jnp.nan)
    lengths = jnp.asarray(lengths, jnp.int32)
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(size=(3, H, 1, HD)), jnp.float32)
    out, nsel = ops.paged_decode_attention(q, arena_k, arena_v, bt, lengths,
                                           SITES["relaxed-g0"])
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(np.asarray(nsel)).all()
    # and it still equals the clean gather reference over live blocks only
    clean_k = arena_k.at[poison].set(0.0)
    clean_v = arena_v.at[poison].set(0.0)
    kh, vh = gathered_heads(clean_k, clean_v, bt)
    want, _ = decode_attention_lamp(q, kh, vh, lengths, SITES["relaxed-g0"],
                                    reduce=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)


def test_prefill_skips_blocks_above_causal_bound():
    """Blocks past a q-tile's causal horizon are dead for that tile; poison
    the final block and give every row a window that never reaches it."""
    bs, n_max, W = 8, 4, 8
    starts = [0, 4, 9]
    span = [s + W for s in starts]                  # spans end inside blk 0-2
    arena_k, arena_v, bt = make_paged(9, span, bs, n_max, span=span)
    poison = arena_k.shape[0] - 1
    bt = np.asarray(bt).copy()
    for r, s in enumerate(span):
        bt[r, -(-s // bs):] = poison                # dead tail entries
    bt = jnp.asarray(bt)
    arena_k = arena_k.at[poison].set(jnp.nan)
    arena_v = arena_v.at[poison].set(jnp.nan)
    st = jnp.asarray(starts, jnp.int32)
    rng = np.random.default_rng(10)
    q = jnp.asarray(rng.normal(size=(3, H, W, HD)), jnp.float32)
    out, nsel = ops.paged_prefill_attention(q, arena_k, arena_v, bt, st,
                                            SITES["relaxed-g0"], block_q=4)
    assert np.isfinite(np.asarray(out)).all()
    clean_k = arena_k.at[poison].set(0.0)
    clean_v = arena_v.at[poison].set(0.0)
    kh, vh = gathered_heads(clean_k, clean_v, bt)
    want, _ = attention_lamp(q, kh, vh, SITES["relaxed-g0"], causal=True,
                             offset=st, reduce=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)


def test_supports_site_gate():
    assert supports_site(LampSite(enabled=False, rule="random"))
    assert not supports_site(LampSite(enabled=True, rule="random"))
    for name in SITES:
        assert supports_site(SITES[name])


def test_decode_kv_bytes_model():
    """The traffic model the benchmarks report: fused < gather whenever any
    row is shorter than the full span, and never more than gather + the
    look-ahead K re-read."""
    g, f = decode_kv_bytes([5, 16, 27], n_max=4, block_size=8,
                           bytes_per_token=64, lamp=True)
    assert f < g
    g2, f2 = decode_kv_bytes([32, 32], n_max=4, block_size=8,
                             bytes_per_token=64, lamp=False)
    assert f2 == g2          # full spans, no look-ahead pass: traffic parity
    _, f3 = decode_kv_bytes([32, 32], n_max=4, block_size=8,
                            bytes_per_token=64, lamp=True)
    assert f3 == g2 * 3 // 2  # + one K stream for the smax pass


# ------------------------------------------------------------ fuzz harness

def _fuzz_decode_case(seed, lengths):
    check_decode(seed, list(lengths), 4, 4, SITES["relaxed-g0"])


def _fuzz_prefill_case(seed, starts):
    check_prefill(seed, list(starts), 4, 4, SITES["relaxed-g0"], W=4)


def test_decode_seeded_fuzz_walk():
    """Non-hypothesis fallback: a seeded walk over random block tables,
    ragged lengths, and window offsets (same ops as the hypothesis case)."""
    rng = np.random.default_rng(42)
    for _ in range(12):
        _fuzz_decode_case(int(rng.integers(1 << 16)),
                          rng.integers(1, 17, size=3))
        _fuzz_prefill_case(int(rng.integers(1 << 16)),
                           rng.integers(0, 13, size=3))


try:
    import hypothesis
    from hypothesis import given, strategies as st

    @given(seed=st.integers(0, 2 ** 16 - 1),
           lengths=st.lists(st.integers(1, 16), min_size=3, max_size=3))
    def test_decode_hypothesis_fuzz(seed, lengths):
        _fuzz_decode_case(seed, lengths)

    @given(seed=st.integers(0, 2 ** 16 - 1),
           starts=st.lists(st.integers(0, 12), min_size=3, max_size=3))
    def test_prefill_hypothesis_fuzz(seed, starts):
        _fuzz_prefill_case(seed, starts)

    @pytest.mark.slow
    @hypothesis.settings(max_examples=200, deadline=None, derandomize=False,
                         print_blob=True)
    @given(seed=st.integers(0, 2 ** 20 - 1),
           lengths=st.lists(st.integers(1, 32), min_size=2, max_size=4),
           site_name=st.sampled_from(sorted(SITES)),
           window=st.sampled_from([None, 8, 20]))
    def test_decode_deep_fuzz(seed, lengths, site_name, window):
        """Opt-in random deep fuzz (-m slow): bigger spans, every rule,
        sliding windows."""
        # pad the batch so the jit cache stays bounded across examples
        lengths = (lengths + [1, 1, 1, 1])[:4]
        check_decode(seed, lengths, 8, 4, SITES[site_name], window=window)
except ImportError:  # pragma: no cover - hypothesis is optional
    pass
