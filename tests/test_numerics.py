"""Unit + property tests for PS(mu) rounding (paper Sec 4.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.numerics import (
    round_to_mantissa, round_to_mantissa_stochastic, unit_roundoff,
    effective_mantissa_bits, is_representable)

finite_f32 = st.floats(width=32, allow_nan=False, allow_infinity=False)


def test_ps7_equals_bf16():
    """PS(7) == bfloat16 under RNE (paper Sec 4.1)."""
    x = np.random.default_rng(0).normal(size=2048).astype(np.float32)
    x = np.concatenate([x, x * 1e30, x * 1e-30, [0.0, -0.0]])
    got = np.asarray(round_to_mantissa(jnp.asarray(x), 7))
    want = np.asarray(jnp.asarray(x).astype(jnp.bfloat16).astype(jnp.float32))
    np.testing.assert_array_equal(got, want)


def test_ps23_is_identity():
    x = np.random.default_rng(1).normal(size=512).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(round_to_mantissa(jnp.asarray(x), 23)), x)


def test_special_values_pass_through():
    x = jnp.array([np.inf, -np.inf, np.nan], jnp.float32)
    for mu in (1, 7, 15):
        r = round_to_mantissa(x, mu)
        assert np.isposinf(r[0]) and np.isneginf(r[1]) and np.isnan(r[2])


@pytest.mark.parametrize("mu", [1, 4, 7, 10, 16, 22])
@given(x=finite_f32)
@settings(max_examples=200, deadline=None)
def test_rne_properties(mu, x):
    """RNE invariants: idempotent, magnitude error <= half-ulp, sign-safe,
    monotone grid membership."""
    v = jnp.float32(x)
    r = round_to_mantissa(v, mu)
    # idempotence
    assert round_to_mantissa(r, mu) == r
    # representable values are fixed points
    assert bool(is_representable(r, mu)) or not np.isfinite(float(r))
    if np.isfinite(float(r)) and x != 0.0:
        # relative error bounded by the unit round-off (normal range)
        if abs(x) > 2e-38:
            rel = abs(float(r) - x) / abs(x)
            assert rel <= unit_roundoff(mu) * (1 + 1e-6)
        # sign preserved
        assert np.sign(float(r)) in (0.0, np.sign(x))


@given(x=finite_f32, mu=st.integers(1, 22))
@settings(max_examples=200, deadline=None)
def test_rne_nearest(x, mu):
    """RNE result is one of the two bracketing grid values, and the nearer
    one (or tie)."""
    v = jnp.float32(x)
    r = float(round_to_mantissa(v, mu))
    if not np.isfinite(r):
        return
    shift = 23 - mu
    bits = np.asarray(v).view(np.uint32)
    lo = np.uint32(bits & ~np.uint32((1 << shift) - 1))
    hi = np.uint32(lo + (1 << shift))
    lo_f = lo.view(np.float32) if True else None
    lo_f = np.array([lo], np.uint32).view(np.float32)[0]
    hi_f = np.array([hi], np.uint32).view(np.float32)[0]
    assert r in (float(lo_f), float(hi_f))
    if np.isfinite(hi_f):
        d_lo, d_hi = abs(x - float(lo_f)), abs(float(hi_f) - x)
        if r == float(lo_f):
            assert d_lo <= d_hi + abs(x) * 1e-12
        else:
            assert d_hi <= d_lo + abs(x) * 1e-12


def test_stochastic_rounding_unbiased():
    """SR mean converges to x (the defining property)."""
    x = jnp.full((4096,), 1.0 + 2 ** -9, jnp.float32)  # halfway in PS(8)... use PS(6)
    mu = 6
    r = round_to_mantissa_stochastic(x, mu, jax.random.PRNGKey(0))
    grid = {float(v) for v in np.unique(np.asarray(r))}
    assert len(grid) <= 2
    mean = float(jnp.mean(r))
    assert abs(mean - float(x[0])) < unit_roundoff(mu) * 0.2


def test_effective_mantissa_footnote3():
    """Paper footnote 3: 1*7 + 0.083*23 = 8.909."""
    assert abs(effective_mantissa_bits(7, 0.083) - 8.909) < 1e-9
