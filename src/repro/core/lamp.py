"""LAMP selection rules and exact condition-number formulas.

Implements the paper's closed-form solutions of the LAMP problem (5) for the
elementary transformer nonlinearities:

  * softmax, l1-normwise objective  -> threshold rule (8)            [Prop 3.3]
  * softmax, relaxed relative rule  -> rule (9), FlashAttention-safe [Sec 4.4]
  * softmax, length-normalized (9)  -> tau * sqrt(n_ref / n)         [App C.5]
  * RMS layer norm, componentwise   -> greedy prefix of largest y_i^2 [Prop 3.2]
  * entrywise activations           -> diagonal threshold             [Sec 3.1]

and the exact kappa evaluators used by the property tests:

  * kappa_c for RMSNorm  (Prop 3.1)
  * kappa_1 for softmax  (Prop 3.3)
  * kappa_c for softmax  (App B explicit formula)

Conventions: selections operate on the last axis; `where` masks (e.g. the
causal mask) restrict both the softmax domain and the selectable set. All
rules return boolean masks `q` (True = recompute in high precision).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

_NEG_INF = float("-inf")


def _masked(y: jnp.ndarray, where: Optional[jnp.ndarray], fill: float) -> jnp.ndarray:
    if where is None:
        return y
    return jnp.where(where, y, fill)


def masked_softmax(y: jnp.ndarray, where: Optional[jnp.ndarray] = None,
                   axis: int = -1) -> jnp.ndarray:
    """Numerically-stable softmax restricted to `where` (else prob 0)."""
    y = _masked(y, where, _NEG_INF)
    m = jnp.max(y, axis=axis, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)  # all-masked rows
    e = jnp.exp(y - m)
    if where is not None:
        e = jnp.where(where, e, 0.0)
    s = jnp.sum(e, axis=axis, keepdims=True)
    return e / jnp.maximum(s, jnp.finfo(y.dtype).tiny)


# ---------------------------------------------------------------------------
# Softmax rules
# ---------------------------------------------------------------------------

def select_softmax_strict(y: jnp.ndarray, tau: float,
                          where: Optional[jnp.ndarray] = None,
                          z: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Paper rule (8): q_j = 1  iff  2 z_j (1 - z_j) |y_j| > tau.

    This is the optimal solution of the l1-normwise LAMP problem for softmax
    (Prop 3.3). `y` are the (low-precision-computed) softmax inputs; `z` may
    be supplied to reuse a softmax already computed by the caller.
    """
    if z is None:
        z = masked_softmax(y, where)
    crit = 2.0 * z * (1.0 - z) * jnp.abs(y)
    mask = crit > tau
    if where is not None:
        mask = mask & where
    return mask


def select_softmax_relaxed(y: jnp.ndarray, tau: float,
                           where: Optional[jnp.ndarray] = None,
                           axis: int = -1) -> jnp.ndarray:
    """Paper rule (9): q_j = 1  iff  |y_j| e^{y_j} > tau * max_i |y_i| e^{y_i}.

    Computed in log space for range safety:
        s_j = y_j + log|y_j|   (s_j = -inf at y_j = 0, which is correct:
                                the criterion value |0|*e^0 = 0 never selects)
        q_j = s_j > log(tau) + max_i s_i
    Independent of the softmax normalizer -> online-softmax compatible.

    `tau` may be a traced jax scalar (the serving policy controller threads
    per-layer thresholds through the jitted steps); the value range is then
    the caller's responsibility. The general log-space comparison reproduces
    the static tau == 0 branch exactly: log(0) = -inf makes the threshold
    -inf, selecting every finite s (every nonzero in-domain product).
    """
    static_tau = isinstance(tau, (int, float))
    if static_tau and not (0.0 <= tau < 1.0):
        raise ValueError(f"relaxed LAMP needs 0 <= tau < 1, got {tau}")
    s = y + jnp.log(jnp.abs(y))  # -inf at y == 0 by IEEE semantics
    s = _masked(s, where, _NEG_INF)
    smax = jnp.max(s, axis=axis, keepdims=True)
    if static_tau and tau == 0.0:
        mask = jnp.isfinite(s)  # select everything nonzero in-domain
    else:
        mask = s > (jnp.log(tau) + smax)
    if where is not None:
        mask = mask & where
    return mask


def select_softmax_relaxed_ln(y: jnp.ndarray, tau: float, row_lengths: jnp.ndarray,
                              n_ref: int = 1024,
                              where: Optional[jnp.ndarray] = None,
                              axis: int = -1) -> jnp.ndarray:
    """Length-normalized relaxed rule (App C.5): tau_row = tau * sqrt(n_ref / n).

    `row_lengths` broadcasts against y with the last axis removed, giving the
    valid length n of each softmax row (for causal row i, n = i + 1).
    """
    s = y + jnp.log(jnp.abs(y))
    s = _masked(s, where, _NEG_INF)
    smax = jnp.max(s, axis=axis, keepdims=True)
    tau_row = tau * jnp.sqrt(n_ref / jnp.maximum(row_lengths, 1).astype(jnp.float32))
    tau_row = jnp.minimum(tau_row, 1.0 - 1e-6)[..., None]
    mask = s > (jnp.log(tau_row) + smax)
    if where is not None:
        mask = mask & where
    return mask


# ---------------------------------------------------------------------------
# RMSNorm rule (Props 3.1 / 3.2)
# ---------------------------------------------------------------------------

def select_rmsnorm(y: jnp.ndarray, tau: float, axis: int = -1) -> jnp.ndarray:
    """Greedy almost-optimal solution of componentwise LAMP for RMSNorm.

    Prop 3.2: sort entries by descending square, pick the smallest prefix s
    with  sum_{i<=s} y_i^2 + 2 y_min^2 >= (2 - tau) ||y||^2, select that
    prefix. Returns an exact-size mask (rank-based, tie-safe).
    """
    y = jnp.moveaxis(jnp.asarray(y, jnp.float32), axis, -1)
    y2 = y * y
    total = jnp.sum(y2, axis=-1, keepdims=True)
    ymin2 = jnp.min(y2, axis=-1, keepdims=True)
    order = jnp.argsort(-y2, axis=-1)
    sorted_desc = jnp.take_along_axis(y2, order, axis=-1)
    csum = jnp.cumsum(sorted_desc, axis=-1)
    need = (2.0 - tau) * total - 2.0 * ymin2
    # smallest s >= 0 with prefix_sum(s) >= need, where prefix_sum(0) = 0:
    # s = [need > 0] + #(csum < need), capped at n (select-all fallback).
    s = jnp.sum(csum < need, axis=-1, keepdims=True) + (need > 0)
    n = y.shape[-1]
    s = jnp.minimum(s, n)
    ranks = jnp.argsort(order, axis=-1)  # rank of each entry in the sorted order
    mask = ranks < s
    return jnp.moveaxis(mask, -1, axis)


def kappa_c_rmsnorm(y: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Exact kappa_c for RMSNorm (Prop 3.1), q != all-ones. 1-D inputs."""
    y = jnp.asarray(y, jnp.float32)
    q = jnp.asarray(q, bool)
    y2 = y * y
    total = jnp.sum(y2)
    n_out = jnp.sum(~q)
    min_out = jnp.min(jnp.where(~q, y2, jnp.inf))
    sum_in = jnp.sum(jnp.where(q, y2, 0.0))
    general = 2.0 * (1.0 - min_out / total) - sum_in / total
    single = jnp.maximum(min_out / total, 1.0 - min_out / total)
    return jnp.where(n_out == 1, single, general)


# ---------------------------------------------------------------------------
# Softmax kappa evaluators (for tests / analysis)
# ---------------------------------------------------------------------------

def kappa_1_softmax(y: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Exact l1-normwise kappa for softmax (Prop 3.3): 2 max_{j not in Omega}
    z_j (1 - z_j) |y_j|. 1-D inputs; q != all-ones."""
    z = jax.nn.softmax(jnp.asarray(y, jnp.float32))
    crit = 2.0 * z * (1.0 - z) * jnp.abs(y)
    return jnp.max(jnp.where(jnp.asarray(q, bool), -jnp.inf, crit))


def kappa_c_softmax(y: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Exact componentwise kappa for softmax (App B):
    sum_{j not in Omega} z_j |y_j| + max_{i not in Omega} (1 - 2 z_i) |y_i|."""
    y = jnp.asarray(y, jnp.float32)
    q = jnp.asarray(q, bool)
    z = jax.nn.softmax(y)
    u = z * jnp.abs(y)
    v = (1.0 - 2.0 * z) * jnp.abs(y)
    return jnp.sum(jnp.where(q, 0.0, u)) + jnp.max(jnp.where(q, -jnp.inf, v))


# ---------------------------------------------------------------------------
# Entrywise activation rule (Sec 3.1)
# ---------------------------------------------------------------------------

def select_activation(y: jnp.ndarray, tau: float,
                      phi: Callable[[jnp.ndarray], jnp.ndarray],
                      dphi: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
                      eps: float = 1e-30) -> jnp.ndarray:
    """Sec 3.1: M is diagonal with entries phi'(y) y / phi(y); select where
    the magnitude exceeds tau. `dphi` defaults to jax.grad of phi."""
    y = jnp.asarray(y, jnp.float32)
    if dphi is None:
        dphi = jax.vmap(jax.grad(lambda t: phi(t).sum() if phi(t).ndim else phi(t)))
        flat = y.reshape(-1)
        d = dphi(flat).reshape(y.shape)
    else:
        d = dphi(y)
    f = phi(y)
    crit = jnp.abs(d * y) / jnp.maximum(jnp.abs(f), eps)
    return crit > tau


def gelu_criterion(y: jnp.ndarray) -> jnp.ndarray:
    """|gelu'(y) * y / gelu(y)| computed stably (exact erf-based GELU)."""
    y = jnp.asarray(y, jnp.float32)
    phi = jax.nn.gelu(y, approximate=False)
    d = jax.vmap(jax.grad(lambda t: jax.nn.gelu(t, approximate=False)))(y.reshape(-1)).reshape(y.shape)
    return jnp.abs(d * y) / jnp.maximum(jnp.abs(phi), 1e-30)


# ---------------------------------------------------------------------------
# Bookkeeping helpers
# ---------------------------------------------------------------------------

def recompute_rate(mask: jnp.ndarray, where: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Fraction of selectable entries flagged for recompute (paper's metric:
    divided by the number of inner products inside the causal mask)."""
    if where is None:
        return jnp.mean(mask.astype(jnp.float32))
    sel = jnp.sum((mask & where).astype(jnp.float32))
    tot = jnp.maximum(jnp.sum(where.astype(jnp.float32)), 1.0)
    return sel / tot
