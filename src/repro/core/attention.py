"""LAMP attention: the paper's proof-of-concept composition (Sec 3.3, 4).

Pipeline (per head):
    y_low = dot_ps(q * scale, k^T, mu)        # KQ products, PS(mu) accumulation
    mask  = LAMP rule (8) / (9) / LN-(9)      # look-ahead selection
    y     = where(mask, fp32 q k^T, y_low)    # selective recompute
    z     = softmax(y);  out = z @ v          # everything else in FP32 (paper)

Variants:
  * attention_reference     -- uniform FP32 (the paper's reference model)
  * attention_lamp          -- materialized logits (the paper's "strict"
                               benchmark setting; any rule)
  * chunked_attention       -- online-softmax over KV blocks, O(T) memory
  * chunked_attention_lamp  -- relaxed-LAMP fused with online softmax
                               (two-pass exact threshold, or one-pass
                               conservative running threshold). This is the
                               paper's stated future-work direction (Sec 4.4).
  * decode_attention_lamp   -- single-query decode step against a KV cache.

Shapes: q (B, H, Tq, D), k (B, H, Tk, D), v (B, H, Tk, D). GQA head
repetition happens in the model layer, not here.
"""

from __future__ import annotations

import functools
import os
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


def baseline_mode() -> bool:
    """REPRO_BASELINE=1 re-enables the pre-optimization code paths so the
    EXPERIMENTS Sec Perf before/after measurements stay reproducible."""
    return os.environ.get("REPRO_BASELINE") == "1"

from . import lamp as L
from .mixed_matmul import dot_ps
from .policy import LampSite

_NEG = -1e30


class AttnAux(NamedTuple):
    recompute_rate: jnp.ndarray   # scalar: selected / valid KQ products
    n_selected: jnp.ndarray       # scalar count
    n_valid: jnp.ndarray          # scalar count


def _causal_where(tq: int, tk: int, offset=0,
                  window: Optional[int] = None) -> jnp.ndarray:
    """Validity mask. `offset` = absolute position of query row 0 minus key
    row 0 (for caches / blocks); a scalar, or a (B,) array for batches whose
    rows sit at different absolute positions (partial prefill windows) --
    then the mask is (B, 1, tq, tk). `window` = sliding-window size."""
    qi = jnp.arange(tq)[:, None] + _as_offset(offset)
    kj = jnp.arange(tk)[None, :]
    ok = kj <= qi
    if window is not None:
        ok &= kj > qi - window
    return ok


def _as_offset(offset):
    """Scalar offsets broadcast as-is; (B,) array offsets gain query/key and
    batch/head dims so downstream masks become per-row."""
    if isinstance(offset, (int, float)):
        return offset
    offset = jnp.asarray(offset)
    if offset.ndim == 0:
        return offset
    return offset[:, None, None, None]  # (B,1,1,1) against (tq,1)/(1,tk)


def _select(y: jnp.ndarray, site: LampSite, where, row_lengths=None,
            tau=None) -> jnp.ndarray:
    """`tau` overrides `site.tau`; it may be a traced jax scalar (the policy
    controller's per-layer threshold), in which case it stays out of the jit
    cache key and can move every step without a recompile."""
    if not site.enabled or site.rule == "none":
        return jnp.zeros(y.shape, bool)
    tau = site.tau if tau is None else tau
    if site.rule == "strict":
        return L.select_softmax_strict(y, tau, where=where)
    if site.rule == "relaxed":
        return L.select_softmax_relaxed(y, tau, where=where)
    if site.rule == "relaxed_ln":
        if row_lengths is None:
            raise ValueError("relaxed_ln needs row_lengths")
        return L.select_softmax_relaxed_ln(y, tau, row_lengths,
                                           n_ref=site.n_ref, where=where)
    if site.rule == "random":  # control arm (paper App C.4): caller resamples
        raise ValueError("random rule is handled by attention_lamp(random_key=...)")
    raise ValueError(f"unknown LAMP rule {site.rule!r}")


def attention_reference(q, k, v, *, causal: bool = True, scale: Optional[float] = None,
                        window: Optional[int] = None, offset=0) -> jnp.ndarray:
    """Uniform FP32 attention (paper's reference)."""
    q, k, v = (jnp.asarray(t, jnp.float32) for t in (q, k, v))
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    y = jnp.einsum("bhqd,bhkd->bhqk", q * scale, k)
    where = _causal_where(q.shape[2], k.shape[2], offset, window) if causal else None
    z = L.masked_softmax(y, where)
    return jnp.einsum("bhqk,bhkd->bhqd", z, v)


def attention_lamp(q, k, v, site: LampSite, *, causal: bool = True,
                   scale: Optional[float] = None, window: Optional[int] = None,
                   offset=0, random_key: Optional[jax.Array] = None,
                   reduce: bool = True, tau=None) -> Tuple[jnp.ndarray, AttnAux]:
    """Materialized-softmax LAMP attention (the paper's benchmark setting).

    With `random_key`, runs the App C.4 control: the *number* of recomputed
    products matches the LAMP rule, but positions are chosen at random.

    `tau` (optional, possibly traced) overrides `site.tau` -- the serving
    policy controller's live per-layer threshold.

    With `reduce=False`, `aux.n_selected` / `aux.n_valid` are (B, Tq) arrays
    (summed over heads and keys) instead of scalars, so callers serving
    multiple requests in one batch can attribute recompute work per row.

    `offset` may be a (B,) array: row b's queries sit at absolute positions
    offset[b] .. offset[b] + Tq - 1 against keys at 0 .. Tk - 1 (the partial
    prefill window of the paged serving path).
    """
    q, k, v = (jnp.asarray(t, jnp.float32) for t in (q, k, v))
    B, H, Tq, D = q.shape
    Tk = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    where = _causal_where(Tq, Tk, offset, window) if causal else None
    wb = None if where is None else jnp.broadcast_to(where, (B, H, Tq, Tk))

    kt = jnp.swapaxes(k, -1, -2)
    y_low = dot_ps(q * scale, kt, site.mu, granularity=site.granularity)

    if causal:
        off_row = offset if isinstance(offset, (int, float)) \
            else jnp.asarray(offset)[:, None]                     # (B, 1)
        row_lengths = jnp.clip(jnp.arange(Tq) + off_row + 1, 0,
                               window if window is not None else Tk)
        row_lengths = jnp.broadcast_to(
            row_lengths[..., None, :] if row_lengths.ndim == 2 else row_lengths,
            (B, H, Tq))
    else:
        row_lengths = jnp.full((B, H, Tq), Tk)

    mask = _select(y_low, site, wb, row_lengths, tau=tau)
    if random_key is not None:
        # Keep per-row counts, randomize positions among valid slots.
        n_sel = jnp.sum(mask, axis=-1, keepdims=True)
        scores = jax.random.uniform(random_key, y_low.shape)
        scores = jnp.where(wb, scores, -1.0) if wb is not None else scores
        order = jnp.argsort(-scores, axis=-1)
        ranks = jnp.argsort(order, axis=-1)
        mask = ranks < n_sel
        if wb is not None:
            mask &= wb

    y_exact = jnp.einsum("bhqd,bhkd->bhqk", q * scale, k)
    y = jnp.where(mask, y_exact, y_low)
    z = L.masked_softmax(y, wb)
    out = jnp.einsum("bhqk,bhkd->bhqd", z, v)

    if reduce:
        n_sel = jnp.sum(mask.astype(jnp.float32))
        n_valid = (jnp.sum(wb.astype(jnp.float32)) if wb is not None
                   else jnp.asarray(float(mask.size), jnp.float32))
        rate = n_sel / jnp.maximum(n_valid, 1)
    else:
        n_sel = jnp.sum(mask.astype(jnp.float32), axis=(1, 3))
        n_valid = (jnp.sum(wb.astype(jnp.float32), axis=(1, 3)) if wb is not None
                   else jnp.full((B, Tq), float(H * Tk), jnp.float32))
        rate = jnp.sum(n_sel) / jnp.maximum(jnp.sum(n_valid), 1)
    aux = AttnAux(rate, n_sel, n_valid)
    return out, aux


# ---------------------------------------------------------------------------
# Online-softmax (FlashAttention-style) variants
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, *, causal: bool = True, scale: Optional[float] = None,
                      block: int = 512, window: Optional[int] = None,
                      offset: int = 0, q_tiles: int = 8) -> jnp.ndarray:
    """O(T) memory online-softmax attention: scan over KV blocks.
    Causal q-tiling as in chunked_attention_lamp (skip masked KV blocks)."""
    q, k, v = (jnp.asarray(t, jnp.float32) for t in (q, k, v))
    B, H, Tq, D = q.shape
    Tk = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    if causal and q_tiles > 1 and Tq % q_tiles == 0 and Tq // q_tiles >= block:
        tq = Tq // q_tiles
        outs = []
        for t in range(q_tiles):
            q0 = t * tq
            hi = min(Tk, q0 + tq + max(offset, 0))
            kv_hi = min(Tk, -(-hi // block) * block)
            lo = 0
            if window is not None:
                lo = max(0, (q0 + offset - window) // block * block)
            outs.append(chunked_attention(
                q[:, :, q0:q0 + tq], k[:, :, lo:kv_hi], v[:, :, lo:kv_hi],
                causal=True, scale=scale, block=block, window=window,
                offset=offset + q0 - lo, q_tiles=1))
        return jnp.concatenate(outs, axis=2)
    nb = -(-Tk // block)
    pad = nb * block - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = jnp.moveaxis(k.reshape(B, H, nb, block, D), 2, 0)
    vb = jnp.moveaxis(v.reshape(B, H, nb, block, D), 2, 0)
    qs = q * scale
    qi = jnp.arange(Tq)[:, None] + offset

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, bi = xs
        y = jnp.einsum("bhqd,bhkd->bhqk", qs, kc)
        kj = bi * block + jnp.arange(block)[None, :]
        ok = kj < Tk
        if causal:
            ok = ok & (kj <= qi)
            if window is not None:
                ok = ok & (kj > qi - window)
        y = jnp.where(ok, y, _NEG)
        m_new = jnp.maximum(m, jnp.max(y, axis=-1))
        p = jnp.exp(y - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vc)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Tq), _NEG)
    l0 = jnp.zeros((B, H, Tq))
    a0 = jnp.zeros((B, H, Tq, D))
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, jnp.arange(nb)))
    return acc / jnp.maximum(l, jnp.finfo(jnp.float32).tiny)[..., None]


def chunked_attention_lamp(q, k, v, site: LampSite, *, causal: bool = True,
                           scale: Optional[float] = None, block: int = 512,
                           window: Optional[int] = None, offset: int = 0,
                           onepass: bool = False, q_tiles: int = 8,
                           ) -> Tuple[jnp.ndarray, AttnAux]:
    """Relaxed-LAMP (rule 9) fused with online softmax (paper Sec 4.4 future
    work). The relative threshold needs max_j |y_j| e^{y_j} per row:

      two-pass (default): pass 1 scans KV blocks accumulating the exact row
      max of s = y + log|y|; pass 2 selects, recomputes, and accumulates the
      online softmax. Exactly matches rule (9).

      one-pass: thresholds each block against the *running* max of s. Since
      the running max only grows, early blocks can only over-select -- a
      conservative relaxation (recompute rate >= two-pass, accuracy >=).

    Causal q-tiling (EXPERIMENTS Sec Perf, hillclimb C): the query axis is
    cut into `q_tiles` tiles; each tile scans only the KV blocks inside its
    causal range, skipping the fully-masked upper-triangle work (~2x at
    long context). Exact -- masked blocks contribute nothing.
    """
    if site.enabled and site.rule not in ("relaxed", "none"):
        raise ValueError("online LAMP requires the relaxed rule (paper Sec 4.4)")
    q, k, v = (jnp.asarray(t, jnp.float32) for t in (q, k, v))
    B, H, Tq, D = q.shape
    Tk = k.shape[2]
    scale = scale if scale is not None else D ** -0.5

    # ---- causal q-tiling wrapper --------------------------------------
    if causal and q_tiles > 1 and Tq % q_tiles == 0 and Tq // q_tiles >= block:
        tq = Tq // q_tiles
        outs, nsels, valids = [], [], []
        for t in range(q_tiles):
            q0 = t * tq
            hi = min(Tk, q0 + tq + max(offset, 0))
            kv_hi = min(Tk, -(-hi // block) * block)
            lo = 0
            if window is not None:
                lo = max(0, (q0 + offset - window) // block * block)
            o, aux = chunked_attention_lamp(
                q[:, :, q0:q0 + tq], k[:, :, lo:kv_hi], v[:, :, lo:kv_hi],
                site, causal=True, scale=scale, block=block, window=window,
                offset=offset + q0 - lo, onepass=onepass, q_tiles=1)
            outs.append(o)
            nsels.append(aux.n_selected)
            valids.append(aux.n_valid)
        out = jnp.concatenate(outs, axis=2)
        nsel = sum(nsels)
        valid = sum(valids)
        return out, AttnAux(nsel / jnp.maximum(valid, 1), nsel, valid)

    nb = -(-Tk // block)
    pad = nb * block - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = jnp.moveaxis(k.reshape(B, H, nb, block, D), 2, 0)
    vb = jnp.moveaxis(v.reshape(B, H, nb, block, D), 2, 0)
    qs = q * scale
    qi = jnp.arange(Tq)[:, None] + offset
    log_tau = jnp.log(jnp.maximum(site.tau, 1e-30)) if site.enabled else 0.0

    cast_only = site.enabled and site.granularity == 0 and not baseline_mode()

    def block_logits(kc, bi):
        """Returns (y_low, y_exact_or_None, ok). In the cast-only tier
        (granularity=0, the TPU deployment model) the exact product is the
        single MXU pass and y_low = round(y_exact): ONE matmul, not two
        (EXPERIMENTS Sec Perf, hillclimb C)."""
        if cast_only:
            y_exact = jnp.einsum("bhqd,bhkd->bhqk", qs, kc)
            from repro.core.numerics import round_to_mantissa
            y = round_to_mantissa(y_exact, site.mu)
        elif site.enabled:
            ktc = jnp.swapaxes(kc, -1, -2)
            y = dot_ps(qs, ktc, site.mu, granularity=site.granularity)
            y_exact = None
        else:
            y = jnp.einsum("bhqd,bhkd->bhqk", qs, kc)
            y_exact = None
        kj = bi * block + jnp.arange(block)[None, :]
        ok = kj < Tk
        if causal:
            ok = ok & (kj <= qi)
            if window is not None:
                ok = ok & (kj > qi - window)
        return y, y_exact, ok

    if site.enabled and not onepass:
        def smax_body(smax, xs):
            kc, bi = xs
            y, _, ok = block_logits(kc, bi)
            s = jnp.where(ok, y + jnp.log(jnp.abs(y)), _NEG)
            return jnp.maximum(smax, jnp.max(s, axis=-1)), None
        smax_exact, _ = jax.lax.scan(
            smax_body, jnp.full((B, H, Tq), _NEG), (kb, jnp.arange(nb)))
    else:
        smax_exact = None

    def body(carry, xs):
        m, l, acc, smax_run, nsel = carry
        kc, vc, bi = xs
        y, y_exact, ok = block_logits(kc, bi)
        if site.enabled:
            s = jnp.where(ok, y + jnp.log(jnp.abs(y)), _NEG)
            if onepass:
                smax_run = jnp.maximum(smax_run, jnp.max(s, axis=-1))
                thr = smax_run
            else:
                thr = smax_exact
            sel = ok & (s > log_tau + thr[..., None])
            if y_exact is None:
                y_exact = jnp.einsum("bhqd,bhkd->bhqk", qs, kc)
            y = jnp.where(sel, y_exact, y)
            nsel = nsel + jnp.sum(sel)
        y = jnp.where(ok, y, _NEG)
        m_new = jnp.maximum(m, jnp.max(y, axis=-1))
        p = jnp.exp(y - m_new[..., None])
        p = jnp.where(ok, p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vc)
        return (m_new, l, acc, smax_run, nsel), None

    m0 = jnp.full((B, H, Tq), _NEG)
    l0 = jnp.zeros((B, H, Tq))
    a0 = jnp.zeros((B, H, Tq, D))
    s0 = jnp.full((B, H, Tq), _NEG)
    (m, l, acc, _, nsel), _ = jax.lax.scan(
        body, (m0, l0, a0, s0, jnp.zeros((), jnp.float32)),
        (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(l, jnp.finfo(jnp.float32).tiny)[..., None]
    if causal:
        valid = jnp.sum(jnp.clip(qi + 1, 0, window if window else Tk)
                        .astype(jnp.float32)) * B * H
    else:
        valid = jnp.asarray(float(B) * H * Tq * Tk, jnp.float32)
    aux = AttnAux(nsel / jnp.maximum(valid, 1), nsel, valid)
    return out, aux


def decode_attention_lamp(q, k_cache, v_cache, length, site: LampSite,
                          *, scale: Optional[float] = None,
                          window: Optional[int] = None, reduce: bool = True,
                          tau=None) -> Tuple[jnp.ndarray, AttnAux]:
    """Single-token decode: q (B, H, 1, D) against cache (B, H, S, D).

    `length` (B,) = number of valid cache entries per sequence. LAMP rule (9)
    on the single logit row is O(S) -- fully materializable, so decode gets
    the exact relaxed rule at negligible cost.

    With `reduce=False`, aux counts are per-sequence (B,) arrays (summed over
    heads) so the serving engine can report per-request recompute rates.
    `tau` (optional, possibly traced) overrides `site.tau`.
    """
    q = jnp.asarray(q, jnp.float32)
    B, H, Tq, D = q.shape
    S = k_cache.shape[2]
    scale = scale if scale is not None else D ** -0.5
    pos = jnp.arange(S)[None, None, None, :]
    ok = pos < length[:, None, None, None]
    if window is not None:
        ok &= pos > (length[:, None, None, None] - 1 - window)
    kt = jnp.swapaxes(jnp.asarray(k_cache, jnp.float32), -1, -2)
    qs = q * scale
    if site.enabled:
        y_low = dot_ps(qs, kt, site.mu, granularity=site.granularity)
        mask = _select(y_low, site, ok,
                       row_lengths=jnp.broadcast_to(length[:, None, None], (B, H, Tq)),
                       tau=tau)
        y_exact = jnp.matmul(qs, kt)
        y = jnp.where(mask, y_exact, y_low)
    else:
        y = jnp.matmul(qs, kt)
        mask = jnp.zeros(y.shape, bool)
    z = L.masked_softmax(y, ok)
    out = jnp.einsum("bhqk,bhkd->bhqd", z, jnp.asarray(v_cache, jnp.float32))
    if reduce:
        nsel = jnp.sum(mask.astype(jnp.float32))
        n_valid = jnp.sum(ok.astype(jnp.float32)) * H
    else:
        nsel = jnp.sum(mask.astype(jnp.float32), axis=(1, 2, 3))
        n_valid = jnp.sum(ok.astype(jnp.float32), axis=(1, 2, 3)) * H
    rate = jnp.sum(nsel) / jnp.maximum(jnp.sum(n_valid), 1)
    aux = AttnAux(rate, nsel, n_valid)
    return out, aux
