"""Simulated mixed-precision matrix products (paper Sec 4.1).

The paper multiplies matrices with input/output formats
PS(mu_A) x PS(mu_B) -> PS(mu_C) by accumulating inner products as
``round(c + a*b)`` with the scalar multiply-add in FP32 and the rounding to
mu_C mantissa bits after *every* accumulation step.

We provide three simulation tiers (DESIGN.md Sec 5), selected by
``granularity``:

  granularity = 1   per-FMA rounding    c_g ~ k u      (paper-faithful)
  granularity = g   per-subtile rounding c_g ~ (k/g) u (TPU MXU deployment
                    model: FP32 accumulation inside a K-subtile, rounding
                    when the partial sum leaves the systolic array)
  granularity = 0   cast-only: full FP32 accumulation, one final rounding
                    (what today's MXU does when storing to a mu-bit format)

All tiers share the LAMP selection/recompute path: `matmul_lamp` recomputes
selected output entries with exact FP32 accumulation, which is the paper's
"higher precision" refinement of Sec 2.2.2 (c_g = 0 for recomputed entries).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .numerics import round_to_mantissa, round_to_mantissa_stochastic


def _round(c: jnp.ndarray, mu: int, stochastic: bool, key) -> jnp.ndarray:
    if stochastic:
        return round_to_mantissa_stochastic(c, mu, key)
    return round_to_mantissa(c, mu)


@functools.partial(jax.jit, static_argnames=("mu", "granularity", "stochastic"))
def dot_ps(a: jnp.ndarray, b: jnp.ndarray, mu: int, *, granularity: int = 1,
           stochastic: bool = False, key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Batched matmul a @ b with simulated PS(mu) accumulation.

    a: (..., M, K), b: (..., K, N) -> (..., M, N), float32 values lying on the
    PS(mu) grid (except granularity=0 where only storage would be rounded --
    we still apply the final rounding so the result is a PS(mu) value).

    granularity g: the K axis is cut into ceil(K/g) chunks; each chunk is
    accumulated exactly in FP32 and added to the running PS(mu) accumulator,
    which is re-rounded after each chunk. g=1 reproduces the paper's
    per-step ``round(c + a*b)``.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    K = a.shape[-1]
    if b.shape[-2] != K:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    if mu >= 23:
        return jnp.matmul(a, b)
    if granularity == 0 or granularity >= K:
        return _round(jnp.matmul(a, b), mu, stochastic,
                      key if key is not None else jax.random.PRNGKey(0))
    g = int(granularity)
    steps = -(-K // g)  # ceil
    pad = steps * g - K
    if pad:
        a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)])
        b = jnp.pad(b, [(0, 0)] * (b.ndim - 2) + [(0, pad), (0, 0)])
    # (..., M, steps, g) and (..., steps, g, N), scanned over `steps`.
    a_chunks = jnp.moveaxis(a.reshape(*a.shape[:-1], steps, g), -2, 0)
    b_chunks = jnp.moveaxis(b.reshape(*b.shape[:-2], steps, g, b.shape[-1]), -3, 0)

    out_shape = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2]) + (a.shape[-2], b.shape[-1])
    init = jnp.zeros(out_shape, jnp.float32)
    if stochastic:
        if key is None:
            raise ValueError("stochastic dot_ps requires key")
        keys = jax.random.split(key, steps)
    else:
        keys = jnp.zeros((steps, 2), jnp.uint32)

    def body(c, xs):
        ac, bc, k = xs
        c = _round(c + jnp.matmul(ac, bc), mu, stochastic, k)
        return c, None

    out, _ = jax.lax.scan(body, init, (a_chunks, b_chunks, keys))
    return out


def matmul_lamp(a: jnp.ndarray, b: jnp.ndarray, mu: int,
                mask: jnp.ndarray, *, granularity: int = 1,
                y_low: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """LAMP refinement: PS(mu)-accumulated a @ b with the entries flagged in
    `mask` recomputed by exact FP32 accumulation (Sec 2.2.2, c_g = 0).

    `y_low` lets the caller pass an already-computed low-precision product
    (the LAMP workflow computes y_low first, derives `mask` from it via a
    look-ahead rule, then refines).

    Note: the simulation computes the full FP32 product and selects -- this
    is numerically identical to recomputing only the flagged entries (the
    paper's simulation does the same); the Pallas kernel performs the real
    tile-granular selective recompute.
    """
    if y_low is None:
        y_low = dot_ps(a, b, mu, granularity=granularity)
    y_exact = jnp.matmul(jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32))
    return jnp.where(mask, y_exact, y_low)


def dot_ps_error_bound(k: int, mu: int, granularity: int = 1) -> float:
    """First-order worst-case relative error coefficient c_g * u for a
    length-k inner product (Higham 2002): ~ ceil(k/g) * u."""
    from .numerics import unit_roundoff
    g = max(int(granularity), 1) if granularity else k
    return -(-k // g) * unit_roundoff(mu)


def lamp_matmul_softmax(a: jnp.ndarray, b: jnp.ndarray, mu: int, tau: float,
                        *, rule: str = "strict", granularity: int = 1,
                        where: Optional[jnp.ndarray] = None,
                        row_lengths: Optional[jnp.ndarray] = None,
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """End-to-end LAMP evaluation of the composition softmax(a @ b).

    Returns (z, y_adaptive, mask): the softmax probabilities computed from the
    adaptively-refined logits, the refined logits, and the recompute mask.
    This is Algorithm 1 specialized to g = matmul, f = softmax.
    """
    from . import lamp as L
    y_low = dot_ps(a, b, mu, granularity=granularity)
    if rule == "strict":
        mask = L.select_softmax_strict(y_low, tau, where=where)
    elif rule == "relaxed":
        mask = L.select_softmax_relaxed(y_low, tau, where=where)
    elif rule == "relaxed_ln":
        if row_lengths is None:
            raise ValueError("relaxed_ln needs row_lengths")
        mask = L.select_softmax_relaxed_ln(y_low, tau, row_lengths, where=where)
    elif rule == "none":
        mask = jnp.zeros(y_low.shape, bool)
    else:
        raise ValueError(f"unknown rule {rule!r}")
    y = matmul_lamp(a, b, mu, mask, granularity=granularity, y_low=y_low)
    z = L.masked_softmax(y, where)
    return z, y, mask
