"""Bit-exact simulation of the paper's PS(mu) floating-point format.

PS(mu) = sign (1) + exponent (8) + mantissa (mu in 1..23) bits; PS(23) == FP32,
PS(10) == TF32, PS(7) == BF16 (paper Sec. 4.1). We represent PS(mu) values as
FP32 numbers whose trailing (23 - mu) mantissa bits are zero, produced by
round-to-nearest-ties-to-even (RNE) on the FP32 bit pattern -- exactly the
paper's construction.

Also provides stochastic rounding (SR), used by the error-analysis tiers
(c_g ~ sqrt(k) u for SR vs k u for RNE; Connolly-Higham-Mary 2021).

All functions are jit/vmap/scan-safe; `mu` must be a static Python int.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

_EXP_MASK = jnp.uint32(0x7F800000)
_F32_MANT_BITS = 23


def _is_nonfinite(bits: jnp.ndarray) -> jnp.ndarray:
    """True where the FP32 bit pattern is Inf or NaN (exponent all ones)."""
    return (bits & _EXP_MASK) == _EXP_MASK


@functools.partial(jax.jit, static_argnames=("mu",))
def round_to_mantissa(x: jnp.ndarray, mu: int) -> jnp.ndarray:
    """Round FP32 `x` to `mu` mantissa bits with round-to-nearest-ties-to-even.

    Bit-exact: operates on the uint32 bit pattern. Carries out of the mantissa
    propagate into the exponent (correct RNE behaviour, incl. overflow to Inf
    and subnormal -> smallest-normal promotion). Inf/NaN pass through.
    """
    if not isinstance(mu, int):
        raise TypeError(f"mu must be a static int, got {type(mu)}")
    if not 1 <= mu <= 23:
        raise ValueError(f"mu must be in [1, 23], got {mu}")
    x = jnp.asarray(x, jnp.float32)
    if mu == _F32_MANT_BITS:
        return x
    shift = _F32_MANT_BITS - mu
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    keep_mask = jnp.uint32(~((1 << shift) - 1) & 0xFFFFFFFF)
    rem = bits & jnp.uint32((1 << shift) - 1)
    half = jnp.uint32(1 << (shift - 1))
    lsb = (bits >> shift) & jnp.uint32(1)
    round_up = (rem > half) | ((rem == half) & (lsb == jnp.uint32(1)))
    rounded = (bits & keep_mask) + jnp.where(round_up, jnp.uint32(1 << shift), jnp.uint32(0))
    out_bits = jnp.where(_is_nonfinite(bits), bits, rounded)
    return jax.lax.bitcast_convert_type(out_bits, jnp.float32)


@functools.partial(jax.jit, static_argnames=("mu",))
def round_to_mantissa_stochastic(x: jnp.ndarray, mu: int, key: jax.Array) -> jnp.ndarray:
    """Stochastic rounding of FP32 `x` to `mu` mantissa bits.

    Adds uniform random bits below the kept mantissa then truncates --
    the standard SR construction: P(round up) = fractional part.
    """
    if not 1 <= mu <= 23:
        raise ValueError(f"mu must be in [1, 23], got {mu}")
    x = jnp.asarray(x, jnp.float32)
    if mu == _F32_MANT_BITS:
        return x
    shift = _F32_MANT_BITS - mu
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    noise = jax.random.randint(
        key, bits.shape, 0, 1 << shift, dtype=jnp.uint32
    )
    keep_mask = jnp.uint32(~((1 << shift) - 1) & 0xFFFFFFFF)
    rounded = (bits + noise) & keep_mask
    out_bits = jnp.where(_is_nonfinite(bits), bits, rounded)
    return jax.lax.bitcast_convert_type(out_bits, jnp.float32)


def unit_roundoff(mu: int) -> float:
    """Unit round-off u = 2^-(mu+1) of PS(mu) under RNE."""
    return 2.0 ** -(mu + 1)


def quantize_ps(x: jnp.ndarray, mu: int, *, stochastic: bool = False,
                key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Quantize to the PS(mu) representable set (RNE by default)."""
    if stochastic:
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        return round_to_mantissa_stochastic(x, mu, key)
    return round_to_mantissa(x, mu)


def is_representable(x: jnp.ndarray, mu: int) -> jnp.ndarray:
    """True where `x` is exactly representable in PS(mu)."""
    return round_to_mantissa(x, mu) == jnp.asarray(x, jnp.float32)


def effective_mantissa_bits(mu: int, recompute_rate: float,
                            high_mu: int = 23) -> float:
    """Paper footnote 3: average mantissa bits per inner product.

    e.g. mu=7, rate=0.083, high=23  ->  1*7 + 0.083*23 = 8.909.
    (The paper counts the low-precision pass for every product plus the
    FP32 recompute for the selected fraction.)
    """
    return 1.0 * mu + recompute_rate * high_mu


# Named formats from the paper (Sec. 4.1).
PS_FORMATS = {
    "fp32": 23,
    "tf32": 10,
    "bf16": 7,
}


def mu_of(format_or_mu) -> int:
    """Accept 'bf16' / 'tf32' / 'fp32' / int mu."""
    if isinstance(format_or_mu, str):
        return PS_FORMATS[format_or_mu]
    return int(format_or_mu)
