"""LAMP policy configuration: where and how the technique is applied."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class LampSite:
    """LAMP applied at one composition site (g = matmul, f = nonlinearity)."""
    enabled: bool = True
    mu: int = 7                  # PS(mu) accumulation precision for g
    tau: float = 0.1             # LAMP threshold
    rule: str = "relaxed"        # strict | relaxed | relaxed_ln | none
    granularity: int = 0         # dot_ps simulation tier (0=cast-only, 1=per-FMA)
    n_ref: int = 1024            # LN rule reference length (paper: GPT-2 ctx)
    onepass: bool = False        # online rule (9) vs running max (1 KV sweep,
                                 # conservative over-selection; Sec 4.4 tier)

    def replace(self, **kw) -> "LampSite":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class LampPolicy:
    """Per-model LAMP policy.

    Sites:
      kq         -- KQ inner products ahead of attention softmax (paper Sec 3.3)
      router     -- MoE router logits ahead of routing softmax (beyond-paper)
      rmsnorm    -- matmul ahead of RMS layer norm (paper Sec 3.2)
      activation -- matmul ahead of entrywise activation (paper Sec 3.1)
      logits     -- LM-head logits ahead of the output softmax
    """
    kq: LampSite = LampSite()
    router: LampSite = LampSite(enabled=False, rule="strict")
    rmsnorm: LampSite = LampSite(enabled=False)
    activation: LampSite = LampSite(enabled=False)
    logits: LampSite = LampSite(enabled=False)

    def replace(self, **kw) -> "LampPolicy":
        return dataclasses.replace(self, **kw)

    @staticmethod
    def disabled() -> "LampPolicy":
        off = LampSite(enabled=False)
        return LampPolicy(kq=off, router=off, rmsnorm=off, activation=off, logits=off)

    @staticmethod
    def paper_default(mu: int = 7, tau: float = 0.1, rule: str = "strict",
                      granularity: int = 1) -> "LampPolicy":
        """The paper's experimental setting: LAMP on KQ products only."""
        return LampPolicy(
            kq=LampSite(enabled=True, mu=mu, tau=tau, rule=rule,
                        granularity=granularity),
            router=LampSite(enabled=False),
            rmsnorm=LampSite(enabled=False),
            activation=LampSite(enabled=False),
            logits=LampSite(enabled=False),
        )

    @staticmethod
    def deployment(mu: int = 7, tau: float = 0.05) -> "LampPolicy":
        """TPU deployment tier: relaxed rule, cast-only simulation, one-pass
        online threshold (single KV sweep; conservative over-selection),
        router LAMP on MoE models (site is ignored by dense models)."""
        return LampPolicy(
            kq=LampSite(enabled=True, mu=mu, tau=tau, rule="relaxed",
                        granularity=0, onepass=True),
            router=LampSite(enabled=True, mu=mu, tau=tau, rule="strict", granularity=0),
            rmsnorm=LampSite(enabled=False),
            activation=LampSite(enabled=False),
            logits=LampSite(enabled=False),
        )
