"""LAMP core: numerics, selection rules, mixed-precision matmuls, attention."""

from .numerics import (
    round_to_mantissa,
    round_to_mantissa_stochastic,
    quantize_ps,
    unit_roundoff,
    effective_mantissa_bits,
    PS_FORMATS,
    mu_of,
)
from .lamp import (
    masked_softmax,
    select_softmax_strict,
    select_softmax_relaxed,
    select_softmax_relaxed_ln,
    select_rmsnorm,
    select_activation,
    kappa_c_rmsnorm,
    kappa_1_softmax,
    kappa_c_softmax,
    recompute_rate,
)
from .mixed_matmul import dot_ps, matmul_lamp, lamp_matmul_softmax, dot_ps_error_bound
from .attention import (
    attention_reference,
    attention_lamp,
    chunked_attention,
    chunked_attention_lamp,
    decode_attention_lamp,
    AttnAux,
)
from .policy import LampPolicy, LampSite
