"""Fault-tolerant checkpointing: atomic, async, sharded, reshardable.

Layout (one directory per step):
    <dir>/step_000100/
        meta.json            -- step, flat key list, shapes/dtypes, mesh info
        arrays.npz           -- flattened leaves (host-local / fully
                                addressable arrays)
    <dir>/LATEST             -- atomic pointer file (rename-into-place)

Guarantees:
  * atomicity  -- writes go to step_xxx.tmp/, fsync'd, then os.replace'd;
    a crash mid-save never corrupts the previous checkpoint
  * async      -- save() returns immediately (background thread); wait()
    joins (train loop calls wait() before the next save or at exit)
  * resharding -- restore() only needs shapes to match; the caller re-places
    arrays onto whatever mesh/sharding the (possibly different-size) job
    uses, which is what makes elastic scale-up/down work
  * GC         -- keep_last newest checkpoints retained
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
                       for p in path)
        items.append((key, leaf))
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep_last: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        """Snapshot to host memory now; write to disk in the background."""
        self.wait()
        items, _ = _flatten(tree)
        host = [(k, np.asarray(jax.device_get(v))) for k, v in items]
        # numpy cannot serialize ml_dtypes (bfloat16 etc.): store the raw
        # bit pattern and record the true dtype in meta for restore.
        true_dtypes = {k: str(v.dtype) for k, v in host}
        host = [(k, v.view(np.uint16) if str(v.dtype) == "bfloat16" else v)
                for k, v in host]

        def _write():
            try:
                tmp = self.dir / f"step_{step:08d}.tmp"
                final = self.dir / f"step_{step:08d}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                np.savez(tmp / "arrays.npz", **{k: v for k, v in host})
                meta = {
                    "step": step,
                    "time": time.time(),
                    "keys": [k for k, _ in host],
                    "shapes": {k: list(v.shape) for k, v in host},
                    "dtypes": true_dtypes,
                }
                (tmp / "meta.json").write_text(json.dumps(meta))
                if final.exists():
                    shutil.rmtree(final)
                os.replace(tmp, final)
                latest_tmp = self.dir / "LATEST.tmp"
                latest_tmp.write_text(final.name)
                os.replace(latest_tmp, self.dir / "LATEST")
                self._gc()
            except BaseException as e:  # surfaced at next wait()
                self._error = e

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint save failed: {err!r}")

    def _gc(self) -> None:
        steps = sorted(self.dir.glob("step_????????"))
        for old in steps[: -self.keep_last]:
            shutil.rmtree(old, ignore_errors=True)

    # ---------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        ptr = self.dir / "LATEST"
        if not ptr.exists():
            return None
        name = ptr.read_text().strip()
        if not (self.dir / name).exists():
            # LATEST points at a GC'd/corrupt dir: fall back to newest valid
            steps = sorted(self.dir.glob("step_????????"))
            if not steps:
                return None
            name = steps[-1].name
        return int(name.split("_")[1])

    def restore(self, tree_like, step: Optional[int] = None,
                *, shardings=None):
        """Restore into the structure of `tree_like`. With `shardings`
        (a matching pytree of NamedSharding), arrays are placed directly
        onto the target mesh -- this is the elastic-resharding path."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        data = np.load(d / "arrays.npz")
        meta = json.loads((d / "meta.json").read_text())
        items, treedef = _flatten(tree_like)
        leaves = []
        flat_shard = (jax.tree_util.tree_leaves(shardings)
                      if shardings is not None else [None] * len(items))
        for (key, like), sh in zip(items, flat_shard):
            if key not in data:
                raise KeyError(f"checkpoint missing {key}")
            arr = data[key]
            if meta["dtypes"].get(key) == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                                 f"model shape {like.shape}")
            arr = arr.astype(like.dtype)
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)
