"""Elastic rescaling: resume a checkpoint on a different-size mesh.

The checkpoint stores full (unsharded) arrays; rescaling is therefore a
re-placement problem, not a data-transformation problem:

  1. build the new mesh from the surviving host set,
  2. recompute PartitionSpecs against the new mesh (sharding rules degrade
     gracefully: axes that no longer divide fall back to replication --
     see distributed/sharding._fit_spec),
  3. restore() with the new shardings.

The only state that is *not* mesh-independent is the data-pipeline cursor;
the synthetic pipeline is stateless in (seed, step), so resume is exact.
Batch divisibility is re-validated here.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.distributed import sharding as SH


def make_shrunk_mesh(n_devices: int, *, model_axis: int):
    """Largest (data, model) mesh that fits n_devices with the given TP."""
    if n_devices % model_axis:
        raise ValueError(f"{n_devices} devices not divisible by TP={model_axis}")
    data = n_devices // model_axis
    return jax.make_mesh(
        (data, model_axis), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def elastic_restore(mgr: CheckpointManager, params_shape, opt_shape,
                    mesh, *, step: Optional[int] = None):
    """Restore the {params, opt} checkpoint tree onto `mesh` (any size)."""
    pspecs = SH.param_specs(params_shape, mesh)
    ospecs = SH.opt_specs(opt_shape, pspecs)
    tree_like = {"params": params_shape, "opt": opt_shape}
    shardings = {"params": pspecs, "opt": ospecs}
    restored = mgr.restore(tree_like, step, shardings=shardings)
    return restored["params"], restored["opt"], pspecs, ospecs


def validate_batch(global_batch: int, mesh) -> Tuple[bool, str]:
    shards = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    if global_batch % shards:
        return False, (f"global_batch={global_batch} not divisible by "
                       f"{shards} data shards; nearest valid: "
                       f"{global_batch - global_batch % shards}")
    return True, ""
