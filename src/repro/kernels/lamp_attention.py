"""Pallas TPU kernel: one-pass relaxed-LAMP flash attention.

The paper's future-work target (Sec 4.4): fuse the relaxed relative-threshold
rule (9) into an online-softmax attention kernel. TPU adaptation
(DESIGN.md Sec 3):

  * KQ products are accumulated in FP32 inside K-subtiles of `k_subtile`
    lanes (that is what the MXU gives you), and the running accumulator is
    rounded to PS(mu) each time a subtile's partial sum is folded in --
    the block-granular low-precision-accumulation deployment model.
  * Selection uses the running max of s = y + log|y| (one-pass, conservative:
    early blocks can only over-select relative to rule (9)).
  * Selected logits are replaced by the exact FP32 product (on hardware with
    packed low-precision accumulators the exact product would be a tile
    recompute; in the simulation both values fall out of the same MXU pass).

Grid: (batch*heads, n_q_blocks, n_k_blocks); the k-block axis is the
innermost (sequential on TPU), with the online-softmax state carried in VMEM
scratch across k iterations. BlockSpecs keep one (block_q, D) query tile,
one (block_k, D) K tile and V tile in VMEM at a time.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.numerics import round_to_mantissa

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, nsel_ref,
            acc_ref, m_ref, l_ref, smax_ref, cnt_ref,
            *, mu: int, tau: float, causal: bool, scale: float,
            k_subtile: int, block_q: int, block_k: int, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        smax_ref[...] = jnp.full_like(smax_ref, _NEG)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    q = q_ref[0].astype(jnp.float32) * scale        # (bq, D)
    k = k_ref[0].astype(jnp.float32)                # (bk, D)
    v = v_ref[0].astype(jnp.float32)                # (bk, D)
    D = q.shape[-1]

    # --- low-precision QK: PS(mu)-rounded subtile accumulation over D ---
    n_sub = -(-D // k_subtile)
    y_low = jnp.zeros((block_q, block_k), jnp.float32)
    for s in range(n_sub):
        part = jax.lax.dot_general(
            q[:, s * k_subtile:(s + 1) * k_subtile],
            k[:, s * k_subtile:(s + 1) * k_subtile],
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        y_low = round_to_mantissa(y_low + part, mu) if mu < 23 else y_low + part

    ok = jnp.ones((block_q, block_k), bool)
    if causal:
        iq = pl.program_id(1)
        qi = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kj = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = kj <= qi

    # --- relaxed-LAMP selection against the running row max of y + log|y| ---
    s_crit = jnp.where(ok, y_low + jnp.log(jnp.abs(y_low)), _NEG)
    smax = jnp.maximum(smax_ref[...], jnp.max(s_crit, axis=-1))
    smax_ref[...] = smax
    sel = ok & (s_crit > jnp.log(jnp.maximum(tau, 1e-30)) + smax[:, None])
    cnt_ref[...] += jnp.sum(sel.astype(jnp.float32))

    # --- selective exact recompute (full-precision MXU pass) ---
    y_exact = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y = jnp.where(sel, y_exact, y_low)
    y = jnp.where(ok, y, _NEG)

    # --- online softmax ---
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(y, axis=-1))
    p = jnp.where(ok, jnp.exp(y - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)
        nsel_ref[0, 0] = cnt_ref[...]


@functools.partial(jax.jit, static_argnames=(
    "mu", "tau", "causal", "block_q", "block_k", "k_subtile", "interpret"))
def lamp_flash_attention(q, k, v, *, mu: int = 7, tau: float = 0.05,
                         causal: bool = True, block_q: int = 128,
                         block_k: int = 128, k_subtile: int = 32,
                         interpret: bool = True,
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q,k,v: (B, H, T, D) -> (out (B,H,T,D) f32, n_selected scalar f32)."""
    B, H, T, D = q.shape
    S = k.shape[2]
    scale = D ** -0.5
    block_q = min(block_q, T)
    block_k = min(block_k, S)
    if T % block_q or S % block_k:
        raise ValueError(f"T={T} % block_q={block_q} or S={S} % block_k={block_k}")
    n_q, n_k = T // block_q, S // block_k
    qf = q.reshape(B * H, T, D)
    kf = k.reshape(B * H, S, D)
    vf = v.reshape(B * H, S, D)

    kernel = functools.partial(
        _kernel, mu=mu, tau=tau, causal=causal, scale=scale,
        k_subtile=k_subtile, block_q=block_q, block_k=block_k, n_k=n_k)

    out, nsel = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda i, j, kk: (i, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, j, kk: (i, kk, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, j, kk: (i, kk, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, D), lambda i, j, kk: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, T, D), jnp.float32),
            jax.ShapeDtypeStruct((B * H, n_q), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),     # m
            pltpu.VMEM((block_q,), jnp.float32),     # l
            pltpu.VMEM((block_q,), jnp.float32),     # running smax
            pltpu.VMEM((), jnp.float32),             # selection count
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, T, D), jnp.sum(nsel)
