"""Fused Pallas paged-attention kernels: gather-free decode + windowed prefill.

Block-index map
---------------
The serving engine stores KV in a per-layer block arena of shape
(n_blocks, block_size, Hkv, hd); sequence r owns the ordered blocks
``block_tables[r]`` (0 = reserved null block used for padding). The gather
reference path (models/layers.py) materializes each row's view with
``arena[block_tables]`` -- O(n_max * block_size) HBM traffic per row per
step no matter how many tokens are live.

These kernels consume the arena + block tables directly. ``block_tables``
and the per-row lengths/starts are scalar-prefetch operands
(``pltpu.PrefetchScalarGridSpec``), so the K/V BlockSpec index map resolves
grid step (row, kv-block j) to arena block ``block_tables[row, j]`` and the
pipeline DMAs exactly that block into VMEM -- the host-side gather
disappears. The index map clamps j into the row's *live* range [lo, hi]
(past-the-length blocks, and blocks wholly outside the sliding window,
re-map to an already-resident live block, costing no fresh DMA) and the
block's compute is guarded with ``pl.when`` -- fully-masked blocks are
skipped, not summed as zeros. GQA is resolved in the index map as well
(query head -> kv head), so K/V are never repeated in memory.

LAMP two-pass layout
--------------------
The LAMP look-ahead rules threshold against *global* row statistics of the
low-precision logits: the row max of s = y + log|y| for the relaxed rules
(9) / LN-(9), and the softmax normalizer (m, l) for the strict rule (8).
Each variant is therefore a pair of ``pallas_call``s:

  pass 1 (look-ahead): streams live K blocks, computes y_low = PS(mu)
      logits with the same rounding points as ``core.mixed_matmul.dot_ps``
      (granularity 0 = cast-only single MXU pass + final round; g >= 1 =
      FP32 accumulation inside K-chunks of g lanes, re-round per chunk),
      and reduces smax, m = max y_low, l = sum exp(y_low - m) per row.
  pass 2 (recompute): streams live K and V blocks again, recomputes y_low
      identically, selects with the exact rule threshold from the pass-1
      stats, replaces selected logits with the FP32 product, online-softmax
      accumulates P@V, and counts selections per row (the engine's
      per-request recompute telemetry).

Because both passes recompute y_low identically, the pair implements the
materialized-softmax rules exactly: outputs match the gather reference path
to float32 softmax roundoff and selection counts match bit-for-bit for the
max-based rules (relaxed / relaxed_ln).

Variants:
  paged_decode_attention  -- one query row per sequence at absolute
      position lengths[r] - 1; grid (R*H, n_max), like ``flash_decode``.
  paged_prefill_attention -- windowed prefill: query tile x block grid
      ((B*H, W/block_q, n_max)) with absolute-position causal masks
      (query row w of sequence b sits at position starts[b] + w). With the
      optional per-row ``qlens`` scalar-prefetch operand this is also the
      *mixed-row* grid: each row carries its own live query count, so one
      launch covers decode rows (qlen 1), chunked-prefill windows (qlen w)
      and speculative verify rows (qlen k+1) side by side -- the index map
      clamps each row's KV walk to its own live block range and ``pl.when``
      skips tiles/blocks past the row's queries. ``paged_mixed_attention``
      is the documented alias for that calling convention.

The benchmark-only "random" control rule stays on the gather path
(``supports_site``).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.numerics import round_to_mantissa
from repro.core.policy import LampSite

_NEG = -1e30
_TINY = 1.1754944e-38  # float32 tiny: masked_softmax's normalizer clamp


def supports_site(site: LampSite) -> bool:
    """The fused kernels implement every materialized-softmax LAMP rule the
    serving paths use; the App C.4 'random' control arm (benchmark-only,
    needs a resampled key per call) stays on the gather path."""
    return (not site.enabled) or site.rule in ("none", "strict", "relaxed",
                                               "relaxed_ln")


def _y_low(q, k, mu: int, granularity: int):
    """PS(mu) q @ k^T, bitwise-matching ``dot_ps``: granularity 0
    (cast-only) = one FP32 pass + final round; g >= 1 = FP32 accumulation
    inside K-chunks of g lanes, re-rounding the running accumulator."""
    dn = (((1,), (1,)), ((), ()))
    if mu >= 23:
        return jax.lax.dot_general(q, k, dn, preferred_element_type=jnp.float32)
    D = q.shape[-1]
    if granularity == 0 or granularity >= D:
        y = jax.lax.dot_general(q, k, dn, preferred_element_type=jnp.float32)
        return round_to_mantissa(y, mu)
    g = int(granularity)
    acc = jnp.zeros((q.shape[0], k.shape[0]), jnp.float32)
    for s in range(-(-D // g)):
        part = jax.lax.dot_general(q[:, s * g:(s + 1) * g],
                                   k[:, s * g:(s + 1) * g], dn,
                                   preferred_element_type=jnp.float32)
        acc = round_to_mantissa(acc + part, mu)
    return acc


def _select(y_low, ok, smax, m_low, l_low, n_row, *, rule: str, tau,
            n_ref: int):
    """LAMP look-ahead mask on one logits tile from pass-1 row stats.
    smax / m_low / l_low / n_row broadcast against y_low's rows. `tau` may
    be a traced scalar (read off the kernel's scalar-prefetch operand): the
    general log-space comparison then reproduces the static tau == 0 branch
    via log(0) = -inf (threshold -inf selects every finite s)."""
    if rule == "none":
        return jnp.zeros(y_low.shape, bool)
    if rule == "strict":
        z = jnp.where(ok, jnp.exp(y_low - m_low), 0.0) / jnp.maximum(l_low, _TINY)
        return ok & (2.0 * z * (1.0 - z) * jnp.abs(y_low) > tau)
    s = y_low + jnp.log(jnp.abs(y_low))      # -inf at y == 0: never selects
    if rule == "relaxed":
        if isinstance(tau, (int, float)) and tau == 0.0:
            return ok & jnp.isfinite(s)
        return ok & (s > jnp.log(tau) + smax)
    if rule == "relaxed_ln":
        tau_row = tau * jnp.sqrt(n_ref / jnp.maximum(n_row, 1).astype(jnp.float32))
        tau_row = jnp.minimum(tau_row, 1.0 - 1e-6)
        return ok & (s > jnp.log(tau_row) + smax)
    raise ValueError(f"unsupported LAMP rule {rule!r}")


# ---------------------------------------------------------------------------
# Decode variant: one query row per sequence, grid (R*H, n_max)
# ---------------------------------------------------------------------------

def _dec_mask(j, L, bs, window):
    """(live, ok): whether KV block j intersects the valid range of a row of
    effective length L, and the per-position mask inside the block."""
    kj = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    ok = kj < L
    live = j * bs < L
    if window is not None:
        ok &= kj > L - 1 - window
        live &= (j + 1) * bs > L - window
    return live, ok


def _dec_stats_kernel(bt_ref, len_ref, q_ref, k_ref, stats_ref,
                      smax_ref, m_ref, l_ref,
                      *, H, bs, n_k, mu, granularity, scale, window):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        smax_ref[...] = jnp.full_like(smax_ref, _NEG)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    L = len_ref[i // H]
    live, ok = _dec_mask(j, L, bs, window)

    @pl.when(live)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale       # (1, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (bs, hd)
        y = _y_low(q, k, mu, granularity)              # (1, bs)
        s = jnp.where(ok, y + jnp.log(jnp.abs(y)), _NEG)
        smax_ref[...] = jnp.maximum(smax_ref[...], jnp.max(s))
        m_new = jnp.maximum(m_ref[...], jnp.max(jnp.where(ok, y, _NEG)))
        p = jnp.where(ok, jnp.exp(y - m_new), 0.0)
        l_ref[...] = l_ref[...] * jnp.exp(m_ref[...] - m_new) + jnp.sum(p)
        m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _finish():
        stats_ref[0, 0] = smax_ref[...]
        stats_ref[0, 1] = m_ref[...]
        stats_ref[0, 2] = l_ref[...]


def _dec_kernel(bt_ref, len_ref, tau_ref, q_ref, k_ref, v_ref, stats_ref,
                o_ref, nsel_ref, acc_ref, m_ref, l_ref, cnt_ref,
                *, H, bs, n_k, lamp, mu, granularity, rule, n_ref_ln,
                scale, window):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    L = len_ref[i // H]
    live, ok = _dec_mask(j, L, bs, window)

    @pl.when(live)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0, :, 0].astype(jnp.float32)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if lamp:
            y_low = _y_low(q, k, mu, granularity)
            sel = _select(y_low, ok, stats_ref[0, 0], stats_ref[0, 1],
                          stats_ref[0, 2], L, rule=rule, tau=tau_ref[0],
                          n_ref=n_ref_ln)
            if rule == "none":
                y = y_low
            else:
                y_exact = jax.lax.dot_general(
                    q, k, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32)
                y = jnp.where(sel, y_exact, y_low)
            cnt_ref[...] += jnp.sum(sel.astype(jnp.float32))
        else:
            y = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        y = jnp.where(ok, y, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(y))
        p = jnp.where(ok, jnp.exp(y - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], _TINY)
                    ).astype(o_ref.dtype)
        nsel_ref[0, 0] = cnt_ref[...]


@functools.partial(jax.jit, static_argnames=("site", "window", "interpret"))
def paged_decode_attention(q, arena_k, arena_v, block_tables, lengths,
                           site: LampSite, *, tau=None,
                           window: Optional[int] = None,
                           interpret: bool = True,
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One decode step straight off the paged arena (no pre-gather).

    q: (R, H, 1, hd); arena_k/v: (n_blocks, block_size, Hkv, hd);
    block_tables: (R, n_max) int32; lengths: (R,) *effective* lengths (the
    new token's KV already written, so valid positions are [0, lengths[r])).
    Returns (out (R, H, 1, hd) float32, n_selected (R,) float32 summed over
    heads) -- the same contract as ``decode_attention_lamp(reduce=False)``.

    `tau` (optional *traced* scalar) overrides the static ``site.tau``: it
    rides into the selection kernel as a third scalar-prefetch operand, so
    the policy controller can move the threshold every step without the jit
    cache key (site is static) ever changing.
    """
    R, H, Tq, hd = q.shape
    if Tq != 1:
        raise ValueError(f"decode takes one query row, got Tq={Tq}")
    _, bs, Hkv, _ = arena_k.shape
    n_max = block_tables.shape[1]
    rep = H // Hkv
    scale = hd ** -0.5
    qf = q.reshape(R * H, 1, hd)
    bt = block_tables.astype(jnp.int32)
    lens = lengths.astype(jnp.int32)
    tau_arr = jnp.asarray(site.tau if tau is None else tau,
                          jnp.float32).reshape((1,))
    lamp = bool(site.enabled)
    # rule "none" keeps the y_low softmax but selects nothing: the look-ahead
    # stats pass would be dead work, so only run it for a selecting rule
    need_stats = lamp and site.rule != "none"

    def kv_map(i, j, bt_ref, len_ref, *_):
        r = i // H
        L = len_ref[r]
        hi = (L - 1) // bs
        lo = 0 if window is None else jnp.maximum(L - window, 0) // bs
        return (bt_ref[r, jnp.clip(j, lo, hi)], 0, (i % H) // rep, 0)

    q_spec = pl.BlockSpec((1, 1, hd), lambda i, j, *_: (i, 0, 0))
    kv_spec = pl.BlockSpec((1, bs, 1, hd), kv_map)
    stats_spec = pl.BlockSpec((1, 3), lambda i, j, *_: (i, 0))

    if need_stats:
        stats = pl.pallas_call(
            functools.partial(_dec_stats_kernel, H=H, bs=bs, n_k=n_max,
                              mu=site.mu, granularity=site.granularity,
                              scale=scale, window=window),
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=2,
                grid=(R * H, n_max),
                in_specs=[q_spec, kv_spec],
                out_specs=stats_spec,
                scratch_shapes=[pltpu.VMEM((), jnp.float32)] * 3,
            ),
            out_shape=jax.ShapeDtypeStruct((R * H, 3), jnp.float32),
            interpret=interpret,
        )(bt, lens, qf, arena_k)
    else:
        stats = jnp.zeros((R * H, 3), jnp.float32)

    out, nsel = pl.pallas_call(
        functools.partial(_dec_kernel, H=H, bs=bs, n_k=n_max, lamp=lamp,
                          mu=site.mu, granularity=site.granularity,
                          rule=site.rule, n_ref_ln=site.n_ref,
                          scale=scale, window=window),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(R * H, n_max),
            in_specs=[q_spec, kv_spec, kv_spec, stats_spec],
            out_specs=[
                pl.BlockSpec((1, 1, hd), lambda i, j, *_: (i, 0, 0)),
                pl.BlockSpec((1, 1), lambda i, j, *_: (i, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((1, hd), jnp.float32),   # acc
                pltpu.VMEM((), jnp.float32),        # m
                pltpu.VMEM((), jnp.float32),        # l
                pltpu.VMEM((), jnp.float32),        # nsel count
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((R * H, 1, hd), jnp.float32),
            jax.ShapeDtypeStruct((R * H, 1), jnp.float32),
        ],
        interpret=interpret,
    )(bt, lens, tau_arr, qf, arena_k, arena_v, stats)
    return (out.reshape(R, H, 1, hd),
            jnp.sum(nsel.reshape(R, H), axis=1))


# ---------------------------------------------------------------------------
# Windowed-prefill variant: query tile x block grid (B*H, n_q, n_max)
# ---------------------------------------------------------------------------

def _pre_mask(j, q0, qe, bs, wq, window):
    """(live, ok, qi): block liveness for the q-tile starting at absolute
    position q0 with qe live queries (qe == wq when the row fills the tile),
    and the absolute-position causal mask inside the tile. A block is live
    only if it intersects the causal span of the row's *live* queries, so a
    decode row (qe == 1) in a wide mixed bucket walks exactly the blocks the
    dedicated decode grid would. Pad queries past qe keep the plain causal
    mask; their lanes are discarded by the caller, and every block they
    would have added is a bitwise no-op for live rows (p == 0 everywhere,
    m/l/acc carried through unchanged), so skipping those blocks leaves
    live rows bit-identical to the qe == wq walk."""
    qi = q0 + jax.lax.broadcasted_iota(jnp.int32, (wq, bs), 0)
    kj = j * bs + jax.lax.broadcasted_iota(jnp.int32, (wq, bs), 1)
    ok = kj <= qi
    live = (qe > 0) & (j * bs <= q0 + qe - 1)
    if window is not None:
        ok &= kj > qi - window
        live &= (j + 1) * bs - 1 > q0 - window
    return live, ok, qi


def _pre_stats_kernel(bt_ref, starts_ref, ql_ref, q_ref, k_ref,
                      smax_o, m_o, l_o, smax_ref, m_ref, l_ref,
                      *, H, bs, wq, n_k, mu, granularity, scale, window):
    i, t, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        smax_ref[...] = jnp.full_like(smax_ref, _NEG)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q0 = starts_ref[i // H] + t * wq
    qe = jnp.clip(ql_ref[i // H] - t * wq, 0, wq)
    live, ok, _ = _pre_mask(j, q0, qe, bs, wq, window)

    @pl.when(live)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale       # (wq, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (bs, hd)
        y = _y_low(q, k, mu, granularity)              # (wq, bs)
        s = jnp.where(ok, y + jnp.log(jnp.abs(y)), _NEG)
        smax_ref[...] = jnp.maximum(smax_ref[...], jnp.max(s, axis=-1))
        m_new = jnp.maximum(m_ref[...],
                            jnp.max(jnp.where(ok, y, _NEG), axis=-1))
        p = jnp.where(ok, jnp.exp(y - m_new[:, None]), 0.0)
        l_ref[...] = (l_ref[...] * jnp.exp(m_ref[...] - m_new)
                      + jnp.sum(p, axis=-1))
        m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _finish():
        smax_o[0] = smax_ref[...]
        m_o[0] = m_ref[...]
        l_o[0] = l_ref[...]


def _pre_kernel(bt_ref, starts_ref, ql_ref, tau_ref, q_ref, k_ref, v_ref,
                smax_ref, mlow_ref, llow_ref, o_ref, nsel_ref,
                acc_ref, m_ref, l_ref, cnt_ref,
                *, H, bs, wq, n_k, lamp, mu, granularity, rule,
                n_ref_ln, scale, window, Tk):
    i, t, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    q0 = starts_ref[i // H] + t * wq
    qe = jnp.clip(ql_ref[i // H] - t * wq, 0, wq)
    live, ok, qi = _pre_mask(j, q0, qe, bs, wq, window)

    @pl.when(live)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0, :, 0].astype(jnp.float32)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if lamp:
            y_low = _y_low(q, k, mu, granularity)
            # row_lengths as in attention_lamp: clip(qi + 1, 0, window|Tk)
            n_row = jnp.clip(qi[:, :1] + 1, 0, Tk if window is None else window)
            sel = _select(y_low, ok, smax_ref[0][:, None],
                          mlow_ref[0][:, None], llow_ref[0][:, None], n_row,
                          rule=rule, tau=tau_ref[0], n_ref=n_ref_ln)
            if rule == "none":
                y = y_low
            else:
                y_exact = jax.lax.dot_general(
                    q, k, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32)
                y = jnp.where(sel, y_exact, y_low)
            cnt_ref[...] += jnp.sum(sel.astype(jnp.float32), axis=-1)
        else:
            y = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        y = jnp.where(ok, y, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(y, axis=-1))
        p = jnp.where(ok, jnp.exp(y - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], _TINY)[:, None]).astype(o_ref.dtype)
        nsel_ref[0] = cnt_ref[...]


@functools.partial(jax.jit, static_argnames=("site", "window", "block_q",
                                             "interpret"))
def paged_prefill_attention(q, arena_k, arena_v, block_tables, starts,
                            site: LampSite, *, tau=None, qlens=None,
                            window: Optional[int] = None,
                            block_q: Optional[int] = None,
                            interpret: bool = True,
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Windowed-prefill / mixed-row attention straight off the paged arena.

    q: (B, H, W, hd) -- query row w of sequence b sits at absolute position
    starts[b] + w and attends causally to positions 0..starts[b]+w of the
    row's block table (the cached prefix plus this window's just-written
    KV). Padded rows are computed like the gather path and discarded by the
    caller. Returns (out (B, H, W, hd) float32, n_selected (B, W) float32
    summed over heads and keys) -- the ``attention_lamp(reduce=False)``
    telemetry contract.

    `tau` (optional *traced* scalar) overrides the static ``site.tau`` via
    a scalar-prefetch operand into the selection pass, keeping live
    threshold moves out of the jit cache key (see paged_decode_attention).

    `qlens` (optional (B,) int32, traced) gives each row its own live query
    count -- the mixed-row convention: a decode row rides in a wide bucket
    with qlens[b] == 1, a chunked-prefill window with qlens[b] == w, a
    speculative verify row with qlens[b] == k+1. Rows walk (DMA + compute)
    only the KV blocks their live queries can see; results at live query
    positions are bit-identical to qlens == W (skipped blocks are exact
    no-ops for live rows, see `_pre_mask`). ``qlens=None`` means every row
    fills the bucket -- the historical behavior, bit-for-bit.
    """
    B, H, W, hd = q.shape
    _, bs, Hkv, _ = arena_k.shape
    n_max = block_tables.shape[1]
    rep = H // Hkv
    scale = hd ** -0.5
    wq = W if block_q is None else min(block_q, W)
    if W % wq:
        raise ValueError(f"W={W} % block_q={wq}")
    n_q = W // wq
    Tk = n_max * bs
    qf = q.reshape(B * H, W, hd)
    bt = block_tables.astype(jnp.int32)
    st = starts.astype(jnp.int32)
    ql = (jnp.full((B,), W, jnp.int32) if qlens is None
          else qlens.astype(jnp.int32))
    tau_arr = jnp.asarray(site.tau if tau is None else tau,
                          jnp.float32).reshape((1,))
    lamp = bool(site.enabled)
    need_stats = lamp and site.rule != "none"   # as in the decode variant

    def kv_map(i, t, j, bt_ref, starts_ref, ql_ref, *_):
        b = i // H
        q0 = starts_ref[b] + t * wq
        # clamp the walk to the row's live queries in this tile (>= 1 so a
        # dead tile still resolves to a resident block; pl.when skips it)
        qe = jnp.clip(ql_ref[b] - t * wq, 1, wq)
        hi = jnp.minimum((q0 + qe - 1) // bs, n_max - 1)
        lo = 0 if window is None else \
            jnp.minimum(jnp.maximum(q0 - window + 1, 0) // bs, hi)
        return (bt_ref[b, jnp.clip(j, lo, hi)], 0, (i % H) // rep, 0)

    q_spec = pl.BlockSpec((1, wq, hd), lambda i, t, j, *_: (i, t, 0))
    kv_spec = pl.BlockSpec((1, bs, 1, hd), kv_map)
    row_spec = pl.BlockSpec((1, wq), lambda i, t, j, *_: (i, t))

    if need_stats:
        row_shape = jax.ShapeDtypeStruct((B * H, W), jnp.float32)
        smax, m_low, l_low = pl.pallas_call(
            functools.partial(_pre_stats_kernel, H=H, bs=bs, wq=wq, n_k=n_max,
                              mu=site.mu, granularity=site.granularity,
                              scale=scale, window=window),
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=3,
                grid=(B * H, n_q, n_max),
                in_specs=[q_spec, kv_spec],
                out_specs=[row_spec] * 3,
                scratch_shapes=[pltpu.VMEM((wq,), jnp.float32)] * 3,
            ),
            out_shape=[row_shape] * 3,
            interpret=interpret,
        )(bt, st, ql, qf, arena_k)
    else:
        smax = m_low = l_low = jnp.zeros((B * H, W), jnp.float32)

    out, nsel = pl.pallas_call(
        functools.partial(_pre_kernel, H=H, bs=bs, wq=wq, n_k=n_max,
                          lamp=lamp, mu=site.mu, granularity=site.granularity,
                          rule=site.rule, n_ref_ln=site.n_ref,
                          scale=scale, window=window, Tk=Tk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(B * H, n_q, n_max),
            in_specs=[q_spec, kv_spec, kv_spec, row_spec, row_spec, row_spec],
            out_specs=[
                pl.BlockSpec((1, wq, hd), lambda i, t, j, *_: (i, t, 0)),
                row_spec,
            ],
            scratch_shapes=[
                pltpu.VMEM((wq, hd), jnp.float32),  # acc
                pltpu.VMEM((wq,), jnp.float32),     # m
                pltpu.VMEM((wq,), jnp.float32),     # l
                pltpu.VMEM((wq,), jnp.float32),     # nsel counts
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B * H, W, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, W), jnp.float32),
        ],
        interpret=interpret,
    )(bt, st, ql, tau_arr, qf, arena_k, arena_v, smax, m_low, l_low)
    return (out.reshape(B, H, W, hd),
            jnp.sum(nsel.reshape(B, H, W), axis=1))


def paged_mixed_attention(q, arena_k, arena_v, block_tables, starts, qlens,
                          site: LampSite, *, tau=None,
                          window: Optional[int] = None,
                          block_q: Optional[int] = None,
                          interpret: bool = True,
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mixed-row paged attention: one grid over decode rows (qlens[b] == 1),
    chunked-prefill windows (qlens[b] == w) and speculative verify rows
    (qlens[b] == k+1). Alias of ``paged_prefill_attention`` with `qlens`
    required -- the fused serving step's kernel entry."""
    return paged_prefill_attention(q, arena_k, arena_v, block_tables, starts,
                                   site, tau=tau, qlens=qlens, window=window,
                                   block_q=block_q, interpret=interpret)


# ---------------------------------------------------------------------------
# Traffic model (benchmarks): KV bytes DMA'd per decode step, per layer
# ---------------------------------------------------------------------------

def decode_kv_bytes(lengths, *, n_max: int, block_size: int,
                    bytes_per_token: int, window: Optional[int] = None,
                    lamp: bool = True) -> Tuple[int, int]:
    """(gather_bytes, fused_bytes) of KV traffic for one decode step of one
    layer. The gather path materializes every row's full block-table span
    (K and V); the fused kernels DMA only live blocks -- the LAMP look-ahead
    pass re-reads K, so fused = live_blocks * (2K + V) when LAMP is on.
    ``bytes_per_token`` = Hkv * hd * itemsize."""
    import numpy as np
    L = np.maximum(np.asarray(lengths, np.int64), 1)
    gather = int(L.size) * n_max * block_size * bytes_per_token * 2
    lo = (np.maximum(L - window, 0) // block_size if window is not None
          else np.zeros_like(L))
    hi = (L - 1) // block_size
    live = int((hi - lo + 1).sum())
    fused = live * block_size * bytes_per_token * (3 if lamp else 2)
    return gather, fused
