"""Pallas TPU kernel: fused RMSNorm forward (one pass, row-tiled VMEM).

Used by the serving path; also the natural fusion site for the paper's
RMSNorm LAMP rule (Prop 3.2) -- the selection itself needs a sort and stays
in JAX (DESIGN.md Sec 3), but the normalization is fused here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * (1.0 + w)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = True) -> jnp.ndarray:
    """x: (..., d), w: (d,). Rows are tiled block_rows at a time in VMEM."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=((rows + pad) // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((rows + pad), d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(orig_shape)
