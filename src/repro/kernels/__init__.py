"""Pallas TPU kernels (validated in interpret mode on CPU; Mosaic on TPU).

  lamp_attention  -- one-pass relaxed-LAMP flash attention (the paper kernel)
  flash_decode    -- exact two-pass rule-(9) decode attention
  paged_attention -- gather-free paged decode + windowed prefill over the
                     serving engine's KV block arena (scalar-prefetched
                     block-table index maps, LAMP two-pass selection)
  ps_matmul       -- PS(mu)-accumulating blocked matmul
  rmsnorm         -- fused RMSNorm forward

ops.py = public jit'd wrappers; ref.py = pure-jnp oracles.
"""
from . import ops, ref
