"""Pallas TPU kernel pair: LAMP decode attention (exact two-pass rule (9)).

Decode reads one query row against a long KV cache; the relaxed-LAMP
relative threshold needs the global row max of s = y + log|y|, so the op is
split into two VMEM-tiled kernels:

  1. `_smax_kernel`  -- streams K blocks, computes PS(mu) low-precision
     logits, reduces the global max of s per (batch*head).
  2. `_decode_kernel` -- streams K/V blocks again, selects with the exact
     threshold, recomputes selected logits in FP32, online-softmax
     accumulates P@V.

Both kernels recompute y_low identically (same subtile rounding), so the
pair implements rule (9) exactly -- matching `ref.flash_decode_ref`.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.numerics import round_to_mantissa

_NEG = -1e30


def _y_low(q, k, mu, k_subtile):
    D = q.shape[-1]
    n_sub = -(-D // k_subtile)
    acc = jnp.zeros((q.shape[0], k.shape[0]), jnp.float32)
    for s in range(n_sub):
        part = jax.lax.dot_general(
            q[:, s * k_subtile:(s + 1) * k_subtile],
            k[:, s * k_subtile:(s + 1) * k_subtile],
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        acc = round_to_mantissa(acc + part, mu) if mu < 23 else acc + part
    return acc


def _smax_kernel(q_ref, k_ref, len_ref, smax_ref, run_ref,
                 *, mu, scale, k_subtile, block_k, n_k):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        run_ref[...] = jnp.full_like(run_ref, _NEG)

    q = q_ref[0].astype(jnp.float32) * scale            # (1, D)
    k = k_ref[0].astype(jnp.float32)                    # (bk, D)
    y = _y_low(q, k, mu, k_subtile)                     # (1, bk)
    kj = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    ok = kj < len_ref[0]
    s = jnp.where(ok, y + jnp.log(jnp.abs(y)), _NEG)
    run_ref[...] = jnp.maximum(run_ref[...], jnp.max(s))

    @pl.when(ik == n_k - 1)
    def _finish():
        smax_ref[0, 0] = run_ref[...]


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, smax_ref, o_ref, nsel_ref,
                   acc_ref, m_ref, l_ref, cnt_ref,
                   *, mu, tau, scale, k_subtile, block_k, n_k):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    q = q_ref[0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    y_low = _y_low(q, k, mu, k_subtile)
    kj = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    ok = kj < len_ref[0]
    s = jnp.where(ok, y_low + jnp.log(jnp.abs(y_low)), _NEG)
    sel = ok & (s > jnp.log(jnp.maximum(tau, 1e-30)) + smax_ref[0, 0])
    y_exact = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y = jnp.where(sel, y_exact, y_low)
    y = jnp.where(ok, y, _NEG)
    cnt_ref[...] += jnp.sum(sel.astype(jnp.float32))

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(y))
    p = jnp.where(ok, jnp.exp(y - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)
        nsel_ref[0, 0] = cnt_ref[...]


@functools.partial(jax.jit, static_argnames=(
    "mu", "tau", "block_k", "k_subtile", "interpret"))
def flash_decode(q, k_cache, v_cache, length, *, mu: int = 7, tau: float = 0.05,
                 block_k: int = 512, k_subtile: int = 32,
                 interpret: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q (B,H,1,D) vs caches (B,H,S,D), length (B,) ->
    (out (B,H,1,D) f32, n_selected)."""
    B, H, _, D = q.shape
    S = k_cache.shape[2]
    scale = D ** -0.5
    block_k = min(block_k, S)
    if S % block_k:
        raise ValueError(f"S={S} % block_k={block_k}")
    n_k = S // block_k
    qf = q.reshape(B * H, 1, D)
    kf = k_cache.reshape(B * H, S, D)
    vf = v_cache.reshape(B * H, S, D)
    lens = jnp.repeat(length.astype(jnp.int32), H).reshape(B * H, 1)

    smax = pl.pallas_call(
        functools.partial(_smax_kernel, mu=mu, scale=scale,
                          k_subtile=k_subtile, block_k=block_k, n_k=n_k),
        grid=(B * H, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda i, kk: (i, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, kk: (i, kk, 0)),
            pl.BlockSpec((1, 1), lambda i, kk: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, kk: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((), jnp.float32)],
        interpret=interpret,
    )(qf, kf, lens)

    out, nsel = pl.pallas_call(
        functools.partial(_decode_kernel, mu=mu, tau=tau, scale=scale,
                          k_subtile=k_subtile, block_k=block_k, n_k=n_k),
        grid=(B * H, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda i, kk: (i, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, kk: (i, kk, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, kk: (i, kk, 0)),
            pl.BlockSpec((1, 1), lambda i, kk: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, kk: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, D), lambda i, kk: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, kk: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, 1, D), jnp.float32),
            jax.ShapeDtypeStruct((B * H, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((), jnp.float32),
            pltpu.VMEM((), jnp.float32),
            pltpu.VMEM((), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, lens, smax)
    return out.reshape(B, H, 1, D), jnp.sum(nsel)
