"""Jit'd public wrappers for the Pallas kernels.

`interpret` defaults to True off-TPU (this container is CPU-only; interpret
mode executes the kernel bodies in Python for correctness validation) and
False on TPU, where the kernels compile to Mosaic. The REPRO_PALLAS_INTERPRET
env var overrides the default in both directions ("1" forces interpret mode,
"0" forces compiled); tests/conftest.py pins it to "1" so tier-1 tests always
exercise the real kernel code paths on CPU instead of skipping them.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from .flash_decode import flash_decode as _flash_decode
from .lamp_attention import lamp_flash_attention as _lamp_flash_attention
from .paged_attention import (
    paged_decode_attention as _paged_decode_attention,
    paged_mixed_attention as _paged_mixed_attention,
    paged_prefill_attention as _paged_prefill_attention,
)
from .ps_matmul import ps_matmul as _ps_matmul
from .rmsnorm import rmsnorm as _rmsnorm


def _default_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env:  # empty string == unset: fall through to the backend default
        return env.lower() not in ("0", "false")
    return jax.default_backend() != "tpu"


def lamp_flash_attention(q, k, v, *, mu: int = 7, tau: float = 0.05,
                         causal: bool = True, block_q: int = 128,
                         block_k: int = 128, k_subtile: int = 32,
                         interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _lamp_flash_attention(q, k, v, mu=mu, tau=tau, causal=causal,
                                 block_q=block_q, block_k=block_k,
                                 k_subtile=k_subtile, interpret=interpret)


def flash_decode(q, k_cache, v_cache, length, *, mu: int = 7, tau: float = 0.05,
                 block_k: int = 512, k_subtile: int = 32, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash_decode(q, k_cache, v_cache, length, mu=mu, tau=tau,
                         block_k=block_k, k_subtile=k_subtile,
                         interpret=interpret)


def paged_decode_attention(q, arena_k, arena_v, block_tables, lengths, site,
                           *, tau=None, window=None, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _paged_decode_attention(q, arena_k, arena_v, block_tables, lengths,
                                   site, tau=tau, window=window,
                                   interpret=interpret)


def paged_prefill_attention(q, arena_k, arena_v, block_tables, starts, site,
                            *, tau=None, qlens=None, window=None,
                            block_q=None, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _paged_prefill_attention(q, arena_k, arena_v, block_tables, starts,
                                    site, tau=tau, qlens=qlens, window=window,
                                    block_q=block_q, interpret=interpret)


def paged_mixed_attention(q, arena_k, arena_v, block_tables, starts, qlens,
                          site, *, tau=None, window=None, block_q=None,
                          interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _paged_mixed_attention(q, arena_k, arena_v, block_tables, starts,
                                  qlens, site, tau=tau, window=window,
                                  block_q=block_q, interpret=interpret)


def ps_matmul(a, b, *, mu: int = 7, block_m: int = 128, block_n: int = 128,
              block_k: int = 128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ps_matmul(a, b, mu=mu, block_m=block_m, block_n=block_n,
                      block_k=block_k, interpret=interpret)


def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _rmsnorm(x, w, eps=eps, block_rows=block_rows, interpret=interpret)
