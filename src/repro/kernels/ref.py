"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Each oracle reproduces the kernel's *exact* numerical semantics (same block
sizes, same PS(mu) rounding points, same running-threshold selection), so
tests can assert tight tolerances rather than loose "close enough" bounds.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.numerics import round_to_mantissa

_NEG = -1e30


def ps_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, mu: int, block_k: int) -> jnp.ndarray:
    """Oracle for the ps_matmul kernel: FP32 accumulation inside each
    K-subtile of size block_k, PS(mu) rounding of the running accumulator
    when each subtile's partial sum is added (TPU deployment tier)."""
    M, K = a.shape
    N = b.shape[1]
    nk = -(-K // block_k)
    pad = nk * block_k - K
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    acc = jnp.zeros((M, N), jnp.float32)
    for i in range(nk):
        part = a[:, i * block_k:(i + 1) * block_k].astype(jnp.float32) @ \
            b[i * block_k:(i + 1) * block_k].astype(jnp.float32)
        acc = round_to_mantissa(acc + part, mu) if mu < 23 else acc + part
    return acc


def _subtile_qk(q, kb, mu, sub):
    """(bq, D) x (D, bk) with PS(mu) subtile accumulation over D."""
    D = q.shape[-1]
    ns = -(-D // sub)
    acc = jnp.zeros((q.shape[0], kb.shape[1]), jnp.float32)
    for s in range(ns):
        part = q[:, s * sub:(s + 1) * sub] @ kb[s * sub:(s + 1) * sub]
        acc = round_to_mantissa(acc + part, mu) if mu < 23 else acc + part
    return acc


def lamp_flash_attention_ref(q, k, v, *, mu: int, tau: float, causal: bool,
                             block_q: int, block_k: int, k_subtile: int,
                             scale: Optional[float] = None,
                             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for the lamp_attention kernel.

    One-pass relaxed-LAMP flash attention: per (head, q-block), stream
    k-blocks; y_low from PS(mu)-subtile QK accumulation; select with rule (9)
    against the RUNNING max of s = y + log|y| (conservative tier); recompute
    selected logits exactly; online softmax. Returns (out, n_selected)."""
    B, H, T, D = q.shape
    S = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    out = jnp.zeros((B, H, T, D), jnp.float32)
    nsel_total = jnp.zeros((), jnp.float32)
    log_tau = jnp.log(jnp.maximum(tau, 1e-30))
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    nq, nk = -(-T // block_q), -(-S // block_k)
    for b in range(B):
        for h in range(H):
            for iq in range(nq):
                q0 = iq * block_q
                qb = qf[b, h, q0:q0 + block_q]
                m = jnp.full((qb.shape[0],), _NEG)
                l = jnp.zeros((qb.shape[0],))
                acc = jnp.zeros((qb.shape[0], D))
                smax = jnp.full((qb.shape[0],), _NEG)
                for ik in range(nk):
                    k0 = ik * block_k
                    kb = kf[b, h, k0:k0 + block_k].T
                    vb = vf[b, h, k0:k0 + block_k]
                    y_low = _subtile_qk(qb, kb, mu, k_subtile)
                    ok = jnp.ones(y_low.shape, bool)
                    if causal:
                        qi = q0 + jnp.arange(qb.shape[0])[:, None]
                        kj = k0 + jnp.arange(kb.shape[1])[None, :]
                        ok = kj <= qi
                    s = jnp.where(ok, y_low + jnp.log(jnp.abs(y_low)), _NEG)
                    smax = jnp.maximum(smax, jnp.max(s, axis=-1))
                    sel = ok & (s > log_tau + smax[:, None])
                    y_exact = qb @ kb
                    y = jnp.where(sel, y_exact, y_low)
                    y = jnp.where(ok, y, _NEG)
                    nsel_total = nsel_total + jnp.sum(sel)
                    m_new = jnp.maximum(m, jnp.max(y, axis=-1))
                    p = jnp.where(ok, jnp.exp(y - m_new[:, None]), 0.0)
                    corr = jnp.exp(m - m_new)
                    l = l * corr + jnp.sum(p, axis=-1)
                    acc = acc * corr[:, None] + p @ vb
                    m = m_new
                o = acc / jnp.maximum(l, 1e-30)[:, None]
                out = out.at[b, h, q0:q0 + block_q].set(o)
    return out, nsel_total


def flash_decode_ref(q, k_cache, v_cache, length, *, mu: int, tau: float,
                     block_k: int, k_subtile: int,
                     scale: Optional[float] = None,
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for the flash_decode kernel pair (exact two-pass rule (9)):
    pass 1 computes the global row max of s = y + log|y| over valid cache
    entries; pass 2 selects, recomputes, and online-softmaxes. q: (B,H,1,D),
    caches (B,H,S,D), length (B,)."""
    B, H, _, D = q.shape
    S = k_cache.shape[2]
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    log_tau = jnp.log(jnp.maximum(tau, 1e-30))
    out = jnp.zeros((B, H, 1, D), jnp.float32)
    nsel = jnp.zeros((), jnp.float32)
    nk = -(-S // block_k)
    for b in range(B):
        valid = jnp.arange(S) < length[b]
        for h in range(H):
            qr = qf[b, h, 0]
            # pass 1: y_low blocks + global smax
            smax = _NEG
            y_rows = []
            for ik in range(nk):
                k0 = ik * block_k
                kb = kf[b, h, k0:k0 + block_k].T
                y_low = _subtile_qk(qr[None], kb, mu, k_subtile)[0]
                okb = valid[k0:k0 + block_k]
                s = jnp.where(okb, y_low + jnp.log(jnp.abs(y_low)), _NEG)
                smax = jnp.maximum(smax, jnp.max(s))
                y_rows.append((y_low, s, okb))
            # pass 2
            m = _NEG
            l = 0.0
            acc = jnp.zeros((D,))
            for ik, (y_low, s, okb) in enumerate(y_rows):
                k0 = ik * block_k
                kb = kf[b, h, k0:k0 + block_k].T
                vb = vf[b, h, k0:k0 + block_k]
                sel = okb & (s > log_tau + smax)
                y_exact = (qr[None] @ kb)[0]
                y = jnp.where(sel, y_exact, y_low)
                y = jnp.where(okb, y, _NEG)
                nsel = nsel + jnp.sum(sel)
                m_new = jnp.maximum(m, jnp.max(y))
                p = jnp.where(okb, jnp.exp(y - m_new), 0.0)
                corr = jnp.exp(m - m_new)
                l = l * corr + jnp.sum(p)
                acc = acc * corr + p @ vb
                m = m_new
            out = out.at[b, h, 0].set(acc / jnp.maximum(l, 1e-30))
    return out, nsel


def rmsnorm_ref(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
            ).astype(x.dtype)
