"""Pallas TPU kernel: blocked matmul with PS(mu) accumulation.

C = A @ B with the paper's PS(mu) output format: each (block_m, block_k) x
(block_k, block_n) MXU pass accumulates in FP32, and the running (block_m,
block_n) accumulator tile in VMEM is rounded to PS(mu) every time a K-subtile
partial sum is folded in. This is the deployable TPU analogue of the paper's
``round(c + a*b)`` (granularity = block_k instead of 1; DESIGN.md Sec 5).

Grid: (n_m, n_n, n_k), K innermost (sequential), accumulator tile carried in
VMEM scratch.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.numerics import round_to_mantissa


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, mu: int, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    part = jax.lax.dot(a_ref[...].astype(jnp.float32),
                       b_ref[...].astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    acc = acc_ref[...] + part
    acc_ref[...] = round_to_mantissa(acc, mu) if mu < 23 else acc

    @pl.when(ik == n_k - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "mu", "block_m", "block_n", "block_k", "interpret"))
def ps_matmul(a: jnp.ndarray, b: jnp.ndarray, *, mu: int = 7,
              block_m: int = 128, block_n: int = 128, block_k: int = 128,
              interpret: bool = True) -> jnp.ndarray:
    """a (M, K) @ b (K, N) -> (M, N) float32 on the PS(mu) grid."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    if M % block_m or N % block_n or K % block_k:
        raise ValueError(f"{(M, N, K)} not divisible by blocks "
                         f"{(block_m, block_n, block_k)}")
    grid = (M // block_m, N // block_n, K // block_k)
    kernel = functools.partial(_kernel, mu=mu, n_k=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
