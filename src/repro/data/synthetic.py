"""Deterministic synthetic LM data: seeded, shardable, resumable.

The stream is a stateless function of (seed, step, position) -- any host can
materialize exactly its shard of any step without coordination, which is
what makes checkpoint-restart and elastic rescaling trivial (DESIGN.md).

Two generators:
  * `uniform_stream`   -- iid tokens (throughput testing)
  * `markov_stream`    -- order-1 Markov chain with a seeded random
    transition structure; gives nontrivial next-token structure so small
    models actually learn (loss decreases), used by the examples and tests.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "markov"  # markov | uniform
    branching: int = 8    # markov successors per token


_MASK64 = (1 << 64) - 1


def _fold(seed: int, *xs: int) -> np.uint64:
    h = (int(seed) ^ 0x9E3779B97F4A7C15) & _MASK64
    for x in xs:
        h = ((h ^ int(x)) * 0xBF58476D1CE4E5B9) & _MASK64
        h ^= h >> 31
    return np.uint64(h)


class SyntheticDataset:
    """Batch factory: batch_at(step) is pure and deterministic."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        if cfg.kind == "markov":
            # seeded successor table: token t -> branching candidates
            self._succ = rng.integers(0, cfg.vocab,
                                      size=(cfg.vocab, cfg.branching),
                                      dtype=np.int32)
        else:
            self._succ = None

    def batch_at(self, step: int, *, host_id: int = 0, n_hosts: int = 1
                 ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        if cfg.global_batch % n_hosts:
            raise ValueError("global_batch must divide across hosts")
        per_host = cfg.global_batch // n_hosts
        rows = np.arange(host_id * per_host, (host_id + 1) * per_host)
        out = np.empty((per_host, cfg.seq_len), np.int32)
        for i, row in enumerate(rows):
            h = _fold(cfg.seed, step, int(row))
            rng = np.random.default_rng(np.uint64(h))
            if cfg.kind == "uniform":
                out[i] = rng.integers(0, cfg.vocab, cfg.seq_len, dtype=np.int32)
            else:
                toks = np.empty(cfg.seq_len, np.int32)
                t = int(rng.integers(0, cfg.vocab))
                choices = rng.integers(0, cfg.branching, cfg.seq_len)
                for j in range(cfg.seq_len):
                    toks[j] = t
                    t = int(self._succ[t, choices[j]])
                out[i] = toks
        return {"tokens": out}

    def iter_from(self, step: int, **kw) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(step, **kw)
            step += 1
