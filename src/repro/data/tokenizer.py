"""Minimal byte-level tokenizer + document packing for real text files.

No external vocab needed offline: bytes 0..255 map to ids 0..255, with
specials appended. pack() concatenates documents with EOS separators into
fixed-length training rows (standard LM packing).
"""

from __future__ import annotations

from typing import Iterable, List

import numpy as np

BOS = 256
EOS = 257
PAD = 258
VOCAB = 259


def encode(text: str) -> List[int]:
    return [BOS] + list(text.encode("utf-8")) + [EOS]


def decode(ids: Iterable[int]) -> str:
    bs = bytes(i for i in ids if 0 <= i < 256)
    return bs.decode("utf-8", errors="replace")


def pack(docs: Iterable[str], seq_len: int) -> np.ndarray:
    """Pack encoded docs into (n_rows, seq_len) int32 with EOS separators."""
    buf: List[int] = []
    for d in docs:
        buf.extend(encode(d))
    n_rows = max(1, len(buf) // seq_len)
    need = n_rows * seq_len
    if len(buf) < need:
        buf.extend([PAD] * (need - len(buf)))
    arr = np.asarray(buf[:need], np.int32).reshape(n_rows, seq_len)
    return arr
