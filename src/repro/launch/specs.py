"""ShapeDtypeStruct stand-ins for every (arch x input-shape) cell.

No device allocation: params come from jax.eval_shape over the real
initializer, inputs/caches are ShapeDtypeStructs, and the dry-run lowers
against them directly.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, ModelConfig, get_config
from repro.models import api
from repro.optim import adamw


def params_shape(cfg: ModelConfig):
    return jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))


def opt_shape(p_shape):
    return jax.eval_shape(adamw.init_state, p_shape)


def batch_specs_for(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Training / prefill batch: tokens + stub modality inputs."""
    b: Dict[str, Any] = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.family == "whisper":
        b["frames"] = jax.ShapeDtypeStruct((batch, cfg.enc_seq, cfg.d_model),
                                           jnp.bfloat16)
    if cfg.family == "llava":
        b["image_embeds"] = jax.ShapeDtypeStruct((batch, cfg.n_patches, cfg.d_model),
                                                 jnp.bfloat16)
    return b


def cache_shape(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: api.init_cache(cfg, batch, max_len, jnp.bfloat16))


def input_specs(arch: str, shape_name: str) -> Dict[str, Any]:
    """Everything the dry-run needs for one cell (shapes only)."""
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    out: Dict[str, Any] = {"cfg": cfg, "shape": shp}
    p_shape = params_shape(cfg)
    out["params"] = p_shape
    if shp.kind == "train":
        out["batch"] = batch_specs_for(cfg, shp.global_batch, shp.seq_len)
        out["opt"] = opt_shape(p_shape)
    elif shp.kind == "prefill":
        out["batch"] = batch_specs_for(cfg, shp.global_batch, shp.seq_len)
        out["cache"] = cache_shape(cfg, shp.global_batch,
                                   shp.seq_len + cfg.n_patches)
    elif shp.kind == "decode":
        out["tokens"] = jax.ShapeDtypeStruct((shp.global_batch, 1), jnp.int32)
        out["cache"] = cache_shape(cfg, shp.global_batch,
                                   shp.seq_len + cfg.n_patches)
    return out
