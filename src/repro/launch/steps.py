"""Jittable step functions: train_step, prefill_step, serve_step.

These are the units the dry-run lowers and the launchers execute. Training
uses remat'd scan-over-layers + optional microbatch gradient accumulation;
serving runs with the LAMP policy enabled (the paper's technique is an
inference-time feature).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import api
from repro.optim import adamw


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, *,
                    num_microbatches: int = 1, attn_impl: str = "auto",
                    moe_groups: int = 1, use_lamp: bool = False,
                    lr_schedule=None, model_kwargs: Optional[Dict] = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""
    model_kwargs = model_kwargs or {}

    def lossf(p, b):
        return api.loss_fn(cfg, p, b, remat=True, attn_impl=attn_impl,
                           moe_groups=moe_groups, use_lamp=use_lamp,
                           **model_kwargs)

    def train_step(params, opt_state, batch):
        if num_microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(lossf, has_aux=True)(params, batch)
        else:
            M = num_microbatches

            def split(x):
                return x.reshape(M, x.shape[0] // M, *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc(carry, b):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(lossf, has_aux=True)(params, b)
                g_acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / M, grads)
            loss = loss / M
            metrics = {}
        lr = lr_schedule(opt_state.step) if lr_schedule is not None else None
        params, opt_state, om = adamw.apply_updates(opt_cfg, params, grads,
                                                    opt_state, lr=lr)
        return params, opt_state, {"loss": loss, **om}

    return train_step


def make_prefill_step(cfg, *, use_lamp: bool = True, attn_impl: str = "auto",
                      moe_groups: int = 1, model_kwargs: Optional[Dict] = None):
    model_kwargs = model_kwargs or {}

    def prefill_step(params, cache, batch):
        return api.prefill(cfg, params, batch, cache, use_lamp=use_lamp,
                           attn_impl=attn_impl,
                           **({"moe_groups": moe_groups}
                              if cfg.family == "moe" else {}),
                           **model_kwargs)
    return prefill_step


def make_serve_step(cfg, *, use_lamp: bool = True,
                    model_kwargs: Optional[Dict] = None):
    """One batched decode step: (params, cache, tokens) -> (logits, cache)."""
    model_kwargs = model_kwargs or {}

    def serve_step(params, cache, tokens):
        return api.decode_step(cfg, params, cache, tokens, use_lamp=use_lamp,
                               **model_kwargs)
    return serve_step
