"""Production mesh builders (assignment spec).

Single pod: (16, 16) = (data, model) -- 256 chips of TPU v5e.
Multi-pod:  (2, 16, 16) = (pod, data, model) -- 512 chips.

Functions, not module constants: importing this module never touches jax
device state (required so smoke tests see 1 CPU device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over whatever local devices exist (tests / examples)."""
    n = len(jax.devices())
    data = max(1, n // model_axis)
    return jax.make_mesh((data, model_axis), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware constants for the roofline model (assignment spec).
PEAK_FLOPS_BF16 = 197e12     # FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW_PER_LINK = 50e9       # bytes/s per link
