"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts a while-loop body ONCE,
not multiplied by its trip count (verified empirically on the CPU backend:
a scan of 8 matmuls reports 1/8 of the unrolled flops). Our models are
scan-over-layers by design, so the built-in numbers under-report by ~n_layers
(and by the kv-block count inside chunked attention, and by T for SSM scans).

This module re-derives cost from ``compiled.as_text()``:

  * parses every computation and its ops (result shape, operand shapes),
  * builds the call graph (fusion `calls=`, `to_apply=`, while
    `condition=/body=`, conditional branches),
  * extracts while trip counts from the loop-condition's comparison constant,
  * computes, bottom-up with loop multiplication:
      - flops: dot ops (2 x result numel x contraction size) -- matmuls
        dominate transformer compute; elementwise flops are ignored (the VPU
        term is folded into the memory roof)
      - bytes: 2 x result bytes of every materializing op (write + read
        proxy), parameters read once
      - collective bytes per category (all-gather / all-reduce /
        reduce-scatter / all-to-all / collective-permute), result-shape
        bytes, multiplied by enclosing loop trips.

Shapes in post-SPMD compiled HLO are per-device, so all outputs are
per-device quantities.
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_CALL_ATTRS = ("calls=", "to_apply=", "condition=", "body=",
               "true_computation=", "false_computation=", "branch_computations=")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(m: re.Match) -> int:
    return _numel(m.group(2)) * _DTYPE_BYTES.get(m.group(1), 4)


class Op:
    __slots__ = ("name", "kind", "result_bytes", "flops", "callees",
                 "coll_kind", "coll_bytes", "cond", "body", "is_root",
                 "dus_bytes")

    def __init__(self):
        self.kind = ""
        self.result_bytes = 0
        self.flops = 0.0
        self.callees: List[str] = []
        self.coll_kind: Optional[str] = None
        self.coll_bytes = 0
        self.cond: Optional[str] = None
        self.body: Optional[str] = None
        self.is_root = False
        self.dus_bytes: Optional[int] = None   # update-slice bytes for DUS


_SKIP_KINDS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id",
}

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\(", )


def _dot_flops(line: str, result_numel: int,
               symtab: Dict[str, List[int]]) -> float:
    """2 x result numel x contraction size. Scheduled HLO omits operand
    types on the op line, so the lhs shape is resolved via the symbol table
    (falling back to an inline shape if present)."""
    mdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    if not mdims:
        return 2.0 * result_numel  # degenerate
    paren = line[line.index("dot(") + 4:]
    lhs_dims: List[int] = []
    mshape = _SHAPE_RE.search(paren.split(",")[0])
    if mshape:
        lhs_dims = [int(d) for d in mshape.group(2).split(",") if d]
    else:
        mname = re.search(r"%([\w\.\-]+)", paren)
        if mname and mname.group(1) in symtab:
            lhs_dims = symtab[mname.group(1)]
    contr = 1
    for i in (int(x) for x in mdims.group(1).split(",") if x):
        if i < len(lhs_dims):
            contr *= lhs_dims[i]
    return 2.0 * result_numel * contr


def parse_computations(hlo: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    symtab: Dict[str, List[int]] = {}   # op name -> result dims (global)
    cur: Optional[str] = None
    # pass 1: symbol table (names are unique module-wide in HLO)
    for line in hlo.splitlines():
        m = _OP_RE.match(line)
        if m:
            shapes = list(_SHAPE_RE.finditer(m.group(2)))
            if shapes:
                symtab[m.group(1)] = [int(d) for d in
                                      shapes[0].group(2).split(",") if d]
    # pass 2: ops
    for line in hlo.splitlines():
        stripped = line.strip()
        is_hdr = "->" in stripped and stripped.endswith("{")
        hdr = _COMP_HDR.match(stripped) if is_hdr else None
        if hdr:
            cur = hdr.group(1)
            comps[cur] = []
            continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        result_sig, kind = m.group(2), m.group(3)
        op = Op()
        op.kind = kind
        op.is_root = line.lstrip().startswith("ROOT")
        # result bytes: sum all shapes before the op name (tuple results)
        op.result_bytes = sum(_shape_bytes(s)
                              for s in _SHAPE_RE.finditer(result_sig))
        result_numel = sum(_numel(s.group(2))
                           for s in _SHAPE_RE.finditer(result_sig)) or 1
        if kind == "dot":
            op.flops = _dot_flops(line, result_numel, symtab)
        if kind == "dynamic-update-slice":
            # DUS writes only the update slice (aliased in place); the
            # printed result shape is the full operand -- charge the slice.
            ops_str = line[line.index("dynamic-update-slice(") + 22:]
            names = re.findall(r"%([\w\.\-]+)", ops_str)
            if len(names) >= 2 and names[1] in symtab:
                upd = symtab[names[1]]
                n = 1
                for d in upd:
                    n *= d
                op.dus_bytes = n * 4  # dtype unknown from name; assume f32
                # refine with inline shape if present
                shapes = list(_SHAPE_RE.finditer(ops_str))
                if len(shapes) >= 2:
                    op.dus_bytes = _shape_bytes(shapes[1])
        for attr in _CALL_ATTRS:
            for cm in re.finditer(re.escape(attr) + r"\{?%?([\w\.\-]+)", line):
                name = cm.group(1)
                if attr == "condition=":
                    op.cond = name
                elif attr == "body=":
                    op.body = name
                else:
                    op.callees.append(name)
        base = kind[:-6] if kind.endswith("-start") else kind
        if base in _COLLECTIVES and not kind.endswith("-done"):
            op.coll_kind = base
            op.coll_bytes = op.result_bytes
        comps.setdefault(cur, []).append(op)
    return comps


def _root_of(comps: Dict[str, List[Op]], name: str) -> Optional[Op]:
    for op in comps.get(name, []):
        if op.is_root:
            return op
    return None


def _trip_count(cond_ops: List[Op], cond_text_constants: List[int]) -> int:
    """Max s32 constant in the loop condition ~ scan trip count."""
    if cond_text_constants:
        return max(cond_text_constants)
    return 1


def _cond_constants(hlo: str) -> Dict[str, List[int]]:
    """Map computation name -> s32 constants appearing in it."""
    out: Dict[str, List[int]] = {}
    cur = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR.match(line.strip()) if ("->" in line and "{" in line) else None
        if hdr:
            cur = hdr.group(1)
            out[cur] = []
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        for cm in re.finditer(r"[su]32\[\]\s+constant\((\d+)\)", line):
            out[cur].append(int(cm.group(1)))
    return out


def analyze(hlo: str) -> Dict[str, float]:
    """Trip-count-aware totals (per device)."""
    comps = parse_computations(hlo)
    consts = _cond_constants(hlo)
    memo: Dict[str, Dict[str, float]] = {}

    def cost_of(name: str, stack: Tuple[str, ...] = (),
                in_fusion: bool = False) -> Dict[str, float]:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        if name not in comps or name in stack:
            return {"flops": 0.0, "bytes": 0.0,
                    **{f"coll_{c}": 0.0 for c in _COLLECTIVES}}
        total = {"flops": 0.0, "bytes": 0.0,
                 **{f"coll_{c}": 0.0 for c in _COLLECTIVES}}
        for op in comps[name]:
            if op.kind == "while" and op.body is not None:
                trips = _trip_count(comps.get(op.cond or "", []),
                                    consts.get(op.cond or "", []))
                sub = cost_of(op.body, stack + (name,), in_fusion)
                subc = cost_of(op.cond, stack + (name,), in_fusion) \
                    if op.cond else {k: 0.0 for k in total}
                for k in total:
                    total[k] += trips * (sub[k] + subc[k])
                total["bytes"] += op.result_bytes * 2
                continue
            if op.kind in _SKIP_KINDS:
                continue
            total["flops"] += op.flops
            # ops inside a fusion stay in registers/VMEM; only the fusion's
            # own result materializes (counted at the call site below)
            if not in_fusion:
                eff = op.result_bytes
                if op.kind == "dynamic-update-slice" and op.dus_bytes is not None:
                    eff = op.dus_bytes
                elif op.kind == "fusion" and op.callees:
                    # DUS-rooted fusions update in place: charge the slice
                    root = _root_of(comps, op.callees[0])
                    if root is not None and root.kind == "dynamic-update-slice" \
                            and root.dus_bytes is not None:
                        eff = root.dus_bytes
                total["bytes"] += eff * 2
            if op.coll_kind:
                total[f"coll_{op.coll_kind}"] += op.coll_bytes
            fused_call = op.kind == "fusion"
            for c in op.callees:
                sub = cost_of(c, stack + (name,), in_fusion or fused_call)
                for k in total:
                    total[k] += sub[k]
        memo[key] = total
        return total

    # entry computation: the one named like main / entry, else the largest
    entry = None
    for name in comps:
        if "main" in name or name.startswith("entry"):
            entry = name
            break
    if entry is None and comps:
        entry = max(comps, key=lambda n: len(comps[n]))
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}
    # computations reachable only via call attrs are not double counted:
    # cost_of(entry) covers everything transitively.
    t = cost_of(entry)
    coll = {c: t[f"coll_{c}"] for c in _COLLECTIVES}
    return {"flops": t["flops"], "bytes": t["bytes"],
            "collective_bytes": sum(coll.values()),
            "collectives": coll}
