import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first (before any jax import) -- jax locks the
device count at first init, and only the dry-run wants 512 host devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]

Each cell writes results/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, collective bytes, and roofline terms.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, shape_applicable
from repro.distributed import sharding as SH
from repro.launch import roofline as RL
from repro.launch import specs as SP
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.optim import adamw

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _mesh_name(multi_pod: bool) -> str:
    return "multi" if multi_pod else "single"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None) -> dict:
    """Lower + compile one cell; return the result record."""
    t0 = time.time()
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shp)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": _mesh_name(multi_pod),
                "status": "skipped", "reason": reason}

    overrides = overrides or {}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    cell = SP.input_specs(arch, shape_name)
    p_shape = cell["params"]
    pspecs = SH.param_specs(p_shape, mesh)
    data_shards = mesh.shape["data"] * mesh.shape.get("pod", 1)

    # optimized-system defaults (EXPERIMENTS Sec Perf); explicit overrides win
    mk = dict(overrides.get("model_kwargs") or {})
    if cfg.family == "rwkv6" and shp.kind in ("train", "prefill"):
        mk.setdefault("wkv_chunk", 64)
    if cfg.family == "moe" and shp.kind == "decode":
        mk.setdefault("moe_dropless", False)
        mk.setdefault("moe_groups",
                      data_shards if shp.global_batch % data_shards == 0 else 1)
    overrides = {**overrides, "model_kwargs": mk}

    with jax.set_mesh(mesh):  # lets shard_hint() resolve logical axis names
        if shp.kind == "train":
            opt_cfg = adamw.AdamWConfig()
            step = ST.make_train_step(
                cfg, opt_cfg,
                num_microbatches=overrides.get("num_microbatches", 1),
                attn_impl=overrides.get("attn_impl", "auto"),
                moe_groups=overrides.get("moe_groups",
                                         data_shards if cfg.family == "moe" else 1),
                model_kwargs=overrides.get("model_kwargs"))
            ospecs = SH.opt_specs(cell["opt"], pspecs)
            bspecs = SH.batch_specs(cell["batch"], mesh)
            jitted = jax.jit(step, in_shardings=(pspecs, ospecs, bspecs),
                             out_shardings=(pspecs, ospecs, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(p_shape, cell["opt"], cell["batch"])
        elif shp.kind == "prefill":
            step = ST.make_prefill_step(
                cfg, use_lamp=overrides.get("use_lamp", True),
                attn_impl=overrides.get("attn_impl", "auto"),
                moe_groups=overrides.get("moe_groups",
                                         data_shards if cfg.family == "moe" else 1),
                model_kwargs=overrides.get("model_kwargs"))
            cspecs = SH.cache_specs(cell["cache"], mesh)
            bspecs = SH.batch_specs(cell["batch"], mesh)
            jitted = jax.jit(step, in_shardings=(pspecs, cspecs, bspecs),
                             donate_argnums=(1,))
            lowered = jitted.lower(p_shape, cell["cache"], cell["batch"])
        else:  # decode
            step = ST.make_serve_step(cfg, use_lamp=overrides.get("use_lamp", True),
                                      model_kwargs=overrides.get("model_kwargs"))
            cspecs = SH.cache_specs(cell["cache"], mesh)
            tspec = SH.batch_specs(cell["tokens"], mesh,
                                   shard_batch=shp.global_batch % data_shards == 0)
            jitted = jax.jit(step, in_shardings=(pspecs, cspecs, tspec),
                             donate_argnums=(1,))
            lowered = jitted.lower(p_shape, cell["cache"], cell["tokens"])

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    rec = {"arch": arch, "shape": shape_name, "mesh": _mesh_name(multi_pod),
           "status": "ok", "n_devices": int(n_dev),
           "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
           "overrides": overrides}

    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_est": int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}

    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))

    # Trip-count-aware re-analysis: XLA's cost_analysis counts while-loop
    # (scan) bodies once, under-reporting scan-over-layers models by ~L
    # (see launch/hlo_cost.py). All roofline terms use the corrected values.
    from repro.launch import hlo_cost
    hlo = compiled.as_text()
    hc = hlo_cost.analyze(hlo)
    flops = hc["flops"]
    byts = hc["bytes"]
    rec["cost"] = {
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "xla_flops_raw_loopbody_once": xla_flops,
        "xla_bytes_raw_loopbody_once": xla_bytes,
    }
    rec["collectives"] = hc["collectives"]
    coll_total = float(hc["collective_bytes"])
    rec["roofline"] = RL.roofline_terms(flops, byts, coll_total)

    mf = RL.model_flops(cfg, p_shape, shp.kind, shp.global_batch, shp.seq_len)
    rec["model_flops_total"] = mf
    rec["model_flops_per_device"] = mf / n_dev
    rec["useful_flops_ratio"] = (mf / n_dev) / flops if flops else 0.0
    rec.update(RL.active_params(p_shape, cfg))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(RESULTS))
    ap.add_argument("--overrides", default=None,
                    help="JSON dict, e.g. '{\"num_microbatches\": 4}'")
    ap.add_argument("--tag", default="", help="suffix for override experiments")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    overrides = json.loads(args.overrides) if args.overrides else None

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"__{args.tag}" if args.tag else ""
                fname = outdir / f"{arch}__{shape}__{_mesh_name(mp)}{tag}.json"
                if fname.exists() and not args.force:
                    print(f"[cached] {fname.name}")
                    continue
                print(f"[run] {arch} x {shape} x {_mesh_name(mp)} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mp, overrides)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": _mesh_name(mp), "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                rec["overrides_tag"] = args.tag
                fname.write_text(json.dumps(rec, indent=1))
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "error"
                extra = ""
                if st == "ok":
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']} "
                             f"c={r['compute_s']:.3g}s m={r['memory_s']:.3g}s "
                             f"x={r['collective_s']:.3g}s "
                             f"compile={rec['compile_s']}s")
                elif st == "error":
                    extra = " " + rec["error"][:160]
                print(f"[{st}] {fname.name}{extra}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} failed={n_fail}")


if __name__ == "__main__":
    main()
