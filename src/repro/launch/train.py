"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --reduced \
        --steps 50 --batch 8 --seq 128

Full-size configs target the production mesh (run under a real TPU runtime);
--reduced trains the family-preserving smoke config on the host mesh, which
is what this CPU container can execute end-to-end.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs, reduced as reduce_cfg
from repro.data.synthetic import DataConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim import adamw
from repro.runtime.train_loop import TrainLoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_host_mesh())

    loop = TrainLoopConfig(total_steps=args.steps,
                           checkpoint_every=args.checkpoint_every,
                           checkpoint_dir=args.checkpoint_dir,
                           num_microbatches=args.microbatches)
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)

    extra = None
    if cfg.family == "whisper":
        def extra(step):
            return {"frames": jax.random.normal(
                jax.random.PRNGKey(step), (args.batch, cfg.enc_seq, cfg.d_model),
                jnp.float32) * 0.1}
    elif cfg.family == "llava":
        def extra(step):
            return {"image_embeds": jax.random.normal(
                jax.random.PRNGKey(step), (args.batch, cfg.n_patches, cfg.d_model),
                jnp.float32) * 0.1}

    out = train(cfg, mesh, loop, adamw.AdamWConfig(lr=args.lr),
                data_cfg=data, extra_batch=extra)
    losses = [m["loss"] for m in out["metrics"]]
    if losses:
        print(f"[train] first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
