"""Serving launcher: continuous-batching LAMP engine under a synthetic
Poisson request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch gpt2 --reduced \
        --qps 8 --num-requests 32

Requests arrive with exponential inter-arrival times at `--qps`, with
prompt/output lengths drawn per request; the engine admits them into the
paged KV pool, continuously batches prefill/decode, and reports throughput,
latency percentiles, KV-block utilization, and the per-request/aggregate
LAMP recompute rate.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs, reduced as reduce_cfg
from repro.models import api
from repro.serving import EngineConfig, LampEngine, SamplingParams
from repro.serving.engine import TEXT_FAMILIES


def servable_archs():
    """Archs the paged-KV engine can serve (see engine.TEXT_FAMILIES)."""
    return [a for a in list_archs()
            if get_config(a).family in TEXT_FAMILIES]


def build_stream(rng: np.random.Generator, args, vocab: int):
    """Synthetic Poisson stream: (arrival_s, prompt, sampling) per request.
    With --shared-prefix N, every prompt opens with the same N tokens (a
    shared system prompt), the traffic shape prefix caching exists for."""
    arrivals = np.cumsum(rng.exponential(1.0 / args.qps, args.num_requests))
    shared = rng.integers(0, vocab, size=args.shared_prefix).tolist() \
        if args.shared_prefix else []
    reqs = []
    for i in range(args.num_requests):
        plen = int(rng.integers(args.min_prompt, args.max_prompt + 1))
        new = int(rng.integers(args.min_new, args.max_new + 1))
        prompt = shared + rng.integers(0, vocab, size=plen).tolist()
        sampling = SamplingParams(max_new_tokens=new,
                                  temperature=args.temperature, seed=i,
                                  top_k=args.top_k)
        reqs.append((float(arrivals[i]), prompt, sampling))
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=servable_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--qps", type=float, default=8.0,
                    help="Poisson arrival rate (requests/s)")
    ap.add_argument("--num-requests", type=int, default=32)
    ap.add_argument("--min-prompt", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--min-new", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--n-blocks", type=int, default=0,
                    help="KV pool size in blocks (0 = auto)")
    ap.add_argument("--max-model-len", type=int, default=0)
    ap.add_argument("--max-prefill-tokens", type=int, default=2048,
                    help="prefill-step token budget = chunk size")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="share prompt-prefix KV blocks across requests "
                         "(copy-on-write)")
    ap.add_argument("--chunked-prefill",
                    action=argparse.BooleanOptionalAction, default=True,
                    help="prefill long prompts in max-prefill-tokens chunks "
                         "so decode steps interleave")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a common prefix of this many tokens to "
                         "every prompt (prefix-cache traffic)")
    ap.add_argument("--kernel", choices=("gather", "pallas"),
                    default="gather",
                    help="paged-attention path: 'gather' materializes the "
                         "block-table span (reference); 'pallas' fuses the "
                         "block gather into the attention kernel (fast path "
                         "on TPU; interpret mode on CPU)")
    ap.add_argument("--speculative", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="LAMP self-draft speculative decoding: draft with "
                         "the pure low-precision forward (rule 'none'), "
                         "verify all drafted positions in one multi-token "
                         "LAMP forward (greedy outputs identical to "
                         "non-speculative decoding)")
    ap.add_argument("--draft-len", type=int, default=4,
                    help="speculative draft tokens per sequence per round")
    ap.add_argument("--top-k", type=int, default=0,
                    help="sample from the top-k logits only (0 = "
                         "unfiltered); also the filter the speculative "
                         "accept rule scores against")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-lamp", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    longest = args.shared_prefix + args.max_prompt
    max_len = args.max_model_len or min(cfg.max_seq,
                                        longest + args.max_new + 8)
    if args.min_prompt > args.max_prompt or args.min_new > args.max_new:
        ap.error("--min-prompt/--min-new must not exceed --max-prompt/--max-new")
    if longest + args.max_new > max_len:
        ap.error(f"shared prefix + max prompt + max new "
                 f"({longest + args.max_new}) exceeds the model length "
                 f"budget {max_len}; raise --max-model-len "
                 f"(<= cfg.max_seq {cfg.max_seq}) or shrink the request sizes")
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=args.block_size, n_blocks=args.n_blocks,
        max_model_len=max_len, use_lamp=not args.no_lamp,
        max_prefill_tokens=args.max_prefill_tokens,
        prefix_cache=args.prefix_cache,
        chunked_prefill=args.chunked_prefill,
        kernel=args.kernel, speculative=args.speculative,
        draft_len=args.draft_len))

    rng = np.random.default_rng(args.seed)
    stream = build_stream(rng, args, cfg.vocab)
    print(f"[serve] arch={cfg.name} lamp={not args.no_lamp} "
          f"qps={args.qps} requests={args.num_requests} "
          f"pool={engine.pool.num_total}x{engine.pool.block_size} blocks "
          f"prefix_cache={args.prefix_cache} "
          f"chunked_prefill={args.chunked_prefill} kernel={args.kernel}")

    t0 = time.monotonic()
    i, outputs = 0, []
    while i < len(stream) or engine.has_unfinished():
        now = time.monotonic() - t0
        while i < len(stream) and stream[i][0] <= now:
            arr, prompt, sampling = stream[i]
            engine.add_request(prompt, sampling, arrival_time=t0 + arr)
            i += 1
        done = engine.step()
        outputs.extend(done)
        for o in done:
            print(f"[serve]   req {o.req_id:>3d} done: prompt={len(o.prompt)} "
                  f"new={len(o.tokens)} latency={o.latency*1e3:7.1f}ms "
                  f"ttft={o.ttft*1e3:7.1f}ms preempt={o.num_preemptions} "
                  f"cached={o.num_cached_tokens} "
                  f"lamp_rate={o.lamp_recompute_rate:.4f}")
        if not engine.has_unfinished() and i < len(stream):
            time.sleep(max(0.0, stream[i][0] - (time.monotonic() - t0)))

    s = engine.stats()
    mean_rate = (np.mean([o.lamp_recompute_rate for o in outputs])
                 if outputs else 0.0)
    print(f"[serve] finished {s['num_finished']}/{args.num_requests} "
          f"in {s['elapsed_s']:.2f}s "
          f"({s['prefill_steps']} prefill / {s['decode_steps']} decode steps, "
          f"{s['preemptions']} preemptions)")
    print(f"[serve] throughput {s['tokens_per_s']:.1f} tok/s, "
          f"{s['requests_per_s']:.2f} req/s")
    print(f"[serve] latency p50 {s['latency_p50_s']*1e3:.0f}ms  "
          f"p99 {s['latency_p99_s']*1e3:.0f}ms  "
          f"ttft p50 {s['ttft_p50_s']*1e3:.0f}ms")
    print(f"[serve] kv-block utilization mean {s['kv_util_mean']:.2%} "
          f"peak {s['kv_util_peak']:.2%}")
    print(f"[serve] prefix cache: hit rate {s['cache_hit_rate']:.2%} "
          f"({s['cached_tokens']} cached / {s['prefill_tokens_run']} run "
          f"tokens), {s['blocks_saved']} blocks saved / "
          f"{s['blocks_allocated']} allocated, {s['cow_copies']} COW copies, "
          f"{s['cache_evictions']} evictions, "
          f"{s['prefill_chunks']} prefill chunks")
    print(f"[serve] LAMP recompute rate: aggregate "
          f"{s['lamp_recompute_rate']:.4f}, per-request mean {mean_rate:.4f}")
    if args.speculative:
        acc = [o.spec_acceptance_rate for o in outputs if o.spec_drafted]
        print(f"[serve] speculative: {s['spec_rounds']} rounds, "
              f"acceptance {s['spec_acceptance_rate']:.2%} "
              f"(per-request mean {np.mean(acc) if acc else 0.0:.2%}), "
              f"{s['spec_tokens_per_round']:.2f} tokens/round, "
              f"verify recompute rate {s['verify_recompute_rate']:.4f}")


if __name__ == "__main__":
    main()
