"""Serving launcher: batched LAMP inference demo.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs, reduced as reduce_cfg
from repro.runtime.serve_loop import ServeConfig, generate
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--no-lamp", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len),
                                          0, cfg.vocab)}
    if cfg.family == "whisper":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.enc_seq, cfg.d_model)) * 0.1
    if cfg.family == "llava":
        batch["image_embeds"] = jax.random.normal(
            key, (args.batch, cfg.n_patches, cfg.d_model)) * 0.1

    serve = ServeConfig(max_new_tokens=args.new_tokens,
                        temperature=args.temperature,
                        use_lamp=not args.no_lamp,
                        cache_len=args.prompt_len + args.new_tokens
                        + cfg.n_patches + cfg.n_meta_tokens + 8)
    out = generate(cfg, params, batch, serve)
    print(f"[serve] arch={cfg.name} lamp={not args.no_lamp}")
    print(f"[serve] prefill {out['prefill_s']*1e3:.0f}ms, "
          f"decode {out['decode_tok_per_s']:.1f} tok/s")
    print(f"[serve] sample tokens: {out['tokens'][0][:16].tolist()}")


if __name__ == "__main__":
    main()
