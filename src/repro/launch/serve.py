"""Serving launcher: continuous-batching LAMP engine under a synthetic
Poisson request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch gpt2 --reduced \
        --qps 8 --num-requests 32

Requests arrive with exponential inter-arrival times at `--qps`, with
prompt/output lengths drawn per request; the engine admits them into the
paged KV pool, continuously batches prefill/decode, and reports throughput,
latency percentiles, KV-block utilization, and the per-request/aggregate
LAMP recompute rate.

Observability hooks: `--metrics-every S` prints a one-line registry
snapshot every S seconds of stream time; `--trace-out f.json` records
step-phase spans and writes a Chrome trace (load it at https://ui.perfetto.dev
or chrome://tracing); `--metrics-out f.json` dumps the final metrics
registry snapshot; `--jax-profile DIR` wraps the run in
`jax.profiler.trace`. All loop timing -- arrivals, idle sleeps, the
periodic snapshot cadence -- runs off the engine's single injectable clock
(`engine.obs.now`), so `serve_stream` is deterministic under a fake clock.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.configs import get_config, list_archs, reduced as reduce_cfg
from repro.models import api
from repro.obs import ObsConfig
from repro.serving import (AuditConfig, EngineConfig, LampEngine,
                           PolicyConfig, SamplingParams)
from repro.serving.engine import TEXT_FAMILIES


def servable_archs():
    """Archs the paged-KV engine can serve (see engine.TEXT_FAMILIES)."""
    return [a for a in list_archs()
            if get_config(a).family in TEXT_FAMILIES]


def build_stream(rng: np.random.Generator, args, vocab: int):
    """Synthetic Poisson stream: (arrival_s, prompt, sampling) per request.
    With --shared-prefix N, every prompt opens with the same N tokens (a
    shared system prompt), the traffic shape prefix caching exists for."""
    arrivals = np.cumsum(rng.exponential(1.0 / args.qps, args.num_requests))
    shared = rng.integers(0, vocab, size=args.shared_prefix).tolist() \
        if args.shared_prefix else []
    reqs = []
    for i in range(args.num_requests):
        plen = int(rng.integers(args.min_prompt, args.max_prompt + 1))
        new = int(rng.integers(args.min_new, args.max_new + 1))
        prompt = shared + rng.integers(0, vocab, size=plen).tolist()
        sampling = SamplingParams(max_new_tokens=new,
                                  temperature=args.temperature, seed=i,
                                  top_k=args.top_k)
        reqs.append((float(arrivals[i]), prompt, sampling))
    return reqs


def metrics_line(engine: LampEngine, elapsed: float) -> str:
    """One-line live snapshot for periodic progress logging. Carries the
    policy mode and the audited flip rate so a burst-load run is readable
    from the log alone: "mode=shed" explains a rate drop, and a flip-rate
    spike says the degradation is costing real tokens."""
    s = engine.stats()
    mode = s["policy"]["mode"] if s["policy"]["enabled"] else "off"
    audit = s["audit"]
    flips = (f"{audit['flip_rate']:.3f}" if audit["enabled"] else "-")
    return (f"[serve] t={elapsed:7.2f}s live={s['live_requests']:>3d} "
            f"done={s['num_finished']:>3d} steps={s['steps']} "
            f"tok/s={s['tokens_per_s']:7.1f} "
            f"kv_util={s['kv_util_peak']:.0%} "
            f"lamp_rate={s['lamp_recompute_rate']:.4f} "
            f"mode={mode} audit_flips={flips} "
            f"compiles={s['compiles']}")


def serve_stream(engine: LampEngine, stream, *,
                 metrics_every: float = 0.0,
                 sleep: Optional[Callable[[float], None]] = None,
                 log: Callable[[str], None] = print,
                 per_request: bool = True) -> List:
    """Drive the engine over a pre-built (arrival_s, prompt, sampling)
    stream. Every timestamp -- arrivals, idle waits, the snapshot cadence --
    comes from the engine's own clock (`engine.obs.now`), so a fake clock
    plus a clock-advancing `sleep` makes the whole loop deterministic."""
    clock = engine.obs.now
    if sleep is None:
        sleep = time.sleep
    t0 = clock()
    next_metrics = metrics_every
    i, outputs = 0, []
    while i < len(stream) or engine.has_unfinished():
        now = clock() - t0
        while i < len(stream) and stream[i][0] <= now:
            arr, prompt, sampling = stream[i]
            engine.add_request(prompt, sampling, arrival_time=t0 + arr)
            i += 1
        done = engine.step()
        outputs.extend(done)
        if per_request:
            for o in done:
                log(f"[serve]   req {o.req_id:>3d} done: "
                    f"prompt={len(o.prompt)} new={len(o.tokens)} "
                    f"latency={o.latency * 1e3:7.1f}ms "
                    f"ttft={o.ttft * 1e3:7.1f}ms "
                    f"preempt={o.num_preemptions} "
                    f"cached={o.num_cached_tokens} "
                    f"lamp_rate={o.lamp_recompute_rate:.4f}")
        if metrics_every > 0 and clock() - t0 >= next_metrics:
            log(metrics_line(engine, clock() - t0))
            next_metrics += metrics_every
        if not engine.has_unfinished() and i < len(stream):
            sleep(max(0.0, stream[i][0] - (clock() - t0)))
    return outputs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=servable_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--qps", type=float, default=8.0,
                    help="Poisson arrival rate (requests/s)")
    ap.add_argument("--num-requests", type=int, default=32)
    ap.add_argument("--min-prompt", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--min-new", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--n-blocks", type=int, default=0,
                    help="KV pool size in blocks (0 = auto)")
    ap.add_argument("--max-model-len", type=int, default=0)
    ap.add_argument("--max-prefill-tokens", type=int, default=2048,
                    help="prefill-step token budget = chunk size")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="share prompt-prefix KV blocks across requests "
                         "(copy-on-write)")
    ap.add_argument("--chunked-prefill",
                    action=argparse.BooleanOptionalAction, default=True,
                    help="prefill long prompts in max-prefill-tokens chunks "
                         "so decode steps interleave")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a common prefix of this many tokens to "
                         "every prompt (prefix-cache traffic)")
    ap.add_argument("--kernel", choices=("gather", "pallas"),
                    default="gather",
                    help="paged-attention path: 'gather' materializes the "
                         "block-table span (reference); 'pallas' fuses the "
                         "block gather into the attention kernel (fast path "
                         "on TPU; interpret mode on CPU)")
    ap.add_argument("--speculative", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="LAMP self-draft speculative decoding: draft with "
                         "the pure low-precision forward (rule 'none'), "
                         "verify all drafted positions in one multi-token "
                         "LAMP forward (greedy outputs identical to "
                         "non-speculative decoding)")
    ap.add_argument("--draft-len", type=int, default=4,
                    help="speculative draft tokens per sequence per round")
    ap.add_argument("--fused", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="fused serving step: one mixed "
                         "prefill+decode+verify plan per step, executed as "
                         "a single bucketed jitted launch (token-identical "
                         "to the phase-segregated step). On by default; "
                         "--no-fused restores the split phases")
    ap.add_argument("--policy", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="adaptive LAMP policy loop: actuate per-layer "
                         "thresholds toward --target-recompute-rate every "
                         "step (traced operands, zero recompiles) and "
                         "degrade draft length / rule tier under KV-pool "
                         "pressure")
    ap.add_argument("--target-recompute-rate", type=float, default=0.05,
                    help="per-layer LAMP recompute-rate setpoint the "
                         "policy controller steers tau toward")
    ap.add_argument("--latency-slo", type=float, default=0.0,
                    help="step-latency SLO in seconds; exceeding it is "
                         "pressure that degrades the policy mode (0 = no "
                         "latency signal)")
    ap.add_argument("--audit-rate", type=float, default=0.0,
                    help="shadow-audit this fraction of serving steps: "
                         "re-run sampled rows through the FP32 reference "
                         "forward (never perturbs served tokens) and "
                         "report realized LAMP error -- per-layer "
                         "attribution, argmax flip rate, top-k overlap "
                         "(0 = off; 0.05 costs <5%% per-step overhead)")
    ap.add_argument("--audit-out", default="",
                    help="write the final audit summary (stats()['audit'] "
                         "JSON: per-layer errors, flip rate, calibrated "
                         "targets) here")
    ap.add_argument("--top-k", type=int, default=0,
                    help="sample from the top-k logits only (0 = "
                         "unfiltered); also the filter the speculative "
                         "accept rule scores against")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-lamp", action="store_true")
    ap.add_argument("--metrics-every", type=float, default=0.0,
                    help="print a one-line metrics snapshot every S seconds "
                         "of stream time (0 = off)")
    ap.add_argument("--trace-out", default="",
                    help="record step-phase spans and write a Chrome trace "
                         "JSON here (open in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", default="",
                    help="write the final metrics-registry snapshot (JSON) "
                         "here")
    ap.add_argument("--jax-profile", default="",
                    help="wrap the run in jax.profiler.trace writing to "
                         "this directory (TensorBoard/XPlane format)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    longest = args.shared_prefix + args.max_prompt
    max_len = args.max_model_len or min(cfg.max_seq,
                                        longest + args.max_new + 8)
    if args.min_prompt > args.max_prompt or args.min_new > args.max_new:
        ap.error("--min-prompt/--min-new must not exceed --max-prompt/--max-new")
    if longest + args.max_new > max_len:
        ap.error(f"shared prefix + max prompt + max new "
                 f"({longest + args.max_new}) exceeds the model length "
                 f"budget {max_len}; raise --max-model-len "
                 f"(<= cfg.max_seq {cfg.max_seq}) or shrink the request sizes")
    obs = ObsConfig(trace=bool(args.trace_out), trace_path=args.trace_out,
                    jax_profile_dir=args.jax_profile)
    policy = PolicyConfig(enabled=args.policy,
                          target_rate=args.target_recompute_rate,
                          latency_slo_s=args.latency_slo)
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=args.block_size, n_blocks=args.n_blocks,
        max_model_len=max_len, use_lamp=not args.no_lamp,
        max_prefill_tokens=args.max_prefill_tokens,
        prefix_cache=args.prefix_cache,
        chunked_prefill=args.chunked_prefill,
        kernel=args.kernel, speculative=args.speculative,
        draft_len=args.draft_len, fused_step=args.fused,
        obs=obs, policy=policy,
        audit=AuditConfig(rate=args.audit_rate)))

    rng = np.random.default_rng(args.seed)
    stream = build_stream(rng, args, cfg.vocab)
    print(f"[serve] arch={cfg.name} lamp={not args.no_lamp} "
          f"qps={args.qps} requests={args.num_requests} "
          f"pool={engine.pool.num_total}x{engine.pool.block_size} blocks "
          f"prefix_cache={args.prefix_cache} "
          f"chunked_prefill={args.chunked_prefill} kernel={args.kernel} "
          f"policy={args.policy} fused={args.fused}")

    with engine.obs.profile():
        outputs = serve_stream(engine, stream,
                               metrics_every=args.metrics_every)

    # end-of-run report: exact percentiles over every finished request
    # (the periodic lines above use the streaming histogram estimates)
    s = engine.stats(exact=True)
    mean_rate = (np.mean([o.lamp_recompute_rate for o in outputs])
                 if outputs else 0.0)
    shape = (f"{s['mixed_steps']} mixed steps, {s['launches']} launches"
             if args.fused else
             f"{s['prefill_steps']} prefill / {s['decode_steps']} decode "
             f"steps")
    print(f"[serve] finished {s['num_finished']}/{args.num_requests} "
          f"in {s['elapsed_s']:.2f}s "
          f"({shape}, {s['preemptions']} preemptions)")
    print(f"[serve] throughput {s['tokens_per_s']:.1f} tok/s, "
          f"{s['requests_per_s']:.2f} req/s")
    print(f"[serve] latency p50 {s['latency_p50_s']*1e3:.0f}ms  "
          f"p99 {s['latency_p99_s']*1e3:.0f}ms  "
          f"ttft p50 {s['ttft_p50_s']*1e3:.0f}ms")
    print(f"[serve] kv-block utilization mean {s['kv_util_mean']:.2%} "
          f"peak {s['kv_util_peak']:.2%}")
    print(f"[serve] prefix cache: hit rate {s['cache_hit_rate']:.2%} "
          f"({s['cached_tokens']} cached / {s['prefill_tokens_run']} run "
          f"tokens), {s['blocks_saved']} blocks saved / "
          f"{s['blocks_allocated']} allocated, {s['cow_copies']} COW copies, "
          f"{s['cache_evictions']} evictions, "
          f"{s['prefill_chunks']} prefill chunks, "
          f"{s['resume_cached_tokens']} resume-cached tokens")
    print(f"[serve] LAMP recompute rate: aggregate "
          f"{s['lamp_recompute_rate']:.4f}, per-request mean {mean_rate:.4f}")
    rates = s["lamp_layer_rates"]
    if any(v > 0 for v in rates):
        print("[serve] per-layer recompute rate: "
              + " ".join(f"L{i}={r:.3f}" for i, r in enumerate(rates)))
    if s["compiles"]:
        print(f"[serve] jit compiles: {s['compiles']} "
              f"({s['compile_time_s']:.2f}s wall): "
              + " ".join(f"{e['kind']}{e['shape']}"
                         for e in engine.compile_events))
    phases = sorted(s["phase"].items(),
                    key=lambda kv: -kv[1]["mean_us"] * kv[1]["count"])
    print("[serve] phase wall time: "
          + "  ".join(f"{name}={p['mean_us']:.0f}us x{p['count']}"
                      for name, p in phases))
    if args.policy:
        p = s["policy"]
        print(f"[serve] policy: mode={p['mode']} "
              f"({p['mode_transitions']} transitions, "
              f"{p['actuations']} actuations), tau mean {p['tau_mean']:.4f} "
              f"[{p['tau_min']:.4f}, {p['tau_max']:.4f}], "
              f"draft_len={p['draft_len']}")
    if args.audit_rate > 0:
        a = s["audit"]
        if a["enabled"]:
            print(f"[serve] audit: {a['audited_steps']} steps / "
                  f"{a['audited_rows']} rows audited, "
                  f"flip rate {a['flip_rate']:.4f}, "
                  f"logit rel err {a['logit_rel_err']:.3e}, "
                  f"{a['calibrations']} calibrations")
            print("[serve] audit per-layer KQ err: "
                  + " ".join(f"L{i}={e:.2e}"
                             for i, e in enumerate(a["layer_kq_err"])))
            if "targets" in a:
                print("[serve] audit calibrated targets: "
                      + " ".join(f"L{i}={t:.3f}"
                                 for i, t in enumerate(a["targets"]))
                      + f" (guarded: "
                      f"{sum(1 for ok in a['relax_ok'] if not ok)})")
        else:
            print("[serve] audit: disabled (--no-lamp runs have no LAMP "
                  "error to measure)")
    if args.audit_out:
        with open(args.audit_out, "w") as f:
            json.dump(s["audit"], f, indent=1)
        print(f"[serve] wrote audit summary to {args.audit_out}")
    if args.speculative:
        acc = [o.spec_acceptance_rate for o in outputs if o.spec_drafted]
        print(f"[serve] speculative: {s['spec_rounds']} rounds, "
              f"acceptance {s['spec_acceptance_rate']:.2%} "
              f"(per-request mean {np.mean(acc) if acc else 0.0:.2%}), "
              f"{s['spec_tokens_per_round']:.2f} tokens/round, "
              f"verify recompute rate {s['verify_recompute_rate']:.4f}")
    if args.trace_out:
        path = engine.write_trace()
        n = len(engine.obs.tracer.events())
        print(f"[serve] wrote Chrome trace ({n} events, "
              f"{engine.obs.tracer.dropped} dropped) to {path}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(engine.metrics_snapshot(), f, indent=1)
        print(f"[serve] wrote metrics snapshot to {args.metrics_out}")


if __name__ == "__main__":
    main()
