"""Serving launcher: continuous-batching LAMP engine under a synthetic
Poisson request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch gpt2 --reduced \
        --qps 8 --num-requests 32

Requests arrive with exponential inter-arrival times at `--qps`, with
prompt/output lengths drawn per request; the engine admits them into the
paged KV pool, continuously batches prefill/decode, and reports throughput,
latency percentiles, KV-block utilization, and the per-request/aggregate
LAMP recompute rate.

Observability hooks: `--metrics-every S` prints a one-line registry
snapshot every S seconds of stream time; `--trace-out f.json` records
step-phase spans and writes a Chrome trace (load it at https://ui.perfetto.dev
or chrome://tracing); `--metrics-out f.json` dumps the final metrics
registry snapshot; `--jax-profile DIR` wraps the run in
`jax.profiler.trace`. All loop timing -- arrivals, idle sleeps, the
periodic snapshot cadence -- runs off the engine's single injectable clock
(`engine.obs.now`), so `serve_stream` is deterministic under a fake clock.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.configs import get_config, list_archs, reduced as reduce_cfg
from repro.models import api
from repro.obs import ObsConfig
from repro.serving import (AuditConfig, EngineConfig, FaultConfig,
                           LampEngine, PolicyConfig, QueueFullError,
                           SamplingParams)
from repro.serving.engine import TEXT_FAMILIES


def servable_archs():
    """Archs the paged-KV engine can serve (see engine.TEXT_FAMILIES)."""
    return [a for a in list_archs()
            if get_config(a).family in TEXT_FAMILIES]


def build_stream(rng: np.random.Generator, args, vocab: int):
    """Synthetic Poisson stream: (arrival_s, prompt, sampling) per request.
    With --shared-prefix N, every prompt opens with the same N tokens (a
    shared system prompt), the traffic shape prefix caching exists for."""
    arrivals = np.cumsum(rng.exponential(1.0 / args.qps, args.num_requests))
    shared = rng.integers(0, vocab, size=args.shared_prefix).tolist() \
        if args.shared_prefix else []
    reqs = []
    for i in range(args.num_requests):
        plen = int(rng.integers(args.min_prompt, args.max_prompt + 1))
        new = int(rng.integers(args.min_new, args.max_new + 1))
        prompt = shared + rng.integers(0, vocab, size=plen).tolist()
        sampling = SamplingParams(max_new_tokens=new,
                                  temperature=args.temperature, seed=i,
                                  top_k=args.top_k,
                                  deadline_s=getattr(args, "deadline", 0.0))
        reqs.append((float(arrivals[i]), prompt, sampling))
    return reqs


def metrics_line(engine: LampEngine, elapsed: float) -> str:
    """One-line live snapshot for periodic progress logging. Carries the
    policy mode and the audited flip rate so a burst-load run is readable
    from the log alone: "mode=shed" explains a rate drop, and a flip-rate
    spike says the degradation is costing real tokens."""
    s = engine.stats()
    mode = s["policy"]["mode"] if s["policy"]["enabled"] else "off"
    audit = s["audit"]
    flips = (f"{audit['flip_rate']:.3f}" if audit["enabled"] else "-")
    return (f"[serve] t={elapsed:7.2f}s live={s['live_requests']:>3d} "
            f"done={s['num_finished']:>3d} steps={s['steps']} "
            f"tok/s={s['tokens_per_s']:7.1f} "
            f"kv_util={s['kv_util_peak']:.0%} "
            f"lamp_rate={s['lamp_recompute_rate']:.4f} "
            f"mode={mode} audit_flips={flips} "
            f"compiles={s['compiles']}")


def serve_stream(engine: LampEngine, stream, *,
                 metrics_every: float = 0.0,
                 sleep: Optional[Callable[[float], None]] = None,
                 log: Callable[[str], None] = print,
                 per_request: bool = True,
                 outputs: Optional[List] = None) -> List:
    """Drive the engine over a pre-built (arrival_s, prompt, sampling)
    stream. Every timestamp -- arrivals, idle waits, the snapshot cadence --
    comes from the engine's own clock (`engine.obs.now`), so a fake clock
    plus a clock-advancing `sleep` makes the whole loop deterministic.

    A bounded admission queue (EngineConfig.max_queue) rejects arrivals
    with QueueFullError; rejected requests are logged and skipped, the
    stream keeps serving. Pass `outputs` to share the result list with the
    caller: requests finished before a mid-stream exception (engine fault,
    KeyboardInterrupt) stay visible for draining and reporting."""
    clock = engine.obs.now
    if sleep is None:
        sleep = time.sleep
    t0 = clock()
    next_metrics = metrics_every
    i = 0
    if outputs is None:
        outputs = []
    while i < len(stream) or engine.has_unfinished():
        now = clock() - t0
        while i < len(stream) and stream[i][0] <= now:
            arr, prompt, sampling = stream[i]
            try:
                engine.add_request(prompt, sampling, arrival_time=t0 + arr)
            except QueueFullError as e:
                log(f"[serve]   req at t={arr:.2f}s REJECTED: {e}")
            i += 1
        done = engine.step()
        outputs.extend(done)
        if per_request:
            for o in done:
                if o.error is not None:
                    log(f"[serve]   req {o.req_id:>3d} FAILED "
                        f"({o.finish_reason}): {o.error}")
                    continue
                log(f"[serve]   req {o.req_id:>3d} done: "
                    f"prompt={len(o.prompt)} new={len(o.tokens)} "
                    f"latency={o.latency * 1e3:7.1f}ms "
                    f"ttft={o.ttft * 1e3:7.1f}ms "
                    f"preempt={o.num_preemptions} "
                    f"cached={o.num_cached_tokens} "
                    f"lamp_rate={o.lamp_recompute_rate:.4f}")
        if metrics_every > 0 and clock() - t0 >= next_metrics:
            log(metrics_line(engine, clock() - t0))
            next_metrics += metrics_every
        if not engine.has_unfinished() and i < len(stream):
            sleep(max(0.0, stream[i][0] - (clock() - t0)))
    return outputs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=servable_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--qps", type=float, default=8.0,
                    help="Poisson arrival rate (requests/s)")
    ap.add_argument("--num-requests", type=int, default=32)
    ap.add_argument("--min-prompt", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--min-new", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--n-blocks", type=int, default=0,
                    help="KV pool size in blocks (0 = auto)")
    ap.add_argument("--max-model-len", type=int, default=0)
    ap.add_argument("--max-prefill-tokens", type=int, default=2048,
                    help="prefill-step token budget = chunk size")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="share prompt-prefix KV blocks across requests "
                         "(copy-on-write)")
    ap.add_argument("--chunked-prefill",
                    action=argparse.BooleanOptionalAction, default=True,
                    help="prefill long prompts in max-prefill-tokens chunks "
                         "so decode steps interleave")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a common prefix of this many tokens to "
                         "every prompt (prefix-cache traffic)")
    ap.add_argument("--kernel", choices=("gather", "pallas"),
                    default="gather",
                    help="paged-attention path: 'gather' materializes the "
                         "block-table span (reference); 'pallas' fuses the "
                         "block gather into the attention kernel (fast path "
                         "on TPU; interpret mode on CPU)")
    ap.add_argument("--speculative", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="LAMP self-draft speculative decoding: draft with "
                         "the pure low-precision forward (rule 'none'), "
                         "verify all drafted positions in one multi-token "
                         "LAMP forward (greedy outputs identical to "
                         "non-speculative decoding)")
    ap.add_argument("--draft-len", type=int, default=4,
                    help="speculative draft tokens per sequence per round")
    ap.add_argument("--fused", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="fused serving step: one mixed "
                         "prefill+decode+verify plan per step, executed as "
                         "a single bucketed jitted launch (token-identical "
                         "to the phase-segregated step). On by default; "
                         "--no-fused restores the split phases")
    ap.add_argument("--policy", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="adaptive LAMP policy loop: actuate per-layer "
                         "thresholds toward --target-recompute-rate every "
                         "step (traced operands, zero recompiles) and "
                         "degrade draft length / rule tier under KV-pool "
                         "pressure")
    ap.add_argument("--target-recompute-rate", type=float, default=0.05,
                    help="per-layer LAMP recompute-rate setpoint the "
                         "policy controller steers tau toward")
    ap.add_argument("--latency-slo", type=float, default=0.0,
                    help="step-latency SLO in seconds; exceeding it is "
                         "pressure that degrades the policy mode (0 = no "
                         "latency signal)")
    ap.add_argument("--audit-rate", type=float, default=0.0,
                    help="shadow-audit this fraction of serving steps: "
                         "re-run sampled rows through the FP32 reference "
                         "forward (never perturbs served tokens) and "
                         "report realized LAMP error -- per-layer "
                         "attribution, argmax flip rate, top-k overlap "
                         "(0 = off; 0.05 costs <5%% per-step overhead)")
    ap.add_argument("--audit-out", default="",
                    help="write the final audit summary (stats()['audit'] "
                         "JSON: per-layer errors, flip rate, calibrated "
                         "targets) here")
    ap.add_argument("--top-k", type=int, default=0,
                    help="sample from the top-k logits only (0 = "
                         "unfiltered); also the filter the speculative "
                         "accept rule scores against")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-lamp", action="store_true")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-request wall-clock TTL in seconds; an "
                         "expired request is cancelled with "
                         "finish_reason='timeout' and its blocks freed "
                         "(0 = no deadline)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded admission queue: reject arrivals once "
                         "this many requests are waiting (0 = unbounded)")
    ap.add_argument("--fault-nan", type=float, default=0.0,
                    help="deterministic fault injection: per-step "
                         "probability of poisoning one row's logits/KV "
                         "with NaN (exercises the health guard + recovery "
                         "ladder)")
    ap.add_argument("--fault-alloc", type=float, default=0.0,
                    help="fault injection: per-step probability of failing "
                         "the next KV-block allocation (degrades to "
                         "deferral, never crashes)")
    ap.add_argument("--fault-draft", type=float, default=0.0,
                    help="fault injection: per-step probability of "
                         "corrupting one row's speculative draft tokens "
                         "(the verifier rejects them)")
    ap.add_argument("--fault-step", type=float, default=0.0,
                    help="fault injection: per-step probability of a "
                         "fused-step launch anomaly (degrades that step to "
                         "the split twin)")
    ap.add_argument("--fault-stall", type=float, default=0.0,
                    help="fault injection: per-step probability of an "
                         "artificial stall (no-progress steps the "
                         "watchdog must clear)")
    ap.add_argument("--fault-salt", type=int, default=0,
                    help="salt for the deterministic fault hash: same "
                         "salt + rates + stream replays the same faults "
                         "bit-for-bit")
    ap.add_argument("--fault-max", type=int, default=0,
                    help="cap total injected faults (0 = unlimited)")
    ap.add_argument("--metrics-every", type=float, default=0.0,
                    help="print a one-line metrics snapshot every S seconds "
                         "of stream time (0 = off)")
    ap.add_argument("--trace-out", default="",
                    help="record step-phase spans and write a Chrome trace "
                         "JSON here (open in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", default="",
                    help="write the final metrics-registry snapshot (JSON) "
                         "here")
    ap.add_argument("--jax-profile", default="",
                    help="wrap the run in jax.profiler.trace writing to "
                         "this directory (TensorBoard/XPlane format)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    longest = args.shared_prefix + args.max_prompt
    max_len = args.max_model_len or min(cfg.max_seq,
                                        longest + args.max_new + 8)
    if args.min_prompt > args.max_prompt or args.min_new > args.max_new:
        ap.error("--min-prompt/--min-new must not exceed --max-prompt/--max-new")
    if longest + args.max_new > max_len:
        ap.error(f"shared prefix + max prompt + max new "
                 f"({longest + args.max_new}) exceeds the model length "
                 f"budget {max_len}; raise --max-model-len "
                 f"(<= cfg.max_seq {cfg.max_seq}) or shrink the request sizes")
    obs = ObsConfig(trace=bool(args.trace_out), trace_path=args.trace_out,
                    jax_profile_dir=args.jax_profile)
    policy = PolicyConfig(enabled=args.policy,
                          target_rate=args.target_recompute_rate,
                          latency_slo_s=args.latency_slo)
    fault_rates = dict(nan_rate=args.fault_nan, alloc_rate=args.fault_alloc,
                       draft_rate=args.fault_draft,
                       step_rate=args.fault_step,
                       stall_rate=args.fault_stall)
    faults = FaultConfig(enabled=any(r > 0 for r in fault_rates.values()),
                         salt=args.fault_salt, max_faults=args.fault_max,
                         **fault_rates)
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=args.block_size, n_blocks=args.n_blocks,
        max_model_len=max_len, use_lamp=not args.no_lamp,
        max_prefill_tokens=args.max_prefill_tokens,
        prefix_cache=args.prefix_cache,
        chunked_prefill=args.chunked_prefill,
        kernel=args.kernel, speculative=args.speculative,
        draft_len=args.draft_len, fused_step=args.fused,
        obs=obs, policy=policy,
        audit=AuditConfig(rate=args.audit_rate),
        faults=faults, max_queue=args.max_queue))

    rng = np.random.default_rng(args.seed)
    stream = build_stream(rng, args, cfg.vocab)
    print(f"[serve] arch={cfg.name} lamp={not args.no_lamp} "
          f"qps={args.qps} requests={args.num_requests} "
          f"pool={engine.pool.num_total}x{engine.pool.block_size} blocks "
          f"prefix_cache={args.prefix_cache} "
          f"chunked_prefill={args.chunked_prefill} kernel={args.kernel} "
          f"policy={args.policy} fused={args.fused}")

    outputs: List = []
    exit_code = 0
    try:
        with engine.obs.profile():
            serve_stream(engine, stream, metrics_every=args.metrics_every,
                         outputs=outputs)
    except KeyboardInterrupt:
        # graceful shutdown: drain what is already admitted (bounded by the
        # watchdog) so no in-flight request is silently dropped, then fall
        # through to the report + artifact flush below
        exit_code = 130
        live = engine.stats()["live_requests"]
        print(f"\n[serve] interrupted with {live} request(s) in flight -- "
              f"draining before shutdown (^C again to abandon)")
        try:
            outputs.extend(engine.run_to_completion())
        except (KeyboardInterrupt, RuntimeError) as e:
            print(f"[serve] drain abandoned: {e!r}")
    except RuntimeError as e:
        # engine gave up (hung stream past the watchdog, invariant
        # violation): report and flush what we have, exit non-zero
        exit_code = 1
        print(f"[serve] stream failed: {e}")

    # end-of-run report: exact percentiles over every finished request
    # (the periodic lines above use the streaming histogram estimates)
    s = engine.stats(exact=True)
    # flush artifacts FIRST: an interrupted or failed run must still leave
    # its trace/metrics/audit files behind for forensics
    if args.audit_out:
        with open(args.audit_out, "w") as f:
            json.dump(s["audit"], f, indent=1)
        print(f"[serve] wrote audit summary to {args.audit_out}")
    if args.trace_out:
        path = engine.write_trace()
        n = len(engine.obs.tracer.events())
        print(f"[serve] wrote Chrome trace ({n} events, "
              f"{engine.obs.tracer.dropped} dropped) to {path}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(engine.metrics_snapshot(), f, indent=1)
        print(f"[serve] wrote metrics snapshot to {args.metrics_out}")
    mean_rate = (np.mean([o.lamp_recompute_rate for o in outputs])
                 if outputs else 0.0)
    shape = (f"{s['mixed_steps']} mixed steps, {s['launches']} launches"
             if args.fused else
             f"{s['prefill_steps']} prefill / {s['decode_steps']} decode "
             f"steps")
    print(f"[serve] finished {s['num_finished']}/{args.num_requests} "
          f"in {s['elapsed_s']:.2f}s "
          f"({shape}, {s['preemptions']} preemptions)")
    print(f"[serve] throughput {s['tokens_per_s']:.1f} tok/s, "
          f"{s['requests_per_s']:.2f} req/s")
    print(f"[serve] latency p50 {s['latency_p50_s']*1e3:.0f}ms  "
          f"p99 {s['latency_p99_s']*1e3:.0f}ms  "
          f"ttft p50 {s['ttft_p50_s']*1e3:.0f}ms")
    print(f"[serve] kv-block utilization mean {s['kv_util_mean']:.2%} "
          f"peak {s['kv_util_peak']:.2%}")
    print(f"[serve] prefix cache: hit rate {s['cache_hit_rate']:.2%} "
          f"({s['cached_tokens']} cached / {s['prefill_tokens_run']} run "
          f"tokens), {s['blocks_saved']} blocks saved / "
          f"{s['blocks_allocated']} allocated, {s['cow_copies']} COW copies, "
          f"{s['cache_evictions']} evictions, "
          f"{s['prefill_chunks']} prefill chunks, "
          f"{s['resume_cached_tokens']} resume-cached tokens")
    print(f"[serve] LAMP recompute rate: aggregate "
          f"{s['lamp_recompute_rate']:.4f}, per-request mean {mean_rate:.4f}")
    rates = s["lamp_layer_rates"]
    if any(v > 0 for v in rates):
        print("[serve] per-layer recompute rate: "
              + " ".join(f"L{i}={r:.3f}" for i, r in enumerate(rates)))
    if s["compiles"]:
        print(f"[serve] jit compiles: {s['compiles']} "
              f"({s['compile_time_s']:.2f}s wall): "
              + " ".join(f"{e['kind']}{e['shape']}"
                         for e in engine.compile_events))
    phases = sorted(s["phase"].items(),
                    key=lambda kv: -kv[1]["mean_us"] * kv[1]["count"])
    print("[serve] phase wall time: "
          + "  ".join(f"{name}={p['mean_us']:.0f}us x{p['count']}"
                      for name, p in phases))
    if args.policy:
        p = s["policy"]
        print(f"[serve] policy: mode={p['mode']} "
              f"({p['mode_transitions']} transitions, "
              f"{p['actuations']} actuations), tau mean {p['tau_mean']:.4f} "
              f"[{p['tau_min']:.4f}, {p['tau_max']:.4f}], "
              f"draft_len={p['draft_len']}")
    if args.audit_rate > 0:
        a = s["audit"]
        if a["enabled"]:
            print(f"[serve] audit: {a['audited_steps']} steps / "
                  f"{a['audited_rows']} rows audited, "
                  f"flip rate {a['flip_rate']:.4f}, "
                  f"logit rel err {a['logit_rel_err']:.3e}, "
                  f"{a['calibrations']} calibrations")
            print("[serve] audit per-layer KQ err: "
                  + " ".join(f"L{i}={e:.2e}"
                             for i, e in enumerate(a["layer_kq_err"])))
            if "targets" in a:
                print("[serve] audit calibrated targets: "
                      + " ".join(f"L{i}={t:.3f}"
                                 for i, t in enumerate(a["targets"]))
                      + f" (guarded: "
                      f"{sum(1 for ok in a['relax_ok'] if not ok)})")
        else:
            print("[serve] audit: disabled (--no-lamp runs have no LAMP "
                  "error to measure)")
    if args.speculative:
        acc = [o.spec_acceptance_rate for o in outputs if o.spec_drafted]
        print(f"[serve] speculative: {s['spec_rounds']} rounds, "
              f"acceptance {s['spec_acceptance_rate']:.2%} "
              f"(per-request mean {np.mean(acc) if acc else 0.0:.2%}), "
              f"{s['spec_tokens_per_round']:.2f} tokens/round, "
              f"verify recompute rate {s['verify_recompute_rate']:.4f}")
    if s["faults"]["enabled"] or s["recoveries"] or s["failed_requests"]:
        f = s["faults"]
        by = (" ".join(f"{k}={v}" for k, v in f["by_site"].items())
              if f["enabled"] else "off")
        print(f"[serve] faults: injected="
              f"{f['injected'] if f['enabled'] else 0} ({by}), "
              f"recoveries={s['recoveries']}, "
              f"failed_requests={s['failed_requests']}")

    # exit non-zero when any request was individually failed (timeout,
    # exhausted recovery ladder, stall eviction) or rejected at admission,
    # so CI chaos runs can gate on a clean stream
    failed = [o for o in outputs if o.error is not None]
    rejected = args.num_requests - len(outputs) if exit_code != 130 else 0
    for o in failed:
        print(f"[serve] FAILED req {o.req_id} ({o.finish_reason}): "
              f"{o.error}")
    if rejected > 0:
        print(f"[serve] {rejected} request(s) rejected at admission "
              f"(queue bound {args.max_queue})")
    if exit_code == 0 and (failed or rejected > 0):
        exit_code = 1
    if exit_code:
        raise SystemExit(exit_code)


if __name__ == "__main__":
    main()
