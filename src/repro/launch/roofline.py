"""Roofline-term extraction from compiled dry-run artifacts.

Terms (per device; cost_analysis on the CPU backend reports post-SPMD
per-device numbers, equivalent to total/chips):

    compute_s    = flops_per_device / PEAK_FLOPS_BF16
    memory_s     = bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW_PER_LINK

collective bytes are parsed from the compiled HLO: the result-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (async '-start' counted once, '-done' skipped). This is a
first-order traffic proxy (ring all-reduce really moves ~2x), stated as such
in EXPERIMENTS.md.
"""

from __future__ import annotations

import re
from typing import Any, Dict

from .mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective category from (compiled) HLO."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[0]:
            continue
        kind = m.group(1)
        lhs = line.split("=", 1)
        if len(lhs) != 2:
            continue
        # shapes between '=' and the op name
        seg = lhs[1][: m.start() - len(lhs[0]) - 1] if m.start() > len(lhs[0]) else lhs[1]
        total = 0
        for sm in _SHAPE_RE.finditer(seg):
            total += shape_bytes(sm.group(1), sm.group(2))
        out[kind] = out.get(kind, 0) + total
    return out


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> Dict[str, Any]:
    compute_s = flops_per_dev / PEAK_FLOPS_BF16
    memory_s = bytes_per_dev / HBM_BW
    collective_s = coll_bytes_per_dev / ICI_BW_PER_LINK
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    total = sum(terms.values())
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        # fraction of ideal: if terms fully overlap, step time = max(terms);
        # roofline fraction = dominant / sum (1.0 = perfectly balanced on
        # one roof, lower = time wasted on non-dominant roofs if serial).
        "overlap_efficiency": bound / total if total else 0.0,
    }


def active_params(p_shape, cfg) -> Dict[str, float]:
    """Total and active (MoE-discounted) parameter counts from shapes."""
    import jax

    def path_str(path):
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "name"):
                parts.append(p.name)
        return "/".join(parts)

    total = 0
    expert = 0
    flat = jax.tree_util.tree_flatten_with_path(p_shape)[0]
    for path, leaf in flat:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if "we_in" in path_str(path) or "we_out" in path_str(path):
            expert += n
    active = total - expert
    if cfg.n_experts:
        active += expert * cfg.top_k / cfg.n_experts
    return {"n_params": float(total), "n_active": float(active)}


def model_flops(cfg, p_shape, kind: str, batch: int, seq: int) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference forward), N = active."""
    n = active_params(p_shape, cfg)["n_active"]
    tokens = batch * (seq if kind in ("train", "prefill") else 1)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
