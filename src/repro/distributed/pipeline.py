"""GPipe-style pipeline parallelism via shard_map + collective_permute.

For meshes with a `stage` axis: the layer stack is split into S contiguous
stages; microbatches flow through stages with activations handed to the next
stage by `jax.lax.ppermute`. The schedule is the classic GPipe fill-drain
loop (S + M - 1 ticks for M microbatches), expressed as a lax.fori over a
rotating buffer so it stays a single compiled program.

This is an optional parallelism mode (the assigned production meshes are
DP x TP); it exists so the framework covers PP for depth-dominated models
(mistral-large-123b at 88 layers is the natural customer) and is exercised
by tests on a host mesh.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(mesh: Mesh, stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
                   stage_params: Any, x_mb: jnp.ndarray, *,
                   stage_axis: str = "stage") -> jnp.ndarray:
    """Run x_mb (M, mb, ...) microbatches through S pipeline stages.

    stage_params: pytree whose leaves have leading dim S (one slice per
    stage, sharded over `stage_axis`). stage_fn(params_slice, x) -> y must
    preserve x's shape (a transformer block stack does).
    Returns (M, mb, ...) outputs.
    """
    S = mesh.shape[stage_axis]
    M = x_mb.shape[0]
    if M < S:
        raise ValueError(f"need microbatches >= stages, got {M} < {S}")

    def per_stage(params_local, x_local):
        # params_local: leaves (1, ...) -- this stage's slice
        # x_local: (M, mb, ...) full microbatch stream (replicated)
        p = jax.tree.map(lambda t: t[0], params_local)
        sid = jax.lax.axis_index(stage_axis)
        n_ticks = M + S - 1
        mb_shape = x_local.shape[1:]

        def tick(t, carry):
            buf, out = carry
            # stage 0 injects microbatch t (if any); others take the handoff
            inject = jax.lax.dynamic_index_in_dim(
                x_local, jnp.minimum(t, M - 1), axis=0, keepdims=False)
            cur = jnp.where(sid == 0, inject, buf)
            y = stage_fn(p, cur)
            # hand off to the next stage (ring; the wraparound write from
            # the last stage is ignored by stage 0, which always injects)
            nxt = jax.lax.ppermute(y, stage_axis,
                                   [(i, (i + 1) % S) for i in range(S)])
            # last stage records microbatch (t - (S-1)) when valid
            mb_idx = t - (S - 1)
            valid = (sid == S - 1) & (mb_idx >= 0)
            out = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(mb_idx, 0), axis=0),
                lambda o: o, out)
            return (nxt, out)

        buf0 = jnp.zeros(mb_shape, x_local.dtype)
        out0 = jnp.zeros((M,) + mb_shape, x_local.dtype)
        _, out = jax.lax.fori_loop(0, n_ticks, tick, (buf0, out0))
        # broadcast results from the last stage to all stages (psum of a
        # one-hot-masked buffer == broadcast, and is a legal collective)
        out = jax.lax.psum(jnp.where(sid == S - 1, out, 0.0), stage_axis)
        return out

    pspec = jax.tree.map(lambda _: P(stage_axis), stage_params)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(pspec, P()), out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x_mb)


def split_stages(params_stacked: Any, n_stages: int) -> Any:
    """Reshape stacked layer params (L, ...) -> (S, L//S, ...) stage slices."""
    def one(t):
        L = t.shape[0]
        if L % n_stages:
            raise ValueError(f"L={L} not divisible by stages={n_stages}")
        return t.reshape(n_stages, L // n_stages, *t.shape[1:])
    return jax.tree.map(one, params_stacked)
