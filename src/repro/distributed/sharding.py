"""Sharding rules: param/cache/batch PartitionSpecs for the production mesh.

Strategy (DESIGN.md Sec 4): FSDP over the `data` axis x tensor parallelism
over the `model` axis; batch over (`pod`, `data`). Expert parallelism puts
the MoE expert axis on `model`. Rules are path-based so every family's
param tree is covered; any dimension that does not divide evenly falls back
to replication on that axis (checked explicitly -- XLA requires even
sharding).
"""

from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# (path regex, spec WITHOUT the stacked-layer axis). First match wins.
# "data"/"model" here are logical axis names resolved against the mesh.
_PARAM_RULES: Tuple[Tuple[str, Tuple], ...] = (
    # embeddings
    (r"embed/tok$",       ("model", "data")),     # (V, d): vocab-TP
    (r"embed/pos$",       (None, None)),
    (r"embed/unembed$",   ("data", "model")),     # (d, V)
    (r"enc_pos$",         (None, None)),
    (r"meta$",            (None, None)),
    (r"mm_proj$",         ("data", "model")),
    # attention
    (r"attn/wq$|xattn/wq$", ("data", "model")),
    (r"attn/wk$|xattn/wk$", ("data", "model")),
    (r"attn/wv$|xattn/wv$", ("data", "model")),
    (r"attn/wo$|xattn/wo$", ("model", "data")),
    (r"attn/qn_w$|attn/kn_w$", (None,)),
    # dense MLP
    (r"mlp/wi$",          ("data", "model")),
    (r"mlp/wo$",          ("model", "data")),
    # MoE
    (r"moe/router$",      ("data", None)),
    (r"moe/we_in$",       ("model", "data", None)),   # (E, d, ff)
    (r"moe/we_out$",      ("model", None, "data")),   # (E, ff, d)
    # rwkv time-mix / channel-mix (cm_* before the generic w[rkvg] rule)
    (r"cm_wk$",           ("data", "model")),
    (r"cm_wv$",           ("model", "data")),
    (r"cm_wr$",           ("data", "model")),
    (r"blocks/w[rkvg]$",  ("data", "model")),
    (r"blocks/wo$",       ("model", "data")),
    (r"tm_lora_down$|w_lora_down$", ("data", None)),
    (r"tm_lora_up$",      (None, None, "model")),
    (r"w_lora_up$",       (None, "model")),
    (r"w_base$",          ("model",)),
    (r"tm_mu$|cm_mu$",    (None, None)),
    (r"/u$",              (None, None)),
    (r"ln_x$",            ("model",)),
    # hymba mamba branch (d_inner sharded over model)
    (r"m_in$",            ("data", "model")),
    (r"m_conv$",          (None, "model")),
    (r"m_dt$",            (None, "model")),
    (r"m_dt_bias$",       ("model",)),
    (r"m_bc$",            ("model", None)),
    (r"m_A_log$",         ("model", None)),
    (r"m_D$",             ("model",)),
    (r"m_out$",           ("model", "data")),
    (r"fuse_na$|fuse_ns$", (None,)),
    (r"fuse_beta$",       (None,)),
    # norms and anything else 1-D: replicate
    (r".*",               None),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
    return "/".join(parts)


def _axis_size(mesh: Mesh, name: Optional[str]) -> int:
    if name is None:
        return 1
    return mesh.shape.get(name, 1) if name in mesh.axis_names else 1


def _fit_spec(shape, raw_spec, mesh: Mesh, stacked: bool) -> P:
    """Build a PartitionSpec, dropping axes that don't divide evenly or
    don't exist in the mesh, and prepending None for the stacked (L,) dim."""
    if raw_spec is None:
        dims = [None] * len(shape)
        return P(*dims)
    dims = list(raw_spec)
    if stacked:
        dims = [None] + dims
    # pad/trim to rank
    while len(dims) < len(shape):
        dims.append(None)
    dims = dims[: len(shape)]
    out = []
    for size, ax in zip(shape, dims):
        if ax is None or ax not in mesh.axis_names or size % _axis_size(mesh, ax):
            out.append(None)
        else:
            out.append(ax)
    return P(*out)


def shard_hint(x, *spec):
    """Best-effort with_sharding_constraint: resolves logical axis names
    against the ambient mesh (trace-time `with mesh:` context); silently
    no-ops when no mesh / axes absent so model code stays mesh-agnostic.
    Spec entries: "batch" -> ("pod","data") as available, or literal axis
    names, or None."""
    try:
        env = jax.sharding.get_abstract_mesh()
        names = env.axis_names if env is not None else ()
    except Exception:
        names = ()
    if not names:
        return x
    resolved = []
    for s in spec:
        if s == "batch":
            ax = tuple(a for a in ("pod", "data") if a in names)
            resolved.append(ax if ax else None)
        elif s is None or s in names:
            resolved.append(s)
        else:
            resolved.append(None)
    # drop axes that do not divide the dim evenly
    def size_of(entry):
        if entry is None:
            return 1
        axs = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axs:
            n *= env.shape[a]
        return n
    final = [e if x.shape[i] % size_of(e) == 0 else None
             for i, e in enumerate(resolved)]
    try:
        return jax.lax.with_sharding_constraint(x, P(*final))
    except Exception:
        return x


def param_specs(params_shape, mesh: Mesh):
    """Map a params shape-pytree to PartitionSpecs via the path rules."""
    def one(path, leaf):
        s = _path_str(path)
        stacked = "blocks/" in s or s.startswith("blocks")
        for pat, raw in _PARAM_RULES:
            if re.search(pat, s):
                return NamedSharding(mesh, _fit_spec(leaf.shape, raw, mesh, stacked))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Logical batch axes present in this mesh (pod first if multi-pod)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_specs(batch_shape, mesh: Mesh, *, shard_batch: bool = True):
    """Shard the leading batch dim of every batch leaf over (pod, data)."""
    baxes = batch_axes(mesh)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]

    def one(leaf):
        if not shard_batch or leaf.ndim == 0 or leaf.shape[0] % bsize:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(baxes, *([None] * (leaf.ndim - 1))))
    return jax.tree.map(one, batch_shape)


def cache_specs(cache_shape, mesh: Mesh):
    """KV caches: (L, B, S, Hkv, hd) -> batch over (pod,data), seq over model.
    SSM states: (L, B, ...) -> batch over (pod,data), channel dims over model
    where divisible. `length` replicated."""
    baxes = batch_axes(mesh)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    msize = _axis_size(mesh, "model")

    def one(path, leaf):
        s = _path_str(path)
        if s == "length" or leaf.ndim <= 1:
            return NamedSharding(mesh, P())
        dims = [None] * leaf.ndim
        # leading (L, B, ...)
        bdim = 1 if leaf.ndim >= 2 else 0
        if leaf.shape[bdim] % bsize == 0 and bsize > 1:
            dims[bdim] = baxes
        # KV cache: shard seq (axis 2 of 5) over model; states: shard the
        # largest trailing dim over model if divisible.
        if leaf.ndim == 5 and leaf.shape[2] % msize == 0:
            dims[2] = "model"
        elif leaf.ndim >= 3:
            for ax in range(leaf.ndim - 1, 1, -1):
                if leaf.shape[ax] % msize == 0 and msize > 1:
                    dims[ax] = "model"
                    break
        return NamedSharding(mesh, P(*dims))
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def opt_specs(opt_state_shape, pspecs):
    """AdamW state (step, m, v): m/v shard like params, step replicated."""
    step_s, m_s, v_s = opt_state_shape
    mesh = jax.tree.leaves(pspecs)[0].mesh

    def like(tree):
        return jax.tree.map(lambda sh, sp: sp, tree, pspecs)
    from repro.optim.adamw import AdamWState
    return AdamWState(step=NamedSharding(mesh, P()), m=like(m_s), v=like(v_s))
