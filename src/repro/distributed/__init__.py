from . import sharding
