"""Straggler and failure detection for the training loop.

On a real multi-host job each host runs this monitor around its step; the
policy layer (runtime/train_loop.py) reacts:

  * slow step (> threshold x trailing median)   -> log + counter; repeated
    stragglers trigger a checkpoint so a scheduler can replace the host
  * missed heartbeat (host stops stepping)      -> after `grace` seconds the
    survivors restart from the last checkpoint on a shrunken mesh
    (checkpoint/elastic.py handles the re-shard)

Host-side logic only -- deliberately free of jax so it is unit-testable and
portable to any launcher.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class StragglerPolicy:
    slow_factor: float = 2.0        # step is "slow" above factor x median
    window: int = 32                # trailing steps for the median
    max_consecutive_slow: int = 3   # then recommend checkpoint + replace
    heartbeat_timeout_s: float = 300.0


class StragglerMonitor:
    def __init__(self, policy: StragglerPolicy = StragglerPolicy(),
                 clock: Callable[[], float] = time.monotonic):
        self.policy = policy
        self.clock = clock
        self.durations: List[float] = []
        self.consecutive_slow = 0
        self.last_heartbeat: Dict[int, float] = {}
        self.events: List[dict] = []

    # ------------------------------------------------------------ steps
    def record_step(self, duration_s: float) -> Optional[str]:
        """Returns an action: None | 'warn_slow' | 'checkpoint_and_replace'."""
        self.durations.append(duration_s)
        hist = self.durations[-self.policy.window - 1: -1]
        if len(hist) < 5:
            return None
        med = statistics.median(hist)
        if duration_s > self.policy.slow_factor * med:
            self.consecutive_slow += 1
            ev = {"type": "slow_step", "duration": duration_s, "median": med,
                  "consecutive": self.consecutive_slow}
            self.events.append(ev)
            if self.consecutive_slow >= self.policy.max_consecutive_slow:
                self.consecutive_slow = 0
                return "checkpoint_and_replace"
            return "warn_slow"
        self.consecutive_slow = 0
        return None

    # ------------------------------------------------------- heartbeats
    def heartbeat(self, host_id: int) -> None:
        self.last_heartbeat[host_id] = self.clock()

    def dead_hosts(self) -> List[int]:
        now = self.clock()
        return [h for h, t in self.last_heartbeat.items()
                if now - t > self.policy.heartbeat_timeout_s]

    def should_shrink(self) -> bool:
        return bool(self.dead_hosts())
