"""Explicit shard_map collectives: sequence-parallel decode attention and
quantized all-reduce.

`sp_decode_attention` is the scalable decode path (DESIGN.md Sec 4): the KV
cache's sequence axis lives on the `model` axis; each shard runs a local
online-softmax against its cache slice and the shards combine with one tiny
all-reduce of (m, l, acc) -- a distributed flash-decode. The relaxed-LAMP
threshold needs the global max of s = y + log|y|, which is one more scalar
all-reduce (pmax). This replaces an XLA-chosen all-gather of logits with
O(head_dim) traffic per (batch, head).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.numerics import round_to_mantissa

_NEG = -1e30


def sp_decode_attention(mesh: Mesh, q, k_cache, v_cache, length, *,
                        mu: int = 23, tau: float = 0.0, lamp: bool = False,
                        axis: str = "model", scale: Optional[float] = None,
                        window: Optional[int] = None,
                        batch_axes: Optional[Tuple[str, ...]] = None):
    """Sequence-parallel GQA decode attention.

    q (B, H, 1, D); caches (B, Hkv, S, D) bf16/f32 with S sharded over
    `axis` and B over `batch_axes`; length (B,). H = G * Hkv (grouped-query:
    KV heads are NEVER repeated/materialized -- the grouped einsum reads the
    cache once). Each shard runs a local online softmax over its cache slice
    and shards combine with one tiny (B,H,1[,D]) all-reduce.

    With lamp=True, the exact relaxed rule (9) runs distributed: one extra
    pmax carries the global row max of s = y + log|y| (cast-only PS(mu)
    tier, DESIGN.md Sec 5).

    Returns out (B, H, 1, D) float32.
    """
    B, H, _, D = q.shape
    Hkv = k_cache.shape[1]
    G = H // Hkv
    S = k_cache.shape[2]
    scale = scale if scale is not None else D ** -0.5
    baxes = batch_axes if batch_axes is not None else tuple(
        a for a in ("pod", "data") if a in mesh.axis_names and B % mesh.shape[a] == 0)
    bspec = baxes if baxes else None

    def local(q_l, k_l, v_l, len_l):
        sid = jax.lax.axis_index(axis)
        Bl = q_l.shape[0]
        S_l = k_l.shape[2]
        qg = (q_l.astype(jnp.float32) * scale).reshape(Bl, Hkv, G, D)
        pos = sid * S_l + jnp.arange(S_l)
        ok = pos[None, None, None, :] < len_l[:, None, None, None]   # (B,1,1,S_l)
        if window is not None:
            ok &= pos[None, None, None, :] > (len_l[:, None, None, None] - 1 - window)
        # grouped QK: cache read once, no head repetition; q cast down to
        # the cache dtype (bf16) with FP32 MXU accumulation -- the exact
        # value under the hardware's best accumulate (DESIGN.md Sec 3)
        y = jnp.einsum("bhgd,bhkd->bhgk", qg.astype(k_l.dtype), k_l,
                       preferred_element_type=jnp.float32)            # (B,Hkv,G,S_l)
        if lamp and mu < 23:
            y_low = round_to_mantissa(y, mu)  # cast-only tier at scale
            s = jnp.where(ok, y_low + jnp.log(jnp.abs(y_low)), _NEG)
            smax = jax.lax.pmax(jnp.max(s, axis=-1), axis)      # global rule (9)
            sel = ok & (s > jnp.log(jnp.maximum(tau, 1e-30)) + smax[..., None])
            y = jnp.where(sel, y, y_low)
        y = jnp.where(ok, y, _NEG)
        m_l = jnp.max(y, axis=-1)                                # (B,Hkv,G)
        p = jnp.where(ok, jnp.exp(y - m_l[..., None]), 0.0)
        l_l = jnp.sum(p, axis=-1)
        acc_l = jnp.einsum("bhgk,bhkd->bhgd", p.astype(v_l.dtype), v_l,
                           preferred_element_type=jnp.float32)
        # combine across shards: all-reduce of (m, l, acc), O(B*H*D) traffic
        m_g = jax.lax.pmax(m_l, axis)
        w = jnp.exp(m_l - m_g)
        l_g = jax.lax.psum(l_l * w, axis)
        acc_g = jax.lax.psum(acc_l * w[..., None], axis)
        out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
        return out.reshape(Bl, H, 1, D)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec), P(bspec, None, axis, None),
                  P(bspec, None, axis, None), P(bspec)),
        out_specs=P(bspec),
        check_rep=False)
    return fn(q, k_cache, v_cache, length)


def quantized_psum(mesh: Mesh, tree, *, axis: str = "data"):
    """int8-quantized gradient all-reduce via shard_map: quantize locally,
    psum the int32-accumulated payload, dequantize with the max scale.
    Wire cost ~= 1/4 of f32 psum; bias-free for symmetric quantization."""
    def local(*leaves):
        outs = []
        for g in leaves:
            gf = g.astype(jnp.float32)
            scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
            scale = jax.lax.pmax(scale, axis)           # shared scale
            q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int32)
            qs = jax.lax.psum(q, axis)
            n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
            outs.append(qs.astype(jnp.float32) * scale / n)
        return tuple(outs)

    flat, td = jax.tree.flatten(tree)
    fn = shard_map(local, mesh=mesh,
                   in_specs=tuple(P() for _ in flat),
                   out_specs=tuple(P() for _ in flat),
                   check_rep=False)
    return jax.tree.unflatten(td, list(fn(*flat)))
