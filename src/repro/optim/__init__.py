from . import adamw
from .adamw import AdamWConfig, AdamWState, init_state, apply_updates, cosine_schedule
