"""AdamW (decoupled weight decay) on pytrees, no external deps.

Optimizer state (m, v) is kept in FP32 regardless of param dtype (standard
bf16-training recipe); state shards identically to params (ZeRO-3 via the
same PartitionSpecs). Update math in FP32, cast back to the param dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_state(params) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState,
                  lr: Optional[jnp.ndarray] = None) -> Tuple[Any, AdamWState, Dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cfg.lr if lr is None else lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m / b1c
        vhat = v / b2c
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr_at(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup, warm, cos)
    return lr_at
