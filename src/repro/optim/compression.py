"""Gradient compression for bandwidth-bound data parallelism.

Two composable schemes (DESIGN.md: distributed-optimization tricks):

  * top-k sparsification with error feedback (memory): each worker sends
    only the largest-|g| fraction of every leaf; the residual is added back
    into the next step's gradient (Stich et al. / Deep Gradient Compression).
    Convergence-safe: the error-feedback memory guarantees all mass is
    eventually applied.

  * int8 quantization with per-leaf scale: linear quantization of the
    (already sparse or dense) gradient to int8 for the wire, dequantized
    after the all-reduce. 4x traffic cut vs f32 at <1% cosine distortion
    for typical gradient distributions.

These run *above* jit (pure functions over pytrees) so they compose with
any train step; the quantized collective itself is exercised in
distributed/collectives.py via shard_map.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def topk_compress(grads: Any, error: Any, frac: float) -> Tuple[Any, Any, Dict]:
    """Keep the top `frac` of entries per leaf (by |g|), carry the rest in
    the error-feedback memory. Returns (sparse_grads, new_error, stats)."""
    if not 0.0 < frac <= 1.0:
        raise ValueError("frac in (0, 1]")

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        if frac >= 1.0 or g.size <= 16:
            return gf, jnp.zeros_like(gf)
        k = max(1, int(g.size * frac))
        flat = jnp.abs(gf).reshape(-1)
        thr = jax.lax.top_k(flat, k)[0][-1]
        mask = jnp.abs(gf) >= thr
        sent = jnp.where(mask, gf, 0.0)
        return sent, gf - sent

    flat_g, td = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    sent = jax.tree.unflatten(td, [o[0] for o in outs])
    new_err = jax.tree.unflatten(td, [o[1] for o in outs])
    density = sum(float(jnp.mean((s != 0).astype(jnp.float32)) * s.size)
                  for s in jax.tree.leaves(sent))
    total = sum(s.size for s in jax.tree.leaves(sent))
    return sent, new_err, {"density": density / max(total, 1)}


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(tree: Any) -> Tuple[Any, Any]:
    """Per-leaf symmetric int8 quantization. Returns (q_tree, scales)."""
    def one(g):
        gf = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        return q, scale
    flat, td = jax.tree.flatten(tree)
    outs = [one(g) for g in flat]
    return (jax.tree.unflatten(td, [o[0] for o in outs]),
            jax.tree.unflatten(td, [o[1] for o in outs]))


def dequantize_int8(q_tree: Any, scales: Any) -> Any:
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, q_tree, scales)


def compressed_wire_bytes(tree: Any, frac: float) -> int:
    """Estimated wire bytes for topk(frac)+int8 vs dense f32 (for logging)."""
    n = sum(x.size for x in jax.tree.leaves(tree))
    k = int(n * frac)
    return k * (1 + 4)  # int8 payload + int32 index per surviving entry
