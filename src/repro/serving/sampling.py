"""Shared token-sampling primitives for every serving path.

One Gumbel-max core serves both samplers so the two serving stacks cannot
drift apart again (they used to: the batch loop divided by a raw, possibly
zero temperature behind a Python branch while the engine clamped it inside
the graph):

  * ``sample`` -- single-key batch sampling (the static-batch
    ``runtime.serve_loop`` path): one PRNG key for the whole batch, a
    Python-level temperature (greedy at ``t <= 0``).
  * ``sample_rows`` -- per-row keyed sampling (the continuous-batching
    engine): each row's key derives from ``(request seed, tokens generated
    so far[, salt])`` only, so a request's sample stream is deterministic
    regardless of batching, bucketing, or preemption. Temperatures are
    per-row arrays resolved inside the graph.

Both accept ``top_k``: logits outside the top-k are masked to -inf before
sampling (0 disables). ``sample_rows`` takes *per-row* top-k values so one
continuous batch can mix filtered and unfiltered requests; the filter is
exact under jit (dynamic kth-threshold via a per-row sort).

The speculative-decoding verifier reuses ``apply_top_k_rows`` so the
residual-resampling acceptance rule sees exactly the filtered distributions
the drafter and the non-speculative sampler would have sampled from.

Salts: one request consumes several independent draws per position under
speculative decoding (draft proposal, acceptance uniform, residual
resample). Each caller folds a distinct ``salt`` into the key so the draws
never collide with each other or with the plain sampler (salt 0).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# key salts (folded after the position counter; 0 = the plain sampler)
SALT_SAMPLE = 0
SALT_DRAFT = 1
SALT_ACCEPT = 2
SALT_RESIDUAL = 3


def apply_top_k(logits: jnp.ndarray, top_k: int) -> jnp.ndarray:
    """Static (whole-batch) top-k filter: entries below the kth-largest
    logit go to -inf. ``top_k <= 0`` or ``>= vocab`` is the identity."""
    V = logits.shape[-1]
    if top_k <= 0 or top_k >= V:
        return logits
    kth = jnp.sort(logits, axis=-1)[..., V - top_k][..., None]
    return jnp.where(logits >= kth, logits, -jnp.inf)


def apply_top_k_rows(logits: jnp.ndarray, top_k: jnp.ndarray) -> jnp.ndarray:
    """Per-row top-k filter under jit. logits (R, ..., V); top_k (R,) int32,
    0 = unfiltered for that row. Rows keep every logit tied with the kth
    largest (the same semantics as the static filter)."""
    V = logits.shape[-1]
    srt = jnp.sort(logits, axis=-1)
    k = jnp.clip(top_k, 1, V)
    k = k.reshape(k.shape + (1,) * (logits.ndim - 1))
    kth = jnp.take_along_axis(srt, V - k, axis=-1)
    filtered = jnp.where(logits >= kth, logits, -jnp.inf)
    on = (top_k > 0).reshape(k.shape)
    return jnp.where(on, filtered, logits)


def row_key(seed, count, salt: int = SALT_SAMPLE):
    """The engine's per-request key schedule: fold the position counter into
    the request seed, then the caller's salt. ``SALT_SAMPLE`` skips the salt
    fold and reproduces the pre-speculative engine schedule bit-for-bit;
    the speculative salts derive disjoint streams from the same base key."""
    k = jax.random.fold_in(jax.random.PRNGKey(seed), count)
    if salt == SALT_SAMPLE:
        return k
    return jax.random.fold_in(k, salt)


def _gumbel_argmax(lg, key, t):
    """Greedy at t <= 0, Gumbel-max otherwise; t is resolved in-graph."""
    g = jax.random.gumbel(key, lg.shape)
    samp = jnp.argmax(lg / jnp.maximum(t, 1e-6) + g)
    return jnp.where(t > 0, samp, jnp.argmax(lg))


def sample_rows(logits, seeds, counts, temps, top_k=None,
                salt: int = SALT_SAMPLE):
    """Per-row sampling: greedy at temp<=0, Gumbel-max otherwise. The key is
    derived from (request seed, tokens generated so far, salt) only.
    logits (R, V); seeds/counts int32 (R,); temps float32 (R,); top_k
    optional int32 (R,) (None/0 = unfiltered)."""
    if top_k is not None:
        logits = apply_top_k_rows(logits, top_k)

    def one(lg, s, c, t):
        return _gumbel_argmax(lg, row_key(s, c, salt), t)

    return jax.vmap(one)(logits, seeds, counts, temps)


def sample(logits, key, temperature: float, top_k: int = 0):
    """Single-key batch sampling (static-batch loop): logits (..., V), one
    PRNG key, Python-level temperature (greedy at <= 0)."""
    logits = apply_top_k(logits, top_k)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    g = jax.random.gumbel(key, logits.shape)
    return jnp.argmax(logits / temperature + g, axis=-1)


def row_uniforms(seeds, counts, salt: int):
    """One uniform draw per (row, count) keyed on (seed, count, salt) -- the
    speculative acceptance coin flips. seeds (R,); counts (R,) or (R, k)."""
    def one(s, c):
        return jax.random.uniform(row_key(s, c, salt), ())
    if jnp.ndim(counts) == 2:
        return jax.vmap(lambda s, cs: jax.vmap(lambda c: one(s, c))(cs))(
            seeds, counts)
    return jax.vmap(one)(seeds, counts)


def row_gumbel(seeds, counts, salt: int, shape):
    """One Gumbel vector of ``shape`` per row keyed on (seed, count, salt)
    -- the speculative residual resample."""
    def one(s, c):
        return jax.random.gumbel(row_key(s, c, salt), shape)
    return jax.vmap(one)(seeds, counts)
