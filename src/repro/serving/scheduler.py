"""Continuous-batching scheduler.

Policy (vLLM-v0 style, adapted to the fixed-shape jit constraint):

  * Admission is FCFS from the waiting queue, gated by the free-block
    budget. With prefix caching on, a prompt's full-block chain is first
    matched against the pool's prefix index: matched blocks are shared
    (refcounted) instead of allocated, the match is capped at prompt-1
    tokens (at least one token must run to produce logits), and a cap that
    lands mid-block copies that block on write before the sequence may fill
    its tail.
  * Each step is either one prefill batch or one decode batch (fixed-shape,
    padded to buckets so jit recompilation is bounded). Prefill is
    prioritized, but never twice in a row while sequences are decoding --
    this alternation plus FCFS preemption order makes the oldest request
    always progress (no starvation).
  * Chunked prefill: a prompt prefills in `max_prefill_tokens`-sized chunks
    across steps (the per-sequence `prefill_cursor` tracks progress), so a
    long prompt never monopolizes a step and decode latency stays bounded --
    the alternation rule interleaves decode steps between chunks. Blocks are
    allocated per chunk, not for the whole prompt up front.
  * When the pool cannot cover the decode batch's next KV writes, running
    sequences are preempted youngest-first (recompute-style eviction: blocks
    freed, sequence requeued at the *front* of the waiting queue with its
    generated tokens kept). A preempted sequence's filled full blocks are
    registered in the prefix index first, so -- capacity permitting -- its
    resume re-prefills only the un-cached suffix.
  * Speculative decoding (`spec_draft_len` > 0): each decode round grants a
    per-sequence draft budget, oldest-first, accounted against the prefill
    token budget (the verify pass is a (kd+1)-token windowed forward, the
    same compute shape as a prefill chunk) and capped by the sequence's own
    token limit. Block demand covers the whole speculative span
    (cache_len .. cache_len + kd); under pressure the scheduler sheds draft
    lookahead before preempting anyone -- kd = 0 degrades a round to a
    plain decode step, so speculation can never deadlock the pool.

Progress guarantee: the engine validates that the pool can hold at least one
maximal sequence, so a lone running sequence can always allocate its next
block and the oldest request can always eventually run to completion.
"""

from __future__ import annotations

import contextlib
import dataclasses
from collections import deque
from typing import Deque, List, Optional, Set

from .faults import ArenaAllocFault
from .kv_pool import PagedKVPool, chain_hashes
from .request import Sequence, SequenceStatus


@dataclasses.dataclass
class StepPlan:
    kind: str                  # "prefill" | "decode" | "mixed"
    seqs: List[Sequence]
    # prefill / mixed: live tokens each row runs this step. For prefill rows
    # that is the chunk window starting at prefill_cursor; for mixed decode /
    # verify rows it is 1 + draft_lens[i] (the verify span incl. the bonus
    # position)
    windows: Optional[List[int]] = None
    # decode / mixed, speculative engines: tokens each sequence may draft
    # this round (0 = plain decode / verify-only; always 0 for prefill rows)
    draft_lens: Optional[List[int]] = None
    # mixed only: per-row role -- "prefill" (chunk window), "decode" (plain
    # next-token row) or "verify" (speculative round with draft_lens[i] > 0)
    roles: Optional[List[str]] = None


class Scheduler:
    def __init__(self, pool: PagedKVPool, *, max_prefill_batch: int = 8,
                 max_prefill_tokens: int = 2048, max_decode_batch: int = 32,
                 chunked_prefill: bool = False, spec_draft_len: int = 0,
                 mixed: bool = False, obs=None):
        self.pool = pool
        # optional Observability (repro.obs): block-alloc spans + preemption
        # instants; None (standalone scheduler tests) degrades to no-ops
        self._obs = obs
        self._span = (obs.span if obs is not None
                      else lambda name, **kw: contextlib.nullcontext())
        self.max_prefill_batch = max_prefill_batch
        self.max_prefill_tokens = max_prefill_tokens
        self.max_decode_batch = max_decode_batch
        self.chunked_prefill = chunked_prefill
        self.spec_draft_len = spec_draft_len
        # fused-step mode: every schedule() emits one "mixed" StepPlan
        # carrying prefill windows, decode rows and speculative verify rows
        # together (per-row roles), instead of alternating phase-segregated
        # prefill / decode plans
        self.mixed = mixed
        self.waiting: Deque[Sequence] = deque()
        self.running: List[Sequence] = []
        self.num_preemptions = 0
        # allocation failures (injected or real transients) absorbed by
        # degrading the step instead of crashing -- the engine publishes the
        # per-step delta as engine_recoveries_total{action="alloc_defer"}
        self.alloc_fault_degrades = 0
        self._last_was_prefill = False

    # -- queue ops ----------------------------------------------------------

    def add(self, seq: Sequence) -> None:
        self.waiting.append(seq)

    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.running)

    def _preempt_youngest(self, keep: Optional[Sequence] = None) -> bool:
        """Evict the youngest running sequence (never `keep`). Returns False
        when there is nothing evictable."""
        for victim in sorted(self.running, key=lambda s: s.arrival_time,
                             reverse=True):
            if victim is keep:
                continue
            self.running.remove(victim)
            if self.pool.enable_prefix_cache:
                # keep the evicted KV matchable: resume (or any request with
                # the same prefix) re-prefills only the un-cached suffix
                self.pool.register_prefix(victim.prefill_tokens(),
                                          victim.block_ids, victim.cache_len)
            # free tail-first so the cached-free LRU evicts chain tails
            # before the heads that every matching prefix needs
            self.pool.free_blocks(reversed(victim.block_ids))
            victim.preempt()
            self.waiting.appendleft(victim)
            self.num_preemptions += 1
            if self._obs is not None and self._obs.tracer.enabled:
                self._obs.tracer.instant("preempt", cat="sched",
                                         req=victim.req_id)
            return True
        return False

    # -- step composition ---------------------------------------------------

    def _grow_window(self, seq: Sequence, want: int) -> int:
        """Allocate blocks so `seq` can prefill `want` more tokens; shrinks
        the window to what the free-block budget covers. Returns the granted
        window (0 = no progress possible)."""
        if want <= 0:
            return 0
        bs = self.pool.block_size
        avail = (len(seq.block_ids) + self.pool.num_free) * bs \
            - seq.prefill_cursor
        window = min(want, avail)
        if window <= 0:
            return 0
        need = self.pool.blocks_for(seq.prefill_cursor + window) \
            - len(seq.block_ids)
        if need > 0:
            try:
                with self._span("alloc", blocks=need, req=seq.req_id):
                    seq.block_ids.extend(self.pool.alloc(need))
            except ArenaAllocFault:
                # degrade: this row skips its chunk this step and retries
                # next step (nothing was allocated, nothing to unwind)
                self.alloc_fault_degrades += 1
                return 0
        return window

    def _try_admit(self, seq: Sequence, want: int,
                   pending: Set[int]) -> Optional[int]:
        """Admit a waiting sequence: match its prefix chain against the
        cache, share matched blocks, COW a mid-block cap, and allocate the
        first window. Returns the granted window, 0 to defer the sequence to
        the next step (its prefix is being written by this very batch), or
        None when the block budget cannot cover admission."""
        tokens = seq.prefill_tokens()
        target = len(tokens)
        bs = self.pool.block_size
        matched: List[int] = []
        hashes: List[int] = []
        if self.pool.enable_prefix_cache:
            # the prompt is immutable while waiting: hash it once and keep
            # the chain on the sequence across failed admission retries and
            # for per-chunk registration (preempt() clears it)
            if not seq.prefix_hashes:
                seq.prefix_hashes = chain_hashes(tokens, bs)
            hashes = seq.prefix_hashes
            if hashes and hashes[0] in pending:
                # an earlier admission in this same batch is about to write
                # and register this prefix; wait one step and share it
                return 0
            matched = self.pool.match_prefix(tokens, hashes)
        while True:
            cached = min(len(matched) * bs, target - 1)
            kept = -(-cached // bs)
            matched = matched[:kept]
            window = target - cached
            if self.chunked_prefill:
                window = min(window, max(want, 1))
            # block budget: fresh blocks for the window, one COW copy if the
            # match cap lands mid-block, and revived cached-free blocks all
            # come out of num_free
            need_new = self.pool.blocks_for(cached + window) - kept
            need_cow = 1 if cached % bs else 0
            revive = sum(1 for b in matched if self.pool.is_cached_free(b))
            if need_new + need_cow + revive <= self.pool.num_free:
                break
            if not matched:
                return None
            # share + COW overhead does not fit: degrade gracefully by
            # dropping the least-valuable cached block (the chain tail) and
            # recomputing its tokens instead
            matched = matched[:-1]
        hit0 = self.pool.hit_blocks
        try:
            self.pool.share(matched)
            seq.block_ids = list(matched)
            if need_cow:
                seq.block_ids[-1] = self.pool.copy_on_write(seq.block_ids[-1])
                # the COW'd tail is not an avoided allocation (its KV is
                # still reused, which num_cached_tokens reflects)
                self.pool.hit_blocks -= 1
            if need_new > 0:
                with self._span("alloc", blocks=need_new, req=seq.req_id):
                    seq.block_ids.extend(self.pool.alloc(need_new))
        except ArenaAllocFault:
            # degrade: unwind the partial admission (drop the shared owners,
            # restore the hit accounting) and defer the sequence; it stays
            # at the front of the waiting queue and retries next step
            self.pool.free_blocks(reversed(seq.block_ids))
            seq.block_ids = []
            self.pool.hit_blocks = hit0
            self.alloc_fault_degrades += 1
            return None
        seq.prefill_cursor = cached
        seq.cache_len = cached
        # a resumed sequence matching blocks it registered at its own
        # preemption is not a cross-request cache win: count it separately
        # so the cache hit rate is not double-counted by preemption churn
        if seq.num_preemptions > 0:
            seq.num_resume_cached_tokens += cached
        else:
            seq.num_cached_tokens += cached
        seq.status = SequenceStatus.PREFILL
        pending.update(hashes[:(cached + window) // bs])
        return window

    def _try_prefill(self) -> Optional[StepPlan]:
        batch: List[Sequence] = []
        windows: List[int] = []
        budget = self.max_prefill_tokens
        # 1. continue partially-prefilled running sequences, oldest first
        if self.chunked_prefill:
            for seq in sorted(self.running, key=lambda s: s.arrival_time):
                if seq.status != SequenceStatus.PREFILL:
                    continue
                if len(batch) >= self.max_prefill_batch or budget <= 0:
                    break
                window = self._grow_window(
                    seq, min(seq.prefill_remaining, budget))
                if window == 0:
                    # block-starved (free list empty, tail block full):
                    # younger sequences with in-block slack can still
                    # advance without allocating — no stealing possible
                    continue
                batch.append(seq)
                windows.append(window)
                budget -= window
        # 2. admit new / resumed sequences FCFS
        pending: Set[int] = set()
        while self.waiting and len(batch) < self.max_prefill_batch:
            seq = self.waiting[0]
            if not self.chunked_prefill and batch \
                    and seq.prefill_remaining > budget:
                break
            if self.chunked_prefill and batch and budget <= 0:
                break
            window = self._try_admit(seq, budget, pending)
            if window is None or window == 0:
                break
            batch.append(self.waiting.popleft())
            windows.append(window)
            budget -= window
        if not batch:
            return None
        for seq in batch:
            if seq not in self.running:
                self.running.append(seq)
        return StepPlan("prefill", batch, windows)

    def _grant_draft_budgets(self, batch: List[Sequence],
                             budget: Optional[int] = None) -> List[int]:
        """Per-sequence speculative draft budget for this round, granted
        oldest-first. A round's verify pass is a (kd + 1)-token windowed
        forward per row -- the same compute shape as a prefill chunk -- so
        speculative tokens are accounted against the prefill token budget:
        the batch's base verify positions (one per row, == plain decode)
        are free, and sum(kd) is capped at what the budget has left (mixed
        plans pass the budget that their prefill windows did not spend). A
        sequence never drafts past its own token limit (the round emits at
        most kd + 1 tokens)."""
        if self.spec_draft_len <= 0:
            return [0] * len(batch)
        if budget is None:
            budget = max(0, self.max_prefill_tokens - len(batch))
        out = []
        for seq in batch:              # batch is already oldest-first
            kd = min(self.spec_draft_len, budget,
                     max(0, seq.sampling.max_new_tokens
                         - seq.num_generated - 1))
            out.append(kd)
            budget -= kd
        return out

    def _try_decode(self) -> Optional[StepPlan]:
        while True:
            ready = [s for s in self.running
                     if s.status == SequenceStatus.DECODE]
            if not ready:
                return None
            batch = sorted(ready,
                           key=lambda s: s.arrival_time)[:self.max_decode_batch]
            draft_lens = self._grant_draft_budgets(batch)
            while True:
                # blocks to cover each sequence's next-token KV write plus
                # its speculative lookahead (draft + verify write positions
                # cache_len .. cache_len + kd)
                deficits = []
                need = 0
                for seq, kd in zip(batch, draft_lens):
                    want = self.pool.blocks_for(seq.cache_len + 1 + kd)
                    deficits.append(max(0, want - len(seq.block_ids)))
                    need += deficits[-1]
                if need <= self.pool.num_free:
                    try:
                        if need > 0:
                            with self._span("alloc", blocks=need):
                                for seq, deficit in zip(batch, deficits):
                                    if deficit:
                                        seq.block_ids.extend(
                                            self.pool.alloc(deficit))
                    except ArenaAllocFault:
                        # degrade and re-grant: blocks already extended stay
                        # owned; the recomputed deficits skip them
                        self.alloc_fault_degrades += 1
                        continue
                    return StepPlan("decode", batch, draft_lens=draft_lens)
                if any(draft_lens):
                    # shed speculative lookahead before evicting anyone: a
                    # shorter draft is strictly cheaper than a recompute
                    draft_lens = [max(0, kd - 1) for kd in draft_lens]
                    continue
                if self._preempt_youngest(keep=batch[0]):
                    break              # recompose the batch
                raise RuntimeError(
                    "KV pool too small for a single sequence; raise n_blocks")

    def _mixed_decode_part(self, pre_seqs: List[Sequence],
                           pre_windows: List[int]):
        """Decode/verify rows of a mixed plan. Mirrors `_try_decode` --
        draft budgets shed before anyone is preempted -- except that draft
        budgets come out of what the plan's prefill windows left of the
        token budget, and preemption protects the oldest plan member
        overall. A preemption that evicts one of this very plan's prefill
        rows drops that row from the plan (its blocks are already freed and
        the sequence is requeued; nothing has run yet)."""
        while True:
            ready = [s for s in self.running
                     if s.status == SequenceStatus.DECODE]
            if not ready:
                return [], []
            batch = sorted(ready, key=lambda s: s.arrival_time
                           )[:self.max_decode_batch]
            budget = max(0, self.max_prefill_tokens - sum(pre_windows)
                         - len(batch))
            draft_lens = self._grant_draft_budgets(batch, budget=budget)
            while True:
                deficits = []
                need = 0
                for seq, kd in zip(batch, draft_lens):
                    want = self.pool.blocks_for(seq.cache_len + 1 + kd)
                    deficits.append(max(0, want - len(seq.block_ids)))
                    need += deficits[-1]
                if need <= self.pool.num_free:
                    try:
                        if need > 0:
                            with self._span("alloc", blocks=need):
                                for seq, deficit in zip(batch, deficits):
                                    if deficit:
                                        seq.block_ids.extend(
                                            self.pool.alloc(deficit))
                    except ArenaAllocFault:
                        self.alloc_fault_degrades += 1
                        continue
                    return batch, draft_lens
                if any(draft_lens):
                    draft_lens = [max(0, kd - 1) for kd in draft_lens]
                    continue
                keep = min(pre_seqs + batch, key=lambda s: s.arrival_time)
                if self._preempt_youngest(keep=keep):
                    for i in range(len(pre_seqs) - 1, -1, -1):
                        if pre_seqs[i].status == SequenceStatus.WAITING:
                            pre_seqs.pop(i)
                            pre_windows.pop(i)
                    break              # recompose the decode rows
                raise RuntimeError(
                    "KV pool too small for a single sequence; raise n_blocks")

    def _schedule_mixed(self) -> Optional[StepPlan]:
        """One fused step: prefill windows first (chunk continuation +
        admission, exactly `_try_prefill`), then decode/verify rows funded
        by the leftover token budget -- all in a single mixed StepPlan.
        Prefill-first plus FCFS admission and oldest-protected preemption
        preserves the split scheduler's no-starvation guarantee; decode
        rows cost one token each regardless, so they always ride along."""
        pre = self._try_prefill()
        pre_seqs = list(pre.seqs) if pre is not None else []
        pre_windows = list(pre.windows) if pre is not None else []
        dec_batch, draft_lens = self._mixed_decode_part(pre_seqs, pre_windows)
        if not pre_seqs and not dec_batch:
            prefill_work = bool(self.waiting) or any(
                s.status == SequenceStatus.PREFILL for s in self.running)
            if not (prefill_work and self.running):
                return None
            # every runnable sequence is mid-prefill but starved of blocks:
            # evict youngest-first until the oldest can advance (the split
            # path's recovery)
            oldest = min(self.running, key=lambda s: s.arrival_time)
            while self._preempt_youngest(keep=oldest):
                pre = self._try_prefill()
                if pre is not None:
                    pre_seqs = list(pre.seqs)
                    pre_windows = list(pre.windows)
                    break
            if not pre_seqs:
                raise RuntimeError(
                    "KV pool too small for a single sequence; raise n_blocks")
        roles = (["prefill"] * len(pre_seqs)
                 + ["verify" if kd else "decode" for kd in draft_lens])
        return StepPlan(
            "mixed", pre_seqs + dec_batch,
            windows=pre_windows + [1 + kd for kd in draft_lens],
            draft_lens=[0] * len(pre_seqs) + draft_lens,
            roles=roles)

    def schedule(self) -> Optional[StepPlan]:
        if self.mixed:
            return self._schedule_mixed()
        decode_possible = any(s.status == SequenceStatus.DECODE
                              for s in self.running)
        prefill_work = bool(self.waiting) or any(
            s.status == SequenceStatus.PREFILL for s in self.running)
        prefer_prefill = prefill_work and not (
            self._last_was_prefill and decode_possible)
        plan = None
        if prefer_prefill:
            plan = self._try_prefill()
        if plan is None and decode_possible:
            plan = self._try_decode()
        if plan is None and prefill_work and not prefer_prefill:
            plan = self._try_prefill()
        if plan is None and prefill_work and not decode_possible \
                and self.running:
            # every runnable sequence is mid-prefill but starved of blocks:
            # evict youngest-first until the oldest can advance
            oldest = min(self.running, key=lambda s: s.arrival_time)
            while self._preempt_youngest(keep=oldest):
                plan = self._try_prefill()
                if plan is not None:
                    break
            if plan is None:
                raise RuntimeError(
                    "KV pool too small for a single sequence; raise n_blocks")
        self._last_was_prefill = plan is not None and plan.kind == "prefill"
        return plan

    def finish(self, seq: Sequence) -> None:
        """Release a finished sequence's resources. Registered prefix blocks
        survive on the pool's cached-free list (tail-first, so eviction
        reclaims chain tails before shared heads) until evicted."""
        self.running.remove(seq)
        self.pool.free_blocks(reversed(seq.block_ids))
        seq.block_ids = []

    def cancel(self, seq: Sequence) -> None:
        """Remove a sequence from wherever it sits (waiting queue or running
        set) and release its blocks: deadline expiry, health-guard failure,
        and stall eviction all route through here. Idempotent-safe against
        the queue/running split; freeing mirrors `finish` (tail-first)."""
        if seq in self.running:
            self.running.remove(seq)
        else:
            try:
                self.waiting.remove(seq)
            except ValueError:
                pass
        if seq.block_ids:
            self.pool.free_blocks(reversed(seq.block_ids))
            seq.block_ids = []
