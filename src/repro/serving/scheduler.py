"""Continuous-batching scheduler.

Policy (vLLM-v0 style, adapted to the fixed-shape jit constraint):

  * Admission is FCFS from the waiting queue, gated by the free-block
    budget: a prompt is admitted only if all its prefill blocks fit.
  * Each step is either one prefill batch or one decode batch (fixed-shape,
    padded to buckets so jit recompilation is bounded). Prefill is
    prioritized, but never twice in a row while sequences are decoding --
    this alternation plus FCFS preemption order makes the oldest request
    always progress (no starvation).
  * When the pool cannot cover the decode batch's next KV writes, running
    sequences are preempted youngest-first (recompute-style eviction: blocks
    freed, sequence requeued at the *front* of the waiting queue with its
    generated tokens kept).

Progress guarantee: the engine validates that the pool can hold at least one
maximal sequence, so a lone running sequence can always allocate its next
block and the oldest request can always eventually run to completion.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

from .kv_pool import PagedKVPool
from .request import Sequence, SequenceStatus


@dataclasses.dataclass
class StepPlan:
    kind: str                  # "prefill" | "decode"
    seqs: List[Sequence]


class Scheduler:
    def __init__(self, pool: PagedKVPool, *, max_prefill_batch: int = 8,
                 max_prefill_tokens: int = 2048, max_decode_batch: int = 32):
        self.pool = pool
        self.max_prefill_batch = max_prefill_batch
        self.max_prefill_tokens = max_prefill_tokens
        self.max_decode_batch = max_decode_batch
        self.waiting: Deque[Sequence] = deque()
        self.running: List[Sequence] = []
        self.num_preemptions = 0
        self._last_was_prefill = False

    # -- queue ops ----------------------------------------------------------

    def add(self, seq: Sequence) -> None:
        self.waiting.append(seq)

    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.running)

    def _preempt_youngest(self, keep: Optional[Sequence] = None) -> bool:
        """Evict the youngest running sequence (never `keep`). Returns False
        when there is nothing evictable."""
        for victim in sorted(self.running, key=lambda s: s.arrival_time,
                             reverse=True):
            if victim is keep:
                continue
            self.running.remove(victim)
            self.pool.free_blocks(victim.block_ids)
            victim.preempt()
            self.waiting.appendleft(victim)
            self.num_preemptions += 1
            return True
        return False

    # -- step composition ---------------------------------------------------

    def _try_prefill(self) -> Optional[StepPlan]:
        batch: List[Sequence] = []
        budget = self.max_prefill_tokens
        while self.waiting and len(batch) < self.max_prefill_batch:
            seq = self.waiting[0]
            n_tok = len(seq.prefill_tokens())
            if batch and n_tok > budget:
                break
            need = self.pool.blocks_for(n_tok)
            if not self.pool.can_alloc(need):
                break
            seq.block_ids = self.pool.alloc(need)
            seq.cache_len = 0
            seq.status = SequenceStatus.PREFILL
            batch.append(self.waiting.popleft())
            budget -= n_tok
        if not batch:
            return None
        self.running.extend(batch)
        return StepPlan("prefill", batch)

    def _try_decode(self) -> Optional[StepPlan]:
        while self.running:
            batch = sorted(self.running,
                           key=lambda s: s.arrival_time)[:self.max_decode_batch]
            # blocks needed to write each sequence's next token KV
            short = []
            need = 0
            for seq in batch:
                want = self.pool.blocks_for(seq.cache_len + 1)
                if want > len(seq.block_ids):
                    short.append(seq)
                    need += want - len(seq.block_ids)
            if need <= self.pool.num_free:
                for seq in short:
                    seq.block_ids.extend(self.pool.alloc(1))
                for seq in batch:
                    seq.status = SequenceStatus.DECODE
                return StepPlan("decode", batch)
            if not self._preempt_youngest(keep=batch[0]):
                raise RuntimeError(
                    "KV pool too small for a single sequence; raise n_blocks")
        return None

    def schedule(self) -> Optional[StepPlan]:
        decode_possible = bool(self.running)
        prefer_prefill = bool(self.waiting) and not (
            self._last_was_prefill and decode_possible)
        plan = None
        if prefer_prefill:
            plan = self._try_prefill()
        if plan is None and decode_possible:
            plan = self._try_decode()
        if plan is None and self.waiting and not prefer_prefill:
            plan = self._try_prefill()
        self._last_was_prefill = plan is not None and plan.kind == "prefill"
        return plan

    def finish(self, seq: Sequence) -> None:
        """Release a finished sequence's resources."""
        self.running.remove(seq)
        self.pool.free_blocks(seq.block_ids)
        seq.block_ids = []
