"""Request/Sequence lifecycle objects for the continuous-batching engine.

A `Sequence` tracks one request through
    WAITING -> PREFILL -> DECODE -> FINISHED
with preemption (recompute-style eviction) looping it back to WAITING: the
KV blocks are dropped and on re-admission the prompt *plus the tokens
generated so far* are re-prefilled, so generation resumes exactly where it
stopped. Per-request LAMP telemetry (selected / valid KQ-product counts from
the paged attention path) accumulates across prefill, decode, and resumes.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np


class SequenceStatus(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 = greedy
    seed: int = 0                   # per-request sampling stream
    stop_token: Optional[int] = None
    top_k: int = 0                  # 0 = unfiltered; else sample from the
                                    # top-k logits only (also the filter the
                                    # speculative accept rule scores against)
    deadline_s: float = 0.0         # wall-clock TTL from arrival; 0 = none.
                                    # An expired request is cancelled with
                                    # finish_reason="timeout", blocks freed


@dataclasses.dataclass
class LampStats:
    """Accumulated LAMP recompute telemetry for one request."""
    selected: float = 0.0           # KQ products recomputed in high precision
    valid: float = 0.0              # KQ products inside the causal mask
    # per-layer breakdown (length n_layers once populated; each sums to the
    # scalar above) -- populated by the engine's per-layer step counts
    by_layer_selected: Optional[np.ndarray] = None
    by_layer_valid: Optional[np.ndarray] = None

    @property
    def recompute_rate(self) -> float:
        return self.selected / self.valid if self.valid > 0 else 0.0

    @property
    def layer_rates(self) -> List[float]:
        if self.by_layer_selected is None:
            return []
        return [float(s / v) if v else 0.0 for s, v in
                zip(self.by_layer_selected, self.by_layer_valid)]

    def add(self, selected: float, valid: float) -> None:
        self.selected += float(selected)
        self.valid += float(valid)

    def add_layers(self, selected, valid) -> None:
        """Accumulate one step's per-layer (L,) counts (and the totals)."""
        selected = np.asarray(selected, np.float64)
        valid = np.asarray(valid, np.float64)
        if self.by_layer_selected is None:
            self.by_layer_selected = np.zeros_like(selected)
            self.by_layer_valid = np.zeros_like(valid)
        self.by_layer_selected += selected
        self.by_layer_valid += valid
        self.add(selected.sum(), valid.sum())


class Sequence:
    """One request's mutable serving state."""

    def __init__(self, req_id: int, prompt: List[int],
                 sampling: SamplingParams, arrival_time: float):
        self.req_id = req_id
        self.prompt = list(prompt)
        self.sampling = sampling
        self.arrival_time = arrival_time
        self.status = SequenceStatus.WAITING
        self.generated: List[int] = []
        self.block_ids: List[int] = []
        # tokens whose KV is in the arena (prompt + generated - 1 once
        # decoding: the latest sampled token's KV is written by the next step)
        self.cache_len = 0
        # prefill progress: tokens of prefill_tokens() already in the arena
        # (prefix-cache hits + completed chunks); equals cache_len while the
        # sequence is mid-prefill, frozen at the prefill target afterwards
        self.prefill_cursor = 0
        # prompt tokens served from the prefix cache. Cross-request hits
        # (first admission) and this sequence re-hitting its *own* KV after
        # a preemption are tracked separately: resume self-hits are not
        # avoided work relative to a never-preempted run, so folding them
        # into num_cached_tokens would inflate the cache hit rate
        self.num_cached_tokens = 0
        self.num_resume_cached_tokens = 0
        # chain hashes of prefill_tokens(), computed once at admission so
        # per-chunk registration does not rehash the whole prefix
        self.prefix_hashes: List[int] = []
        self.num_preemptions = 0
        # speculative-decoding cursors: tokens this request drafted and how
        # many of those drafts the verifier accepted (across all rounds)
        self.spec_drafted = 0
        self.spec_accepted = 0
        # shadow-audit accumulation (obs/audit.py): audited steps this
        # request rode in, summed final-logit relative error, and argmax
        # flips -- folded into the per-request cumulative-error histogram
        # and RequestOutput at finish
        self.audit_samples = 0
        self.audit_err_sum = 0.0
        self.audit_flips = 0
        self.first_token_time: Optional[float] = None
        self.finish_time: Optional[float] = None
        self.finish_reason: Optional[str] = None
        self.lamp = LampStats()

    # -- lifecycle ----------------------------------------------------------

    @property
    def is_finished(self) -> bool:
        return self.status == SequenceStatus.FINISHED

    def prefill_tokens(self) -> List[int]:
        """Tokens to run at (re-)prefill: prompt plus anything generated
        before a preemption."""
        return self.prompt + self.generated

    @property
    def prefill_target(self) -> int:
        return len(self.prompt) + len(self.generated)

    @property
    def prefill_remaining(self) -> int:
        return self.prefill_target - self.prefill_cursor

    @property
    def num_generated(self) -> int:
        return len(self.generated)

    @property
    def last_token(self) -> int:
        return self.generated[-1] if self.generated else self.prompt[-1]

    @property
    def total_len(self) -> int:
        """Max cache positions this request can ever need."""
        return len(self.prompt) + self.sampling.max_new_tokens

    @property
    def spec_acceptance_rate(self) -> float:
        """Fraction of drafted tokens the verifier accepted (in [0, 1])."""
        return (self.spec_accepted / self.spec_drafted
                if self.spec_drafted else 0.0)

    def on_token(self, token: int, now: float) -> None:
        if self.first_token_time is None:
            self.first_token_time = now
        self.generated.append(token)

    def should_stop(self) -> Optional[str]:
        if self.generated and self.generated[-1] == self.sampling.stop_token:
            return "stop_token"
        if self.num_generated >= self.sampling.max_new_tokens:
            return "length"
        return None

    def finish(self, reason: str, now: float) -> None:
        self.status = SequenceStatus.FINISHED
        self.finish_reason = reason
        self.finish_time = now

    def preempt(self) -> None:
        """Recompute-style eviction: drop KV, keep generated tokens."""
        assert not self.is_finished
        self.status = SequenceStatus.WAITING
        self.block_ids = []
        self.cache_len = 0
        self.prefill_cursor = 0
        self.prefix_hashes = []
        self.num_preemptions += 1

    # -- metrics ------------------------------------------------------------

    def latency(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Sequence(id={self.req_id}, status={self.status.value}, "
                f"prompt={len(self.prompt)}, gen={self.num_generated}, "
                f"blocks={len(self.block_ids)}, preempt={self.num_preemptions})")
