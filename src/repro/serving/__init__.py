"""Continuous-batching LAMP serving engine.

vLLM-style serving architecture over the repro model stack:

  request.py   -- Request/Sequence lifecycle (WAITING -> PREFILL -> DECODE ->
                  FINISHED), per-request sampling params and LAMP stats
  kv_pool.py   -- paged KV-cache pool: refcounted block tables over a shared
                  (L, n_blocks, block_size, Hkv, hd) arena, chain-hashed
                  prefix index with copy-on-write sharing
  scheduler.py -- continuous-batching scheduler: FCFS admission by free-block
                  budget with prefix matching, chunked prefill windows,
                  preemption-by-eviction, bucketed step composition
  engine.py    -- the step loop: add_request() / step() / stream outputs,
                  cached jitted (windowed) prefill+decode, per-request LAMP
                  and prefix-cache telemetry
  sampling.py  -- shared Gumbel-max sampling primitives (per-request keyed
                  streams, top-k filtering) used by the engine, the
                  static-batch loop, and the speculative accept rule
  speculative.py -- LAMP self-draft speculative decoding: low-precision
                  drafter (rule "none") + selective-recompute verifier over
                  the paged pool, standard accept/residual-resample rule
  policy.py    -- adaptive LAMP policy controller: per-layer threshold
                  actuation (traced operands, zero recompiles) driven by
                  recompute-rate telemetry, with load-aware graceful
                  degradation of draft length and rule tier
  faults.py    -- deterministic fault injection: seeded, hash-sampled fault
                  sites (NaN poisoning, allocation failure, draft
                  corruption, fused-step anomaly, stall) replayable
                  bit-for-bit; drives the engine's health guard, recovery
                  ladder, and watchdog under test

Observability lives in `repro.obs` (metrics registry, step-phase tracer,
compile-event log); every engine carries an `Observability` bundle at
`engine.obs`, configured by `EngineConfig.obs` (an `repro.obs.ObsConfig`).
"""

from repro.obs.audit import AuditConfig

from .engine import EngineConfig, LampEngine, QueueFullError, RequestOutput
from .faults import (FAULT_SITES, ArenaAllocFault, FaultConfig, FaultError,
                     FaultInjector, StepLaunchFault, fault_hash)
from .kv_pool import PagedKVPool
from .policy import (MODE_NAMES, MODE_NORMAL, MODE_RELAXED, MODE_SHED,
                     PolicyActions, PolicyConfig, PolicyController,
                     PolicySignals)
from .request import SamplingParams, Sequence, SequenceStatus
from .scheduler import Scheduler, StepPlan
from .speculative import SpecConfig

__all__ = [
    "EngineConfig", "LampEngine", "RequestOutput", "PagedKVPool",
    "SamplingParams", "Sequence", "SequenceStatus", "Scheduler", "StepPlan",
    "SpecConfig", "PolicyConfig", "PolicyController", "PolicySignals",
    "PolicyActions", "MODE_NAMES", "MODE_NORMAL", "MODE_RELAXED",
    "MODE_SHED", "AuditConfig", "QueueFullError", "FAULT_SITES",
    "FaultConfig", "FaultInjector", "FaultError", "ArenaAllocFault",
    "StepLaunchFault", "fault_hash",
]
