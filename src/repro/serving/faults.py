"""Deterministic fault injection for the serving engine (chaos testing).

A production engine's recovery paths are exactly the code that never runs
in a clean test suite. This module makes them run, *reproducibly*: every
fault decision is a pure function of (step, site, salt) through the same
splitmix64 mixing the shadow audit samples with (obs/audit.py), so a chaos
stream replays bit-for-bit -- the same steps fault, the same rows are
poisoned, the same allocations fail -- across processes and platforms.

Fault sites (each with its own rate knob):

  nan    -- poison one live row's step output: its returned health value
            goes non-finite and the KV positions the row wrote this step
            are overwritten with NaN in the arena, simulating a kernel
            that produced garbage for that row. The engine's health guard
            quarantines the row and the recovery ladder re-runs its
            window (which rewrites exactly the poisoned span).
  alloc  -- arm the KV pool to fail its next block allocation with
            `ArenaAllocFault` (raised before any pool state mutates). The
            scheduler degrades: the affected admission/window/decode
            grant is deferred or retried, never crashed.
  draft  -- corrupt one row's speculative draft proposals after the draft
            scan returns. No dedicated recovery exists because the verify
            pass *is* the recovery: corrupt proposals are rejected by the
            accept rule and the verifier's own token is emitted (greedy
            streams stay token-identical by construction).
  step   -- fail the fused mixed launch before it runs (`StepLaunchFault`);
            the engine degrades that step to the split-execution twin
            (`mixed_exec="split"`) and recovers.
  stall  -- wedge the engine for `stall_steps` consecutive steps (each
            reported as a `stall_s` latency spike): step() schedules
            nothing and makes no progress, exercising the
            run_to_completion stall watchdog, which clears the wedge and
            evicts the stalled rows instead of raising.

At most one fault fires per (site, step): sites are independent, replays
are stable under engine-internal refactors (the hash keys on the engine
step counter, not wall time), and `max_faults` bounds the total chaos a
long stream absorbs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.obs.audit import audit_hash

__all__ = ["FaultConfig", "FaultInjector", "FaultError", "ArenaAllocFault",
           "StepLaunchFault", "fault_hash", "FAULT_SITES"]

FAULT_SITES = ("nan", "alloc", "draft", "step", "stall")

# stable per-site key offsets for the hash (never reordered: replays of
# recorded chaos streams depend on them)
_SITE_IDS = {site: 0x5EED + 131 * i for i, site in enumerate(FAULT_SITES)}


class FaultError(RuntimeError):
    """Base class for injected faults (never raised by real failures)."""


class ArenaAllocFault(FaultError):
    """Simulated KV-pool block-allocation failure (raised by
    `PagedKVPool.alloc` when armed, before any pool state mutates)."""


class StepLaunchFault(FaultError):
    """Simulated fused-step launch failure (raised before the jitted call,
    so no device or bookkeeping state has changed)."""


def fault_hash(step: int, site: str, salt: int = 0) -> float:
    """Deterministic (step, site, salt) -> [0, 1): the audit sampler's
    splitmix64 mixing with the site's stable id in the request slot."""
    return audit_hash(step, _SITE_IDS[site], salt)


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Fault-injection knobs (hashable: lives inside frozen EngineConfig).

    All rates are per-engine-step firing probabilities in [0, 1]; 0
    disables that site. `enabled=False` (the default) constructs no
    injector at all -- zero hot-path cost. `salt` is the replay key:
    the same stream with the same salt injects the same faults."""
    enabled: bool = False
    salt: int = 0
    nan_rate: float = 0.0       # poison one row's step output / written KV
    alloc_rate: float = 0.0     # fail the pool's next block allocation
    draft_rate: float = 0.0     # corrupt one row's draft proposals
    step_rate: float = 0.0      # fail the fused launch (-> split twin)
    stall_rate: float = 0.0     # wedge the engine for stall_steps steps
    stall_steps: int = 4        # consecutive wedged steps per stall event
    stall_s: float = 0.25       # simulated wall-clock cost per wedged step
    max_faults: int = 0         # total injection budget (0 = unbounded)

    def __post_init__(self):
        for f in ("nan_rate", "alloc_rate", "draft_rate", "step_rate",
                  "stall_rate"):
            r = getattr(self, f)
            if not 0.0 <= r <= 1.0:
                raise ValueError(f"fault {f} must be in [0, 1], got {r}")
        if self.stall_steps < 1:
            raise ValueError(
                f"fault stall_steps must be >= 1, got {self.stall_steps}")
        if self.stall_s < 0:
            raise ValueError(f"fault stall_s must be >= 0, got {self.stall_s}")
        if self.max_faults < 0:
            raise ValueError(
                f"fault max_faults must be >= 0, got {self.max_faults}")

    @property
    def any_rate(self) -> bool:
        return any(getattr(self, f"{s}_rate") > 0 for s in FAULT_SITES)


class FaultInjector:
    """Replayable fault scheduler + accounting.

    The engine asks `fires(step, site)` at each site's hook point; the
    decision is the pure hash above gated by the site's rate, the global
    `max_faults` budget, and a one-per-(site, step) latch (so the split
    twin re-executing a plan's sub-steps cannot double-inject). Injections
    the engine actually applied are recorded through `record`, which
    feeds `engine_faults_injected_total{site}` and a trace instant."""

    def __init__(self, config: FaultConfig, obs=None) -> None:
        self.config = config
        self._obs = obs
        self.injected = 0
        self.by_site: Dict[str, int] = {s: 0 for s in FAULT_SITES}
        self._fired_at: Dict[str, int] = {}
        self._stall_left = 0
        self._c_site = None
        if obs is not None:
            fam = obs.registry.counter(
                "engine_faults_injected_total",
                help="deterministic fault injections by site",
                labels=("site",))
            self._c_site = {s: fam.labels(s) for s in FAULT_SITES}

    # -- decisions ----------------------------------------------------------

    def fires(self, step: int, site: str) -> bool:
        rate = getattr(self.config, f"{site}_rate")
        if rate <= 0.0:
            return False
        if self.config.max_faults and self.injected >= self.config.max_faults:
            return False
        if self._fired_at.get(site) == step:
            return False
        return rate >= 1.0 or fault_hash(step, site, self.config.salt) < rate

    def pick_row(self, step: int, site: str,
                 req_ids: Sequence[int]) -> Optional[int]:
        """Deterministic victim row: the live request whose (step, request,
        site-salted) hash ranks first -- stable under batch composition of
        the *other* rows. None when the batch is empty."""
        if not req_ids:
            return None
        salt = self.config.salt ^ _SITE_IDS[site]
        return min(range(len(req_ids)),
                   key=lambda i: (audit_hash(step, int(req_ids[i]) + 1,
                                             salt), i))

    def record(self, step: int, site: str, **detail) -> None:
        """Mark one applied injection (latches the (site, step) pair)."""
        self.injected += 1
        self.by_site[site] += 1
        self._fired_at[site] = step
        if self._c_site is not None:
            self._c_site[site].inc()
        if self._obs is not None and self._obs.tracer.enabled:
            self._obs.tracer.instant(f"fault:{site}", cat="fault",
                                     step=step, **detail)

    # -- stall state --------------------------------------------------------

    def maybe_stall(self, step: int) -> bool:
        """True while the engine is wedged. A fresh stall event arms
        `stall_steps` wedged steps; each call consumes one."""
        if self._stall_left <= 0 and self.fires(step, "stall"):
            self._stall_left = self.config.stall_steps
            self.record(step, "stall", steps=self.config.stall_steps)
        if self._stall_left > 0:
            self._stall_left -= 1
            return True
        return False

    @property
    def stalled(self) -> bool:
        return self._stall_left > 0

    def clear_stall(self) -> None:
        """Watchdog recovery hook: end the current stall event early."""
        self._stall_left = 0

    # -- reporting ----------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        return {"enabled": True, "injected": self.injected,
                "by_site": dict(self.by_site)}
