"""One bounded cache for every jitted serving step function.

The serving stack used to hold independent module-level dicts of compiled
step callables -- `engine._JIT_CACHE` for the (prefill, decode) pairs,
`speculative._SPEC_JIT_CACHE` for the (draft, verify) pairs -- and the
fused mixed step would have added a third. Each grew one entry per
(model config, lamp flag, kernel, top-k variant, ...) forever: a process
cycling through many configurations (test suites, multi-model benchmarks,
policy rule-tier swaps) leaked compiled-function handles without bound.

`FnCache` dedupes them into one keyed LRU store with an eviction bound.
Callers namespace their keys with a leading tag ("step", "spec", "mixed")
so one config's variants never collide across call sites. Eviction drops
our handle on the callable (and its compiled-signature bookkeeping); the
underlying XLA executables are owned by JAX's own caches, which
`jax.clear_caches()` manages separately -- see `engine.reset_step_caches`
for the cold-start helper benchmarks and compile-count tests use.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Hashable


class FnCache:
    """Keyed LRU cache: `get_or_build(key, build)` returns the cached value
    or builds, stores, and (beyond `maxsize` entries) evicts the least
    recently used. Not thread-safe, like the dicts it replaces."""

    def __init__(self, maxsize: int = 64):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self.evictions = 0
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()

    def get_or_build(self, key: Hashable, build: Callable[[], Any]) -> Any:
        try:
            fn = self._entries[key]
        except KeyError:
            fn = build()
            self._entries[key] = fn
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
                self.evictions += 1
        else:
            self._entries.move_to_end(key)
        return fn

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def keys(self):
        return list(self._entries)

    def clear(self) -> None:
        self._entries.clear()


# the process-wide store every step-function builder routes through
STEP_FNS = FnCache()
