"""LAMP self-draft speculative decoding over the paged KV pool.

LAMP's split -- run everything in low precision, selectively recompute only
the components the look-ahead error analysis flags -- maps one-to-one onto
speculative decoding, with *one* set of weights playing both roles:

  draft   = the pure low-precision forward pass (LAMP rule "none": PS(mu)
            KQ products, nothing recomputed). Runs `draft_len` plain paged
            decode steps per sequence per round, writing draft KV into the
            sequence's own blocks.
  verify  = the LAMP selective-recompute pass (the engine's configured
            rule). Scores all draft_len + 1 positions in ONE batched
            multi-token paged forward (`transformer.paged_verify_window`,
            the chunked-prefill window machinery pointed at the decode
            tail), which also overwrites the drafted positions' KV with
            verify-quality values -- so the cache ends up exactly as if the
            tokens had been decoded non-speculatively.

Acceptance is the standard speculative rule (Leviathan et al. '23), so
outputs are provably distributed as non-speculative decoding from the
verify model:

  * greedy (temp <= 0): accept draft j+1 while it equals the verifier's
    argmax at position j; the first disagreement (or the bonus position)
    emits the verifier's argmax. Token streams are bit-identical to the
    non-speculative engine.
  * sampling: accept draft token d ~ q with probability min(1, p(d)/q(d));
    on rejection, resample from the residual distribution
    norm(max(p - q, 0)). Draws use the engine's keyed streams
    (request seed, position, salt), so they are independent of the draft
    proposals and of the plain sampler. `top_k` filtering is applied to
    BOTH p and q before the ratio, matching what each sampler would
    actually have sampled from.

Every round emits between 1 (first draft rejected -> the verifier's own
token, i.e. a plain decode step's worth of progress) and draft_len + 1
(all accepted + bonus) tokens. Rejected drafts' KV is rolled back by the
engine via `PagedKVPool.rollback`.

Shapes are fixed per (config, draft_len): the draft loop is a
`lax.scan` of `draft_len` decode steps inside one jitted call, and the
verify window is bucketed to the next power of two >= draft_len + 1.
Sequences whose per-round draft budget `kd` is smaller (token limit nearly
reached: kd = 0 degrades to a verify-only round == one plain decode step)
freeze their draft cursor early -- frozen steps rewrite the same tail
position with the same token, and the verifier masks everything past
kd + 1, so no extra shapes are compiled and no garbage KV survives.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer

from . import sampling
from .fn_cache import STEP_FNS

_DRAFT_RULES = ("none", "strict", "relaxed", "relaxed_ln")


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs.

    draft_len  -- tokens drafted per sequence per round (k). The verify
                  window scores k + 1 positions (k drafts + bonus).
    draft_rule -- LAMP rule for the drafter. "none" (default) is the
                  paper-motivated self-draft: the pure low-precision
                  forward with zero recompute. The verify rule always
                  comes from the engine's model config.
    """
    draft_len: int = 4
    draft_rule: str = "none"

    def __post_init__(self):
        if self.draft_len < 1:
            raise ValueError(f"draft_len must be >= 1, got {self.draft_len}")
        if self.draft_rule not in _DRAFT_RULES:
            raise ValueError(f"draft_rule must be one of {_DRAFT_RULES}, "
                             f"got {self.draft_rule!r}")

    @property
    def verify_width(self) -> int:
        """Verify-window bucket: next power of two >= draft_len + 1."""
        w = 1
        while w < self.draft_len + 1:
            w *= 2
        return w


def draft_model_config(cfg, spec: SpecConfig):
    """The drafter's model config: same weights, same mu, the draft rule at
    the KQ site (rule "none" = pure low-precision logits, no recompute)."""
    pol = cfg.lamp
    if not pol.kq.enabled or pol.kq.rule == spec.draft_rule:
        return cfg
    return cfg.replace(lamp=pol.replace(kq=pol.kq.replace(rule=spec.draft_rule)))


def speculative_accept(verify_logits, draft_tokens, draft_logits, kd,
                       seeds, counts, temps, top_k
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Vectorized accept/reject + correction sampling.

    verify_logits (R, >=k+1, V): target logits; position j scores the token
        following draft prefix d_1..d_j.
    draft_tokens  (R, k): proposals d_1..d_k (garbage past kd, ignored).
    draft_logits  (R, k, V): the (unfiltered) logits each proposal was
        sampled from.
    kd (R,): per-row draft budget this round; acceptance never exceeds it.
    seeds/counts/temps (R,): the engine's per-request sampling state at
        round start. top_k (R,) filters both p and q before the ratio;
        None skips the filter (no request in the batch uses one).

    Returns (emit (R, k+1) int32, n_accepted (R,) int32): row r's tokens
    for this round are emit[r, :n_accepted[r] + 1] -- the accepted drafts
    followed by one token from the verifier (the residual resample at the
    first rejection, or the bonus sample when everything was accepted).
    """
    R, k = draft_tokens.shape
    V = verify_logits.shape[-1]
    if top_k is not None:    # None: skip the per-row vocab sort entirely
        p_f = sampling.apply_top_k_rows(verify_logits[:, :k + 1], top_k)
        q_f = sampling.apply_top_k_rows(draft_logits, top_k)
    else:
        p_f, q_f = verify_logits[:, :k + 1], draft_logits
    greedy = temps <= 0.0
    tsafe = jnp.maximum(temps, 1e-6)[:, None, None]
    p_prob = jax.nn.softmax(p_f / tsafe, axis=-1)        # (R, k+1, V)
    q_prob = jax.nn.softmax(q_f / tsafe, axis=-1)        # (R, k,   V)
    d = draft_tokens
    p_d = jnp.take_along_axis(p_prob[:, :k], d[..., None], -1)[..., 0]
    q_d = jnp.take_along_axis(q_prob, d[..., None], -1)[..., 0]
    # acceptance coins: u_j < p_j(d)/q_j(d), keyed on (seed, position, salt)
    u = sampling.row_uniforms(
        seeds, counts[:, None] + jnp.arange(k)[None, :],
        sampling.SALT_ACCEPT)
    acc_sample = u * q_d <= p_d
    p_arg = jnp.argmax(p_f, axis=-1)                     # (R, k+1)
    acc_greedy = p_arg[:, :k] == d
    j = jnp.arange(k)[None, :]
    acc = jnp.where(greedy[:, None], acc_greedy, acc_sample) \
        & (j < kd[:, None])
    # accepted prefix length: stop at the first rejection
    cum = jnp.cumprod(acc.astype(jnp.int32), axis=1)
    n_acc = jnp.sum(cum, axis=1)                         # (R,) in [0, kd]
    ridx = jnp.arange(R)
    p_a = p_prob[ridx, n_acc]                            # (R, V)
    q_a = q_prob[ridx, jnp.minimum(n_acc, k - 1)]
    # rejected at n_acc < kd: residual max(p - q, 0); all accepted: bonus
    # position sampled straight from p (no draft to correct against)
    resid = jnp.clip(p_a - q_a, 0.0, None)
    dist = jnp.where((n_acc < kd)[:, None], resid, p_a)
    # degenerate residual (p <= q everywhere up to roundoff, yet the coin
    # rejected): fall back to the target distribution
    dist = jnp.where(jnp.sum(dist, -1, keepdims=True) > 0, dist, p_a)
    g = sampling.row_gumbel(seeds, counts + n_acc, sampling.SALT_RESIDUAL,
                            (V,))
    samp = jnp.argmax(jnp.where(dist > 0, jnp.log(dist), -jnp.inf) + g, -1)
    corr = jnp.where(greedy, p_arg[ridx, n_acc], samp).astype(jnp.int32)
    emit = jnp.where(j < n_acc[:, None], d, 0).astype(jnp.int32)
    emit = jnp.concatenate([emit, jnp.zeros((R, 1), jnp.int32)], axis=1)
    emit = emit.at[ridx, n_acc].set(corr)
    return emit, n_acc


# jitted (draft, verify) pairs keyed on (cfg, use_lamp, kernel, spec) in
# the shared bounded fn_cache.STEP_FNS store (same LRU as the engine's
# prefill/decode and mixed builders), shared across engine instances. KV
# arenas are donated so per-round updates alias the pool buffers in place.


def spec_step_fns(cfg, use_lamp: bool, kernel: str, spec: SpecConfig,
                  use_topk: bool = True):
    """Build (draft_fn, verify_fn) for one engine configuration.

    draft_fn(params, k, v, bt, lengths, tok0, kd, taus, seeds, counts,
             temps, topks) -> (draft_tokens (R, k), draft_logits (R, k, V),
                        arena_k, arena_v)
        runs `draft_len` low-precision paged decode steps (a lax.scan, one
        jitted call), sampling each proposal from the draft distribution
        with the SALT_DRAFT key stream. Rows freeze at their budget kd.

    verify_fn(params, k, v, tok0, draft_tokens, draft_logits, bt, lengths,
              kd, taus, seeds, counts, temps, topks)
        -> (emit (R, k+1), n_accepted (R,), health (R,), arena_k, arena_v,
            n_selected (L, R), n_valid (L, R))
        one multi-token paged forward over [last_token, d_1..d_k] at
        absolute positions lengths..lengths+k with the engine's LAMP verify
        rule (rewriting those positions' KV), then `speculative_accept`.
        `health` is max |logit| over each row's live verify positions
        (non-finite iff the row produced a non-finite logit there; padding
        positions past kd + 1 hold kernel garbage and are masked out) --
        the engine's numerical health guard quarantines rows on it.
        n_selected/n_valid are the verify pass's per-layer per-row LAMP
        counts (the engine reduces them).

    `taus` ((L,) float32) carries the policy controller's live per-layer
    LAMP thresholds into the *verify* pass (the draft runs the fixed draft
    rule, typically "none", so thresholds are irrelevant there); it is a
    traced operand, so actuation never recompiles.

    `use_topk` is a static trace-time switch (as in engine._jitted_steps):
    False skips the per-row top-k vocab sorts for batches where no request
    filters, which is the common case.
    """
    key = ("spec", cfg, use_lamp, kernel, spec, use_topk)
    k = spec.draft_len
    dcfg = draft_model_config(cfg, spec) if use_lamp else cfg

    def _draft(params, ak, av, bt, lengths, tok0, kd, taus, seeds, counts,
               temps, topks):
        del taus  # the draft rule is fixed (typically "none": no selection)
        def body(carry, j):
            tok, ak, av = carry
            # frozen rows (j >= kd) rewrite the same tail position with the
            # same token: no new shape, and the verifier overwrites it
            len_j = lengths + jnp.minimum(j, kd)
            logits, arena, _ = transformer.paged_decode_step(
                dcfg, params, {"k": ak, "v": av}, bt, len_j, tok[:, None],
                use_lamp=use_lamp, kernel=kernel)
            lg = logits[:, -1]
            nxt = sampling.sample_rows(lg, seeds, counts + j, temps,
                                       top_k=topks if use_topk else None,
                                       salt=sampling.SALT_DRAFT)
            nxt = jnp.where(j < kd, nxt.astype(jnp.int32), tok)
            return (nxt, arena["k"], arena["v"]), (nxt, lg)

        (_, ak, av), (toks, qlog) = jax.lax.scan(
            body, (tok0, ak, av), jnp.arange(k))
        return (jnp.moveaxis(toks, 0, 1), jnp.moveaxis(qlog, 0, 1), ak, av)

    def _verify(params, ak, av, tok0, d_toks, d_logits, bt, lengths, kd,
                taus, seeds, counts, temps, topks):
        win = jnp.concatenate([tok0[:, None], d_toks], axis=1)   # (R, k+1)
        Wv = spec.verify_width
        if Wv > k + 1:
            win = jnp.pad(win, ((0, 0), (0, Wv - (k + 1))))
        logits, arena, (nsel, nval) = transformer.paged_verify_window(
            cfg, params, win, {"k": ak, "v": av}, bt, lengths, kd + 1,
            use_lamp=use_lamp, kernel=kernel, per_layer=True, taus=taus)
        emit, n_acc = speculative_accept(
            logits, d_toks, d_logits, kd, seeds, counts, temps,
            topks if use_topk else None)
        # per-row numerical health: max |logit| over the live verify span
        # (positions past kd + 1 are kernel garbage on padded buckets and
        # must not poison the check). NaN/Inf propagate through the max.
        live = jnp.arange(logits.shape[1])[None, :] < (kd + 1)[:, None]
        health = jnp.max(jnp.where(live[..., None], jnp.abs(logits), 0.0),
                         axis=(1, 2))
        return emit, n_acc, health, arena["k"], arena["v"], nsel, nval

    return STEP_FNS.get_or_build(
        key, lambda: (jax.jit(_draft, donate_argnums=(1, 2)),
                      jax.jit(_verify, donate_argnums=(1, 2))))
