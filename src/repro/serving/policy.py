"""Adaptive LAMP policy controller: per-layer threshold actuation with
load-aware graceful degradation.

The static LAMP site config fixes one tau for every layer for the lifetime
of the process. This module closes the loop instead: each engine step the
controller reads the serving telemetry the engine already produces --
per-layer recompute rates, KV-pool utilization, preemption pressure, step
wall time, speculative acceptance -- and actuates three knobs:

  tau (per layer)   -- the LAMP selection threshold, threaded through the
                       jitted steps as a *traced (L,) operand* (scalar
                       prefetch in the pallas kernels), so moving it never
                       recompiles. Driven toward per-layer target recompute
                       rates by a multiplicative log-space law with a
                       deadband (hysteresis) and a clamped slew rate.
  draft_len         -- the speculative lookahead, a host integer the
                       scheduler reads per round (shortening it is
                       recompile-free).
  rule              -- the LAMP rule tier; under sustained pressure the
                       controller drops one tier (strict -> relaxed ->
                       none). Changing the rule is a static config change
                       (one recompile per tier per bucket), so it is the
                       *last* resort of the degradation ladder.

Degradation ladder (mode):

  NORMAL   -- track target recompute rates.
  RELAXED  -- pool utilization crossed util_high (or the step-latency SLO
              is missed): targets are scaled down by relaxed_target_scale
              (recompute less, run cheaper) and the draft length is
              halved. Exits back to NORMAL only below util_low -- the
              enter/exit gap is the mode hysteresis.
  SHED     -- utilization crossed shed_util or the pool started preempting:
              tau slews up at the full rate toward tau_max, speculation is
              shed when its acceptance rate is below shed_accept (accepted
              lookahead finishes sequences in fewer rounds and frees their
              blocks sooner, so high-value speculation is kept even under
              pressure), and (with degrade_rule) the rule drops one tier.
              Exits to RELAXED (never straight to NORMAL) once utilization
              is back under util_high and preemptions stop.

Every actuation is observable: `lamp_tau{layer}` gauges, a `policy_mode`
gauge, a `policy_actuations_total` counter, and (with tracing on) instant
events on the Chrome-trace timeline. `frozen=True` runs the whole loop --
signals, mode tracking, gauges -- but never actuates, which is the
token-identity control arm the differential tests pin.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

MODE_NORMAL = 0
MODE_RELAXED = 1
MODE_SHED = 2
MODE_NAMES = ("normal", "relaxed", "shed")

# one-tier graceful degradation of the LAMP rule under SHED: the relaxed
# rule (9) is FlashAttention-safe and cheaper than strict's full softmax;
# "none" is the pure low-precision forward (zero recompute)
_RULE_LADDER = {"strict": "relaxed", "relaxed_ln": "relaxed",
                "relaxed": "none", "none": "none"}


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    """Knobs of the adaptive LAMP policy loop (all host-side)."""
    enabled: bool = False
    # per-layer recompute-rate target; target_rates (len n_layers)
    # overrides the scalar for heterogeneous layer sensitivity
    target_rate: float = 0.05
    target_rates: Optional[Sequence[float]] = None
    # actuation clamps: tau stays in [tau_min, tau_max] and moves at most
    # max_step in log space per actuation (slew limit)
    tau_min: float = 1e-4
    tau_max: float = 0.9
    gain: float = 0.5
    max_step: float = 0.25
    # deadband hysteresis: no actuation while |rate - target| is within
    # deadband * target (prevents oscillation around the setpoint)
    deadband: float = 0.1
    # actuate every `interval` engine steps; rate EMA smoothing weight of
    # the newest sample
    interval: int = 1
    ema: float = 0.5
    # mode ladder thresholds (pool utilization in [0, 1]); util_high enters
    # RELAXED, util_low exits it, shed_util (or any preemption) enters SHED
    util_high: float = 0.92
    util_low: float = 0.75
    shed_util: float = 0.98
    # step-latency SLO (seconds); 0 disables the latency pressure signal
    latency_slo_s: float = 0.0
    # RELAXED scales the rate targets down by this factor
    relaxed_target_scale: float = 0.5
    # SHED knobs: drop speculation / drop the rule one ladder tier.
    # Speculation is only shed while the cumulative acceptance rate is
    # below shed_accept: low-value lookahead wastes pool blocks it holds,
    # but high-value lookahead finishes sequences in fewer rounds and
    # frees their blocks sooner than plain decode would -- shedding it
    # under memory pressure is counterproductive.
    shed_draft: bool = True
    shed_accept: float = 0.5
    degrade_rule: bool = True
    # observe-only: run signals, mode tracking, and gauges, actuate nothing
    frozen: bool = False

    def __post_init__(self):
        if not (0.0 < self.tau_min <= self.tau_max < 1.0):
            raise ValueError(
                f"need 0 < tau_min <= tau_max < 1, got "
                f"[{self.tau_min}, {self.tau_max}]")
        if self.max_step <= 0 or self.gain < 0:
            raise ValueError("max_step must be > 0 and gain >= 0")
        if not (0.0 < self.ema <= 1.0):
            raise ValueError(f"ema weight must be in (0, 1], got {self.ema}")
        if self.interval < 1:
            raise ValueError(f"interval must be >= 1, got {self.interval}")
        if not (0.0 <= self.util_low <= self.util_high <= self.shed_util):
            raise ValueError(
                "need util_low <= util_high <= shed_util, got "
                f"{self.util_low}/{self.util_high}/{self.shed_util}")


@dataclasses.dataclass
class PolicySignals:
    """One step's telemetry, as read from the engine."""
    layer_rates: Optional[np.ndarray]   # (L,) recompute rates, None if the
                                        # step produced no LAMP counts
    utilization: float                  # pool blocks-in-use fraction
    preemptions: int                    # cumulative scheduler preemptions
    step_latency_s: float               # wall time of the step
    spec_acceptance: float = 0.0        # cumulative draft acceptance rate
    recoveries: int = 0                 # cumulative engine recovery actions
                                        # (health-guard retries, alloc
                                        # deferrals, split fallbacks) -- a
                                        # recovering engine is a stressed
                                        # engine, so deltas act as pressure


@dataclasses.dataclass
class PolicyActions:
    """What the controller wants the engine to apply for the next step."""
    taus: np.ndarray                    # (L,) float32 thresholds
    mode: int
    rule: Optional[str]                 # None = the engine's base rule
    draft_len: int
    changed: bool                       # did anything actuate this update?


class PolicyController:
    """The feedback loop. Owns tau state in log space; `update()` ingests
    one `PolicySignals` and returns the `PolicyActions` to apply."""

    def __init__(self, config: PolicyConfig, n_layers: int, tau0,
                 *, base_rule: str = "relaxed", base_draft_len: int = 0,
                 obs=None):
        self.config = config
        self.n_layers = n_layers
        t0 = np.broadcast_to(np.asarray(tau0, np.float64),
                             (n_layers,)).copy()
        # base thresholds, returned verbatim while frozen (token identity);
        # the live log-tau state starts from the clamped version
        self._tau_base = t0.astype(np.float32)
        self._log_tau = np.log(np.clip(t0, config.tau_min, config.tau_max))
        if config.target_rates is not None:
            tr = np.asarray(list(config.target_rates), np.float64)
            if tr.shape != (n_layers,):
                raise ValueError(
                    f"target_rates must have length {n_layers}, "
                    f"got {tr.shape}")
            self._targets = tr
        else:
            self._targets = np.full((n_layers,), config.target_rate,
                                    np.float64)
        # audit-calibrated state (set_error_targets): replaces the
        # configured targets with error-model-derived ones, and optionally
        # carries the RELAXED guardrail mask (False = this layer's audited
        # flip rate exceeds its error budget; never relax it)
        self._relax_ok: Optional[np.ndarray] = None
        self.target_updates = 0
        self.base_rule = base_rule
        self.base_draft_len = base_draft_len
        self.mode = MODE_NORMAL
        self.mode_transitions = 0
        self.actuations = 0
        self._ema: Optional[np.ndarray] = None
        self._last_preemptions = 0
        self._last_recoveries = 0
        self._accept = 0.0
        self._updates = 0
        self._obs = obs
        if obs is not None:
            reg = obs.registry
            fam = reg.gauge("lamp_tau", help="live LAMP threshold by layer",
                            labels=("layer",))
            self._g_tau = [fam.labels(str(l)) for l in range(n_layers)]
            self._g_mode = reg.gauge(
                "policy_mode", help="0=normal 1=relaxed 2=shed")
            self._g_pressure = reg.gauge(
                "policy_pressure", help="pool utilization the policy saw")
            self._c_actuations = reg.counter(
                "policy_actuations_total",
                help="updates that moved tau, the rule, or the draft length")
            self._c_transitions = reg.counter(
                "policy_mode_transitions_total",
                help="degradation-ladder mode changes", labels=("to",))
            self._c_target_updates = reg.counter(
                "policy_target_updates_total",
                help="error-model calibrations applied to the rate targets")
            self._g_target = reg.gauge(
                "lamp_target_rate", help="live recompute-rate target by "
                "layer (audit-calibrated when the shadow audit is on)",
                labels=("layer",))
            for l in range(n_layers):
                self._g_target.labels(str(l)).set(float(self._targets[l]))
            for g, t in zip(self._g_tau, self._tau_base):
                g.set(float(t))
            self._g_mode.set(MODE_NORMAL)
        else:
            self._g_tau = None

    # -- the loop ------------------------------------------------------------

    @property
    def taus(self) -> np.ndarray:
        """Current thresholds (the base ones while frozen)."""
        if self.config.frozen:
            return self._tau_base
        return np.exp(self._log_tau).astype(np.float32)

    def set_error_targets(self, targets, relax_ok=None) -> None:
        """Install audit-calibrated per-layer recompute-rate targets.

        `targets` ((L,) float) comes from obs/error_model.py: the scalar
        default redistributed in proportion to each layer's amplified
        audited error. `relax_ok` ((L,) bool, optional) is the degradation
        guardrail: layers marked False have audited argmax flip rates over
        their error budget, so RELAXED keeps their *full* target (no
        relaxed_target_scale) and SHED holds their tau instead of slewing
        it toward tau_max -- load never buys throughput with tokens those
        layers are already visibly flipping. Overrides config.target_rate /
        config.target_rates until the next call."""
        t = np.asarray(targets, np.float64)
        if t.shape != (self.n_layers,):
            raise ValueError(
                f"targets must have shape ({self.n_layers},), got {t.shape}")
        if np.any(t <= 0.0) or np.any(t > 1.0):
            raise ValueError("targets must be in (0, 1]")
        self._targets = t
        if relax_ok is not None:
            ok = np.asarray(relax_ok, bool)
            if ok.shape != (self.n_layers,):
                raise ValueError(
                    f"relax_ok must have shape ({self.n_layers},), "
                    f"got {ok.shape}")
            self._relax_ok = ok
        else:
            self._relax_ok = None
        self.target_updates += 1
        if self._obs is not None:
            self._c_target_updates.inc()
            for l in range(self.n_layers):
                self._g_target.labels(str(l)).set(float(t[l]))
            if self._obs.tracer.enabled:
                self._obs.tracer.instant(
                    "policy_targets", cat="policy",
                    target_mean=round(float(t.mean()), 6),
                    target_max=round(float(t.max()), 6),
                    guarded=int(0 if self._relax_ok is None
                                else (~self._relax_ok).sum()))

    def _next_mode(self, sig: PolicySignals, d_preempt: int,
                   slo_miss: bool) -> int:
        c = self.config
        if self.mode == MODE_NORMAL:
            if sig.utilization >= c.shed_util or d_preempt > 0:
                return MODE_SHED
            if sig.utilization >= c.util_high or slo_miss:
                return MODE_RELAXED
        elif self.mode == MODE_RELAXED:
            if sig.utilization >= c.shed_util or d_preempt > 0:
                return MODE_SHED
            if sig.utilization <= c.util_low and not slo_miss:
                return MODE_NORMAL
        else:  # SHED exits one rung at a time (never straight to NORMAL)
            if sig.utilization < c.util_high and d_preempt == 0:
                return MODE_RELAXED
        return self.mode

    def update(self, sig: PolicySignals) -> PolicyActions:
        c = self.config
        self._updates += 1
        if sig.layer_rates is not None:
            r = np.asarray(sig.layer_rates, np.float64)
            self._ema = (r if self._ema is None
                         else c.ema * r + (1.0 - c.ema) * self._ema)
        d_preempt = max(0, sig.preemptions - self._last_preemptions)
        self._last_preemptions = sig.preemptions
        d_recover = max(0, sig.recoveries - self._last_recoveries)
        self._last_recoveries = sig.recoveries
        self._accept = sig.spec_acceptance
        # recovery pressure rides the slo_miss rail: a step that needed a
        # health-guard retry / alloc deferral / split fallback pushes the
        # ladder toward RELAXED and blocks the exit to NORMAL, exactly like
        # a latency-SLO miss
        slo_miss = (c.latency_slo_s > 0
                    and sig.step_latency_s > c.latency_slo_s) \
            or d_recover > 0

        new_mode = self._next_mode(sig, d_preempt, slo_miss)
        mode_changed = new_mode != self.mode
        if mode_changed:
            self.mode = new_mode
            self.mode_transitions += 1
            if self._obs is not None:
                self._c_transitions.labels(MODE_NAMES[new_mode]).inc()
                if self._obs.tracer.enabled:
                    self._obs.tracer.instant(
                        "policy_mode", cat="policy",
                        mode=MODE_NAMES[new_mode],
                        util=round(sig.utilization, 4),
                        preempt_delta=d_preempt)

        moved = False
        if not c.frozen and self._updates % c.interval == 0:
            moved = self._actuate_tau()
        # an "actuation" is an update that applies something to the engine;
        # frozen tracks modes for observability but never applies, so its
        # mode changes are not actuations
        changed = moved or (mode_changed and not c.frozen)

        rule = None
        draft = self._draft_for_mode()
        if not c.frozen and self.mode == MODE_SHED and c.degrade_rule:
            rule = _RULE_LADDER[self.base_rule]

        if self._obs is not None:
            self._g_mode.set(self.mode)
            self._g_pressure.set(sig.utilization)
            if changed:
                self._c_actuations.inc()
                taus = self.taus
                for g, t in zip(self._g_tau, taus):
                    g.set(float(t))
                if self._obs.tracer.enabled:
                    self._obs.tracer.instant(
                        "policy_actuate", cat="policy",
                        mode=MODE_NAMES[self.mode],
                        tau_mean=round(float(taus.mean()), 6),
                        tau_min=round(float(taus.min()), 6),
                        tau_max=round(float(taus.max()), 6),
                        draft_len=draft)
        if changed:
            self.actuations += 1
        return PolicyActions(taus=self.taus, mode=self.mode, rule=rule,
                             draft_len=draft, changed=changed)

    def _draft_for_mode(self) -> int:
        """Speculative lookahead under the current mode: full in NORMAL,
        and -- when acceptance says the lookahead is not earning its
        blocks -- halved (min 1) in RELAXED, shed in SHED. Accepting
        lookahead drains the pool (fewer rounds per sequence), so it is
        kept while the acceptance rate clears shed_accept."""
        c = self.config
        if c.frozen or self.mode == MODE_NORMAL:
            return self.base_draft_len
        if self._accept >= c.shed_accept:
            return self.base_draft_len
        if self.mode == MODE_RELAXED:
            return min(self.base_draft_len, max(1, self.base_draft_len // 2))
        return 0 if c.shed_draft else self.base_draft_len

    def _actuate_tau(self) -> bool:
        """One slew of the log-space threshold law. Returns True if any
        layer's tau moved."""
        c = self.config
        if self.mode == MODE_SHED:
            # pressure overrides tracking: push every layer toward tau_max
            # at the full slew rate (monotone pressure response) -- except
            # layers the audit guardrail froze out of relaxation, which
            # hold where they are
            dlog = np.full((self.n_layers,), c.max_step)
            if self._relax_ok is not None:
                dlog = np.where(self._relax_ok, dlog, 0.0)
        elif self._ema is None:
            return False
        else:
            if self.mode == MODE_RELAXED:
                # guardrail: scaled-down (cheaper) targets only for layers
                # whose audited flip rate is inside budget
                scaled = self._targets * c.relaxed_target_scale
                targets = (scaled if self._relax_ok is None
                           else np.where(self._relax_ok, scaled,
                                         self._targets))
            else:
                targets = self._targets
            eps = 1e-9
            dlog = np.clip(c.gain * np.log((self._ema + eps)
                                           / (targets + eps)),
                           -c.max_step, c.max_step)
            # deadband: inside the tolerance around the setpoint, hold
            dlog[np.abs(self._ema - targets) <= c.deadband * targets] = 0.0
        new = np.clip(self._log_tau + dlog,
                      np.log(c.tau_min), np.log(c.tau_max))
        moved = bool(np.any(new != self._log_tau))
        self._log_tau = new
        return moved

    # -- reporting -----------------------------------------------------------

    def stats(self) -> dict:
        taus = self.taus
        return {
            "enabled": self.config.enabled,
            "frozen": self.config.frozen,
            "mode": MODE_NAMES[self.mode],
            "mode_transitions": self.mode_transitions,
            "actuations": self.actuations,
            "tau_mean": float(taus.mean()),
            "tau_min": float(taus.min()),
            "tau_max": float(taus.max()),
            "rate_ema": ([] if self._ema is None
                         else [float(x) for x in self._ema]),
            "draft_len": self._draft_for_mode(),
            "targets": [float(x) for x in self._targets],
            "target_updates": self.target_updates,
            "guarded_layers": (0 if self._relax_ok is None
                               else int((~self._relax_ok).sum())),
        }
