"""Paged KV-cache pool: refcounted, prefix-cached block tables over a shared
per-layer arena.

The arena is a pair of device arrays shaped (L, n_blocks, block_size, Hkv,
hd) (see `transformer.init_paged_cache`). The pool manages the *host-side*
free list and hands out ordered block lists; sequences index the arena
through (padded) block tables inside the jitted model functions.

Block 0 is reserved as the null/scratch block: block-table padding points at
it, and padded batch slots write into it. It is never allocated.

Prefix caching (vLLM-style):

  * Every *full* block of a prompt gets a chain hash -- hash of its token
    ids chained on the parent block's hash -- registered in a hash -> block
    index once its KV has actually been written.
  * A new request walks its prompt's full-block chain through the index and
    maps its block table onto the matched arena rows (`match_prefix` +
    `share`), bumping each block's refcount instead of allocating.
  * Blocks whose refcount drops to 0 but that are still registered move to
    an LRU "cached-free" list: they remain reclaimable (counted in
    `num_free`, evicted oldest-first when `alloc` runs dry) but stay
    matchable until actually evicted, so prefixes survive their donor.
  * A shared (or registered) block that a sequence needs to *write* -- the
    last partial block when a match is capped mid-block -- is copied on
    write (`copy_on_write`): fresh block, device row copy, old refcount
    dropped. Full shared blocks are never written, so COW is the only write
    path into shared state.

Double-free safety: the free and cached-free sets are explicit, so re-freeing
a specific block id (or freeing with refcount 0) raises instead of silently
corrupting the aggregate count.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict, deque
from typing import Dict, Iterable, List, Optional, Sequence as Seq

import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.serving.faults import ArenaAllocFault

NULL_BLOCK = 0


def chain_hashes(tokens: Seq, block_size: int,
                 n_tokens: Optional[int] = None) -> List[int]:
    """Chain hash per *full* block of `tokens[:n_tokens]`: block i's hash
    covers all token ids up to and including block i (via the parent link),
    so equal hashes imply equal whole prefixes, not just equal blocks.
    SHA-256-based (as vLLM hardened its prefix cache to be): deterministic
    across processes and collision-resistant even against adversarial token
    sequences, unlike Python's builtin hash()."""
    n = len(tokens) if n_tokens is None else min(n_tokens, len(tokens))
    out: List[int] = []
    parent = 0
    for i in range(n // block_size):
        chunk = np.asarray(tokens[i * block_size:(i + 1) * block_size],
                           np.int64).tobytes()
        digest = hashlib.sha256(parent.to_bytes(16, "little") + chunk)
        parent = int.from_bytes(digest.digest()[:16], "little")
        out.append(parent)
    return out


class PagedKVPool:
    def __init__(self, cfg, *, n_blocks: int, block_size: int,
                 dtype=jnp.float32, enable_prefix_cache: bool = False):
        if n_blocks < 2:
            raise ValueError("need at least one allocatable block besides "
                             "the reserved null block")
        arena = transformer.init_paged_cache(cfg, n_blocks, block_size, dtype)
        self.k = arena["k"]
        self.v = arena["v"]
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.enable_prefix_cache = enable_prefix_cache
        self._free = deque(range(1, n_blocks))          # block 0 reserved
        self._free_set = set(self._free)
        self.refcount: Dict[int, int] = {}              # block -> live owners
        # prefix index: chain hash <-> block id (1:1), plus the LRU of
        # registered blocks with no live owner (evictable, still matchable).
        # _hash_to_chunk keeps each entry's (parent hash, block token ids)
        # so a match verifies content along the whole chain, never trusting
        # the hash alone (a collision must not map onto foreign KV).
        self._hash_to_block: Dict[int, int] = {}
        self._block_to_hash: Dict[int, int] = {}
        self._hash_to_chunk: Dict[int, tuple] = {}
        self._cached_free: "OrderedDict[int, None]" = OrderedDict()
        self.peak_used = 0
        # telemetry
        self.total_allocs = 0          # fresh block allocations
        self.hit_blocks = 0            # block allocations avoided via sharing
        self.cow_copies = 0
        self.evictions = 0             # cached-free blocks reclaimed by alloc
        # fault injection: when armed, the next alloc() calls raise
        # ArenaAllocFault *before* mutating any pool state
        self._fail_next_allocs = 0

    # -- accounting ---------------------------------------------------------

    @property
    def num_total(self) -> int:
        """Allocatable blocks (excludes the null block)."""
        return self.n_blocks - 1

    @property
    def num_free(self) -> int:
        """Immediately allocatable: truly free + evictable cached blocks."""
        return len(self._free) + len(self._cached_free)

    @property
    def num_used(self) -> int:
        return self.num_total - self.num_free

    @property
    def num_cached(self) -> int:
        """Registered prefix blocks (live + cached-free)."""
        return len(self._hash_to_block)

    @property
    def utilization(self) -> float:
        return self.num_used / self.num_total

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    # -- alloc / free -------------------------------------------------------

    def can_alloc(self, n: int) -> bool:
        return n <= self.num_free

    def arm_alloc_failure(self, n: int = 1) -> None:
        """Fault injection: make the next `n` alloc() calls raise
        `ArenaAllocFault` before touching any pool state (the caller's
        degradation path sees exactly what a real exhaustion at that call
        site would, minus the exhaustion)."""
        self._fail_next_allocs = max(self._fail_next_allocs, n)

    def alloc(self, n: int) -> List[int]:
        if self._fail_next_allocs > 0:
            self._fail_next_allocs -= 1
            raise ArenaAllocFault(
                f"injected allocation failure (want {n} blocks)")
        if n > self.num_free:
            raise RuntimeError(f"KV pool exhausted: want {n} blocks, "
                               f"{self.num_free} free")
        out = []
        for _ in range(n):
            if self._free:
                b = self._free.popleft()
                self._free_set.discard(b)
            else:
                # reclaim the least-recently-freed cached block
                b, _ = self._cached_free.popitem(last=False)
                self._unregister(b)
                self.evictions += 1
            self.refcount[b] = 1
            out.append(b)
        self.total_allocs += n
        self.peak_used = max(self.peak_used, self.num_used)
        return out

    def share(self, ids: Iterable[int]) -> None:
        """Add an owner to each block (a prefix-cache hit). Blocks on the
        cached-free list are revived in place."""
        for b in ids:
            if b in self._free_set:
                raise ValueError(f"sharing free block {b}")
            if b in self._cached_free:
                del self._cached_free[b]
                self.refcount[b] = 1
            else:
                self.refcount[b] += 1
            self.hit_blocks += 1
        self.peak_used = max(self.peak_used, self.num_used)

    def free_blocks(self, ids: Iterable[int]) -> None:
        """Drop one owner per block; a block with no owners left returns to
        the free list (or the cached-free LRU if it is a registered prefix
        block). Freeing an already-free block id raises."""
        for b in ids:
            if b == NULL_BLOCK:
                raise ValueError("freeing the reserved null block")
            if b in self._free_set or b in self._cached_free:
                raise ValueError(f"double free of block {b}")
            rc = self.refcount.get(b, 0)
            if rc < 1:
                raise ValueError(f"freeing unallocated block {b}")
            if rc > 1:
                self.refcount[b] = rc - 1
                continue
            del self.refcount[b]
            if b in self._block_to_hash:
                self._cached_free[b] = None      # evictable, still matchable
            else:
                self._free.append(b)
                self._free_set.add(b)

    # -- prefix cache -------------------------------------------------------

    def _unregister(self, b: int) -> None:
        h = self._block_to_hash.pop(b, None)
        if h is not None:
            self._hash_to_block.pop(h, None)
            self._hash_to_chunk.pop(h, None)

    def match_prefix(self, tokens: Seq,
                     hashes: Optional[List[int]] = None) -> List[int]:
        """Longest chain of registered full blocks covering a prefix of
        `tokens`. Returns the matched block ids in position order *without*
        taking ownership -- callers commit with `share`. Pass precomputed
        `hashes` (chain_hashes of the same tokens) to skip rehashing.

        Content-checked on top of the SHA-256 chain: each candidate entry's
        stored (parent hash, block tokens) must equal this prompt's -- by
        induction along the chain equal entries imply equal whole prefixes,
        so even a hash collision degrades to a cache miss, never to foreign
        KV."""
        if not self.enable_prefix_cache:
            return []
        bs = self.block_size
        if hashes is None:
            hashes = chain_hashes(tokens, bs)
        out = []
        for i, h in enumerate(hashes):
            b = self._hash_to_block.get(h)
            parent = hashes[i - 1] if i else 0
            if b is None or self._hash_to_chunk[h] != (
                    parent, tuple(tokens[i * bs:(i + 1) * bs])):
                break
            out.append(b)
        return out

    def register_prefix(self, tokens: Seq, block_ids: Seq[int],
                        n_tokens: int,
                        hashes: Optional[List[int]] = None) -> int:
        """Register the full blocks of `tokens[:n_tokens]` (whose KV the
        caller has written through `block_ids`) in the prefix index.
        First writer wins: hashes already mapped to a different block keep
        the existing mapping. Pass precomputed `hashes` covering at least
        n_tokens // block_size blocks to skip rehashing (chunked prefill
        registers after every chunk). Returns the newly indexed count."""
        if not self.enable_prefix_cache:
            return 0
        bs = self.block_size
        n_full = min(n_tokens, len(tokens)) // bs
        if hashes is None:
            hashes = chain_hashes(tokens, bs, n_tokens)
        added = 0
        for i in range(n_full):
            h = hashes[i]
            b = block_ids[i]
            if h in self._hash_to_block or b in self._block_to_hash:
                continue
            self._hash_to_block[h] = b
            self._block_to_hash[b] = h
            self._hash_to_chunk[h] = (hashes[i - 1] if i else 0,
                                      tuple(tokens[i * bs:(i + 1) * bs]))
            added += 1
        return added

    def copy_on_write(self, b: int) -> int:
        """Give the caller a private, writable copy of block `b`: allocate a
        fresh block, copy the arena rows on device, and drop one owner from
        `b`. Required before writing any block that is shared (refcount > 1)
        or registered in the prefix index (its contents must stay equal to
        its hash)."""
        [new] = self.alloc(1)
        self.k = self.k.at[:, new].set(self.k[:, b])
        self.v = self.v.at[:, new].set(self.v[:, b])
        self.free_blocks([b])
        self.cow_copies += 1
        return new

    def rollback(self, block_ids: Seq[int], n_tokens: int) -> List[int]:
        """Truncate a sequence's block list to cover exactly `n_tokens`
        cached positions, freeing the surplus tail blocks (speculative
        decoding rolls back the blocks that held rejected draft KV).

        Safety properties:
          * freeing is refcount-decrement only -- a rolled-back block that
            other sequences share (or that the prefix index still maps)
            keeps its arena contents untouched, exactly like `free_blocks`;
          * if the kept tail block is partially filled (the sequence's next
            write lands inside it) and is shared or registered, it is
            copied on write here, so post-rollback writes can never mutate
            a shared or indexed block;
          * tail blocks are freed deepest-first so the cached-free LRU
            evicts chain tails before the heads other prefixes need.

        Returns the new (kept) block list; the surplus must not be freed
        again by the caller.
        """
        keep = self.blocks_for(n_tokens)
        if keep > len(block_ids):
            raise ValueError(
                f"rollback to {n_tokens} tokens needs {keep} blocks but the "
                f"sequence owns only {len(block_ids)}")
        kept = list(block_ids[:keep])
        self.free_blocks(reversed(list(block_ids[keep:])))
        if n_tokens % self.block_size and kept and self.needs_cow(kept[-1]):
            kept[-1] = self.copy_on_write(kept[-1])
        return kept

    def needs_cow(self, b: int) -> bool:
        return self.refcount.get(b, 0) > 1 or b in self._block_to_hash

    def is_cached_free(self, b: int) -> bool:
        """True if `b` is a registered block with no live owner (reviving it
        via `share` removes it from the allocatable budget)."""
        return b in self._cached_free

    # -- invariants ---------------------------------------------------------

    def check_invariants(self, sequences: Optional[Seq] = None) -> None:
        """Full pool consistency check; raises RuntimeError on corruption.

        Pool-only invariants (always checked): block conservation -- free,
        cached-free, and owned sets are pairwise disjoint and together cover
        every allocatable block; the free deque and free set agree; the
        aggregate counters match the sets; the prefix index is a bijection
        over non-free blocks with a content chunk stored per entry.

        With `sequences` (every live owner of pool blocks), additionally:
        refcounts equal the number of owning sequences per block, no table
        holds a duplicate or free block, and the partial tail block a decode
        write would land in is never shared or registered.

        This is the test suite's fuzz oracle extracted for production use:
        the engine runs it after every recovery path and (when
        `EngineConfig.paranoid`) after every step, so a recovery bug
        corrupting the pool fails loudly at the step that caused it instead
        of as an unrelated crash thousands of steps later.
        """
        def _req(cond: bool, msg: str) -> None:
            if not cond:
                raise RuntimeError(f"KV pool invariant violated: {msg}")

        free = set(self._free)
        cached_free = set(self._cached_free)
        owned = set(self.refcount)
        _req(free == self._free_set, "free deque and free set disagree")
        _req(not (free & cached_free), "block both free and cached-free")
        _req(not (free & owned), "block both free and owned")
        _req(not (cached_free & owned), "block both cached-free and owned")
        _req(free | cached_free | owned == set(range(1, self.n_blocks)),
             "block conservation: free + cached-free + owned != all blocks")
        _req(self.num_free == len(free) + len(cached_free),
             "num_free disagrees with the free sets")
        _req(self.num_free + len(owned) == self.num_total,
             "num_free + owned != num_total")
        _req(all(rc >= 1 for rc in self.refcount.values()),
             "owned block with refcount < 1")
        _req(len(self._hash_to_block) == len(self._block_to_hash),
             "prefix index is not a bijection")
        _req(set(self._hash_to_chunk) == set(self._hash_to_block),
             "prefix index entry without a content chunk")
        for h, b in self._hash_to_block.items():
            _req(self._block_to_hash.get(b) == h,
                 f"prefix index asymmetry at block {b}")
            _req(b not in free, f"registered block {b} on the free list")
        if sequences is None:
            return
        counts: Dict[int, int] = {}
        for seq in sequences:
            for b in set(seq.block_ids):
                counts[b] = counts.get(b, 0) + 1
        _req(counts == self.refcount,
             "refcounts disagree with sequence ownership")
        for seq in sequences:
            _req(len(set(seq.block_ids)) == len(seq.block_ids),
                 f"duplicate block in table of request {seq.req_id}")
            for b in seq.block_ids:
                _req(0 < b < self.n_blocks,
                     f"request {seq.req_id} table points at block {b}")
                _req(b not in free and b not in cached_free,
                     f"request {seq.req_id} table points at freed block {b}")
            tail = seq.cache_len // self.block_size
            if seq.cache_len % self.block_size and tail < len(seq.block_ids):
                _req(not self.needs_cow(seq.block_ids[tail]),
                     f"request {seq.req_id} decode-write tail block "
                     f"{seq.block_ids[tail]} is shared or registered")

    # -- defrag -------------------------------------------------------------

    def defrag(self, sequences: Seq) -> Dict[int, int]:
        """Compact live blocks to the lowest arena indices.

        Permutes the arena rows on device (one gather per array) and rewrites
        each sequence's `block_ids` in place, so long-running churn cannot
        scatter a sequence's blocks across the arena. Refcount-aware: a block
        shared by several sequences maps to one new row (every sharer's table
        is rewritten to it) and keeps its refcount and prefix-index entry.
        Cached-free blocks (registered, no live owner) are evicted -- defrag
        reclaims them as contiguous free space. Returns the old -> new block
        id mapping.
        """
        mapping: Dict[int, int] = {}
        nxt = 1
        for seq in sequences:
            for b in seq.block_ids:
                if b in mapping:
                    continue                     # shared with an earlier seq
                mapping[b] = nxt
                nxt += 1
        self.evictions += len(self._cached_free)
        for b in list(self._cached_free):
            self._unregister(b)
        self._cached_free.clear()
        if all(old == new for old, new in mapping.items()):
            self._free = deque(range(nxt, self.n_blocks))
            self._free_set = set(self._free)
            return mapping  # already compact; skip the device gather
        # build a full permutation: new row i reads old row perm[i]
        perm = np.empty(self.n_blocks, np.int32)
        perm[0] = NULL_BLOCK
        for old, new in mapping.items():
            perm[new] = old
        spare = [b for b in range(1, self.n_blocks) if b not in mapping]
        perm[nxt:] = spare
        pj = jnp.asarray(perm)
        self.k = jnp.take(self.k, pj, axis=1)
        self.v = jnp.take(self.v, pj, axis=1)
        for seq in sequences:
            seq.block_ids = [mapping[b] for b in seq.block_ids]
        self.refcount = {mapping[b]: rc for b, rc in self.refcount.items()}
        b2h = {mapping[b]: h for b, h in self._block_to_hash.items()
               if b in mapping}
        self._block_to_hash = b2h
        self._hash_to_block = {h: b for b, h in b2h.items()}
        self._hash_to_chunk = {h: c for h, c in self._hash_to_chunk.items()
                               if h in self._hash_to_block}
        self._free = deque(range(nxt, self.n_blocks))
        self._free_set = set(self._free)
        return mapping
