"""Paged KV-cache pool: block tables over a shared per-layer arena.

The arena is a pair of device arrays shaped (L, n_blocks, block_size, Hkv,
hd) (see `transformer.init_paged_cache`). The pool manages the *host-side*
free list and hands out ordered block lists; sequences index the arena
through (padded) block tables inside the jitted model functions.

Block 0 is reserved as the null/scratch block: block-table padding points at
it, and padded batch slots write into it. It is never allocated.
"""

from __future__ import annotations

from collections import deque
from typing import Dict, Iterable, List, Sequence as Seq

import jax.numpy as jnp
import numpy as np

from repro.models import transformer

NULL_BLOCK = 0


class PagedKVPool:
    def __init__(self, cfg, *, n_blocks: int, block_size: int,
                 dtype=jnp.float32):
        if n_blocks < 2:
            raise ValueError("need at least one allocatable block besides "
                             "the reserved null block")
        arena = transformer.init_paged_cache(cfg, n_blocks, block_size, dtype)
        self.k = arena["k"]
        self.v = arena["v"]
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._free = deque(range(1, n_blocks))          # block 0 reserved
        self.peak_used = 0

    # -- accounting ---------------------------------------------------------

    @property
    def num_total(self) -> int:
        """Allocatable blocks (excludes the null block)."""
        return self.n_blocks - 1

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.num_total - self.num_free

    @property
    def utilization(self) -> float:
        return self.num_used / self.num_total

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    # -- alloc / free -------------------------------------------------------

    def can_alloc(self, n: int) -> bool:
        return n <= self.num_free

    def alloc(self, n: int) -> List[int]:
        if n > self.num_free:
            raise RuntimeError(f"KV pool exhausted: want {n} blocks, "
                               f"{self.num_free} free")
        out = [self._free.popleft() for _ in range(n)]
        self.peak_used = max(self.peak_used, self.num_used)
        return out

    def free_blocks(self, ids: Iterable[int]) -> None:
        for b in ids:
            assert b != NULL_BLOCK, "freeing the reserved null block"
            self._free.append(b)
        assert self.num_free <= self.num_total, "double free"

    # -- defrag -------------------------------------------------------------

    def defrag(self, sequences: Seq) -> Dict[int, int]:
        """Compact live blocks to the lowest arena indices.

        Permutes the arena rows on device (one gather per array) and rewrites
        each sequence's `block_ids` in place, so long-running churn cannot
        scatter a sequence's blocks across the arena. Returns the old -> new
        block id mapping.
        """
        mapping: Dict[int, int] = {}
        nxt = 1
        for seq in sequences:
            for b in seq.block_ids:
                assert b not in mapping, "block owned by two sequences"
                mapping[b] = nxt
                nxt += 1
        if all(old == new for old, new in mapping.items()):
            return mapping  # already compact; skip the device gather
        # build a full permutation: new row i reads old row perm[i]
        perm = np.empty(self.n_blocks, np.int32)
        perm[0] = NULL_BLOCK
        for old, new in mapping.items():
            perm[new] = old
        spare = [b for b in range(1, self.n_blocks) if b not in mapping]
        perm[nxt:] = spare
        pj = jnp.asarray(perm)
        self.k = jnp.take(self.k, pj, axis=1)
        self.v = jnp.take(self.v, pj, axis=1)
        for seq in sequences:
            seq.block_ids = [mapping[b] for b in seq.block_ids]
        self._free = deque(range(nxt, self.n_blocks))
        return mapping
