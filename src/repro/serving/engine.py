"""The continuous-batching LAMP serving engine.

Step loop: `add_request()` enqueues, `step()` runs one scheduler-composed
batch (a bucketed prefill or a bucketed decode) through cached jitted model
functions over the paged KV pool, samples one token per sequence, and
returns the requests that finished this step.

Fixed-shape jit discipline: batch and sequence dims are padded to
power-of-two buckets and the block-table width is a compile-time constant
(blocks_for(max_model_len)), so the number of compiled shapes is bounded by
O(log(max_batch) * log(max_prefill_len)) per (cfg, use_lamp).

Prefill runs through the *window* path (`transformer.paged_prefill_window`):
each sequence runs the un-cached suffix of its prompt -- possibly one
`max_prefill_tokens`-sized chunk of it -- at its absolute positions against
the gathered arena view. Because every per-position computation is row-wise
and the gathered key width is constant, outputs are token-identical whether
a prompt is prefilled whole, in chunks, or on top of a shared prefix.

Sampling is inside the jitted step and keyed per request as
fold_in(PRNGKey(seed), num_generated): a request's sample stream is
deterministic regardless of how it was batched, bucketed, or preempted.

LAMP telemetry: the paged attention paths return per-row selected/valid
KQ-product counts; the engine accumulates them per request and in aggregate
(the paper's recompute-rate metric, now observable per serving request).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer

from . import sampling
from .kv_pool import PagedKVPool
from .request import SamplingParams, Sequence, SequenceStatus
from .scheduler import Scheduler
from .speculative import SpecConfig, spec_step_fns

# families the paged-KV engine can serve (no per-request side inputs, no
# state-space cache); launchers use this to filter the arch registry.
TEXT_FAMILIES = ("dense", "moe", "gpt2")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    block_size: int = 16
    n_blocks: int = 0               # 0 = auto-size from max_model_len
    max_model_len: int = 0          # 0 = cfg.max_seq
    max_prefill_batch: int = 8
    max_prefill_tokens: int = 2048  # prefill-step token budget = chunk size
    max_decode_batch: int = 32
    kv_dtype: str = "float32"
    use_lamp: bool = True
    # prefix caching: requests sharing a prompt prefix map their block
    # tables onto the same arena rows (refcounted, copy-on-write)
    prefix_cache: bool = True
    # chunked prefill: long prompts prefill max_prefill_tokens per step so
    # decode steps interleave and decode latency stays bounded
    chunked_prefill: bool = True
    # attention path over the paged arena: "gather" materializes each row's
    # full block-table span (reference, bit-identical to the dense cache);
    # "pallas" runs the fused paged-attention kernel (live blocks DMA'd
    # through the block-table index map, masked blocks skipped) -- the fast
    # path on TPU, interpret mode on CPU
    kernel: str = "gather"
    # LAMP self-draft speculative decoding: decode rounds draft `draft_len`
    # tokens per sequence with the pure low-precision forward (LAMP rule
    # "none"), then verify all draft_len+1 positions in one multi-token
    # paged forward with the configured LAMP rule. Greedy outputs are
    # bit-identical to non-speculative decoding; sampled outputs follow the
    # same distribution (standard accept/residual-resample rule).
    speculative: bool = False
    draft_len: int = 4


@dataclasses.dataclass
class RequestOutput:
    req_id: int
    prompt: List[int]
    tokens: List[int]
    finish_reason: str
    latency: float
    ttft: float
    num_preemptions: int
    lamp_selected: float
    lamp_valid: float
    num_cached_tokens: int = 0      # prompt tokens served from prefix cache
    spec_drafted: int = 0           # tokens drafted for this request
    spec_accepted: int = 0          # drafted tokens the verifier accepted

    @property
    def lamp_recompute_rate(self) -> float:
        return self.lamp_selected / self.lamp_valid if self.lamp_valid else 0.0

    @property
    def spec_acceptance_rate(self) -> float:
        return (self.spec_accepted / self.spec_drafted
                if self.spec_drafted else 0.0)


def _bucket(n: int, cap: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return min(b, cap) if cap else b


# jitted step functions keyed on (cfg, use_lamp), shared across engine
# instances so re-instantiation (benchmarks, tests) never recompiles. The KV
# arenas are donated: the per-step .at[].set() updates alias the pool buffers
# in place instead of copying the whole arena every token. Sampling routes
# through the shared serving/sampling.py primitives (same key schedule as
# before: fold_in(PRNGKey(seed), num_generated)).
_JIT_CACHE: Dict[Any, Any] = {}


def _jitted_steps(cfg, use_lamp: bool, kernel: str = "gather",
                  use_topk: bool = False):
    """`use_topk` is a static trace-time switch: the per-row top-k filter
    needs a vocab sort per row per step, so batches where every request has
    top_k == 0 (the common case) use the variant that skips it entirely.
    At most two variants compile per (cfg, use_lamp, kernel)."""
    key = (cfg, use_lamp, kernel, use_topk)
    fns = _JIT_CACHE.get(key)
    if fns is None:
        def _prefill(params, k, v, tokens, bt, starts, lengths, seeds,
                     counts, temps, topks):
            logits, arena, (nsel, nval) = transformer.paged_prefill_window(
                cfg, params, tokens, {"k": k, "v": v}, bt, starts, lengths,
                use_lamp=use_lamp, kernel=kernel)
            nxt = sampling.sample_rows(logits[:, -1], seeds, counts, temps,
                                       top_k=topks if use_topk else None)
            return nxt, arena["k"], arena["v"], nsel, nval

        def _decode(params, k, v, bt, lengths, tokens, seeds, counts, temps,
                    topks):
            logits, arena, (nsel, nval) = transformer.paged_decode_step(
                cfg, params, {"k": k, "v": v}, bt, lengths, tokens,
                use_lamp=use_lamp, kernel=kernel)
            nxt = sampling.sample_rows(logits[:, -1], seeds, counts, temps,
                                       top_k=topks if use_topk else None)
            return nxt, arena["k"], arena["v"], nsel, nval

        fns = (jax.jit(_prefill, donate_argnums=(1, 2)),
               jax.jit(_decode, donate_argnums=(1, 2)))
        _JIT_CACHE[key] = fns
    return fns


class LampEngine:
    def __init__(self, cfg, params, econfig: EngineConfig = EngineConfig()):
        if cfg.family not in TEXT_FAMILIES:
            raise ValueError(
                f"serving engine supports the paged-KV text families "
                f"{TEXT_FAMILIES}, got {cfg.family!r} (state-space / "
                f"modality-frontend families need their own cache layout; "
                f"see ROADMAP open items)")
        if min(econfig.max_prefill_tokens, econfig.max_prefill_batch,
               econfig.max_decode_batch) < 1:
            raise ValueError(
                "max_prefill_tokens, max_prefill_batch and max_decode_batch "
                "must all be >= 1 (a zero prefill budget cannot make "
                "progress)")
        if econfig.kernel not in ("gather", "pallas"):
            raise ValueError(
                f"kernel must be 'gather' or 'pallas', got "
                f"{econfig.kernel!r}")
        if econfig.speculative and econfig.draft_len < 1:
            raise ValueError(
                f"speculative decoding needs draft_len >= 1, got "
                f"{econfig.draft_len}")
        self.cfg = cfg
        self.params = params
        self.econfig = econfig
        self.max_model_len = econfig.max_model_len or cfg.max_seq
        bs = econfig.block_size
        self.blocks_per_seq = -(-self.max_model_len // bs)
        n_blocks = econfig.n_blocks or 4 * self.blocks_per_seq + 1
        if n_blocks - 1 < self.blocks_per_seq:
            raise ValueError(
                f"n_blocks={n_blocks} (one reserved for the null block) "
                f"cannot hold one max-length sequence: need "
                f"{self.blocks_per_seq + 1} for max_model_len="
                f"{self.max_model_len} at block_size={bs}")
        self.pool = PagedKVPool(cfg, n_blocks=n_blocks, block_size=bs,
                                dtype=jnp.dtype(econfig.kv_dtype),
                                enable_prefix_cache=econfig.prefix_cache)
        self.scheduler = Scheduler(
            self.pool, max_prefill_batch=econfig.max_prefill_batch,
            max_prefill_tokens=econfig.max_prefill_tokens,
            max_decode_batch=econfig.max_decode_batch,
            chunked_prefill=econfig.chunked_prefill,
            spec_draft_len=econfig.draft_len if econfig.speculative else 0)
        self._next_id = 0
        self._seqs: Dict[int, Sequence] = {}
        self._finished: List[RequestOutput] = []
        self._util_samples: List[float] = []
        self._start: Optional[float] = None
        self.total_steps = 0
        self.prefill_steps = 0
        self.decode_steps = 0
        self.prefill_chunks = 0         # partial windows (prompt continues)
        self.prefill_tokens_run = 0     # prompt tokens actually computed
        self.generated_tokens = 0
        self.agg_lamp_selected = 0.0
        self.agg_lamp_valid = 0.0
        # speculative-decoding telemetry
        self.spec_rounds = 0            # decode rounds run speculatively
        self.spec_drafted = 0           # draft tokens proposed
        self.spec_accepted = 0          # draft tokens the verifier accepted
        self.spec_emitted = 0           # tokens emitted by spec rounds
        self.spec_verify_selected = 0.0  # LAMP counts of the verify passes
        self.spec_verify_valid = 0.0

        self.spec_config = (SpecConfig(draft_len=econfig.draft_len)
                            if econfig.speculative else None)

    # step functions resolve per batch: `use_topk` selects the jit variant
    # with/without the per-row top-k vocab sort (global caches dedupe, so
    # at most two variants compile per step kind)

    def _step_fns(self, seqs: List[Sequence]):
        use_topk = any(s.sampling.top_k > 0 for s in seqs)
        return _jitted_steps(self.cfg, self.econfig.use_lamp,
                             self.econfig.kernel, use_topk)

    def _spec_fns(self, seqs: List[Sequence]):
        use_topk = any(s.sampling.top_k > 0 for s in seqs)
        return spec_step_fns(self.cfg, self.econfig.use_lamp,
                             self.econfig.kernel, self.spec_config,
                             use_topk)

    # -- request intake -----------------------------------------------------

    def add_request(self, prompt: List[int],
                    sampling: SamplingParams = SamplingParams(),
                    arrival_time: Optional[float] = None) -> int:
        if not prompt:
            raise ValueError("empty prompt")
        if sampling.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {sampling.max_new_tokens}")
        if len(prompt) + sampling.max_new_tokens > self.max_model_len:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new_tokens"
                f"({sampling.max_new_tokens}) exceeds max_model_len "
                f"{self.max_model_len}")
        req_id = self._next_id
        self._next_id += 1
        seq = Sequence(req_id, prompt, sampling,
                       arrival_time if arrival_time is not None
                       else time.monotonic())
        self._seqs[req_id] = seq
        self.scheduler.add(seq)
        return req_id

    def has_unfinished(self) -> bool:
        return self.scheduler.has_work()

    # -- the step loop ------------------------------------------------------

    def step(self) -> List[RequestOutput]:
        """Run one engine step; returns requests finished by this step."""
        if self._start is None:
            self._start = time.monotonic()
        plan = self.scheduler.schedule()
        if plan is None:
            return []
        if plan.kind == "prefill":
            self._step_prefill(plan.seqs, plan.windows)
            self.prefill_steps += 1
        elif self.econfig.speculative and any(plan.draft_lens):
            self._step_spec(plan.seqs, plan.draft_lens)
            self.decode_steps += 1
        else:
            # no draft budget anywhere (spec off, block pressure shed it,
            # or every sequence is at its token limit): the plain decode
            # step is the same progress at a fraction of the compute
            self._step_decode(plan.seqs)
            self.decode_steps += 1
        self.total_steps += 1
        self._util_samples.append(self.pool.utilization)
        return self._collect_finished(plan.seqs)

    def _batch_arrays(self, seqs: List[Sequence], Bb: int):
        bt = np.zeros((Bb, self.blocks_per_seq), np.int32)
        seeds = np.zeros((Bb,), np.int32)
        counts = np.zeros((Bb,), np.int32)
        temps = np.zeros((Bb,), np.float32)
        topks = np.zeros((Bb,), np.int32)
        for i, seq in enumerate(seqs):
            bt[i, :len(seq.block_ids)] = seq.block_ids
            seeds[i] = seq.sampling.seed
            counts[i] = seq.num_generated
            temps[i] = seq.sampling.temperature
            topks[i] = seq.sampling.top_k
        return bt, seeds, counts, temps, topks

    def _step_prefill(self, seqs: List[Sequence],
                      windows: List[int]) -> None:
        """Run one prefill window per sequence: the whole remaining prompt,
        or a `max_prefill_tokens`-bounded chunk of it. A sequence whose
        window completes its prompt samples its first token and moves to
        DECODE; otherwise it stays PREFILL with its cursor advanced."""
        Wb = _bucket(max(windows), 0)
        Bb = _bucket(len(seqs), self.econfig.max_prefill_batch)
        tokens = np.zeros((Bb, Wb), np.int32)
        starts = np.zeros((Bb,), np.int32)
        lengths = np.ones((Bb,), np.int32)   # pad rows: 1 token in null block
        for i, (seq, w) in enumerate(zip(seqs, windows)):
            cur = seq.prefill_cursor
            tokens[i, :w] = seq.prefill_tokens()[cur:cur + w]
            starts[i] = cur
            lengths[i] = w
        bt, seeds, counts, temps, topks = self._batch_arrays(seqs, Bb)
        prefill_fn, _ = self._step_fns(seqs)
        nxt, self.pool.k, self.pool.v, nsel, nval = prefill_fn(
            self.params, self.pool.k, self.pool.v, jnp.asarray(tokens),
            jnp.asarray(bt), jnp.asarray(starts), jnp.asarray(lengths),
            jnp.asarray(seeds), jnp.asarray(counts), jnp.asarray(temps),
            jnp.asarray(topks))
        nxt, nsel, nval = (np.asarray(nxt), np.asarray(nsel),
                           np.asarray(nval))
        now = time.monotonic()
        for i, (seq, w) in enumerate(zip(seqs, windows)):
            seq.prefill_cursor += w
            seq.cache_len = seq.prefill_cursor
            self.prefill_tokens_run += w
            seq.lamp.add(nsel[i], nval[i])
            self.agg_lamp_selected += float(nsel[i])
            self.agg_lamp_valid += float(nval[i])
            if self.econfig.prefix_cache:
                # the window's full blocks now hold real KV: make them
                # matchable by later arrivals (and by our own resume); the
                # admission-time chain hashes avoid rehashing per chunk
                self.pool.register_prefix(seq.prefill_tokens(),
                                          seq.block_ids, seq.cache_len,
                                          hashes=seq.prefix_hashes)
            if seq.prefill_remaining == 0:
                seq.status = SequenceStatus.DECODE
                seq.on_token(int(nxt[i]), now)
                self.generated_tokens += 1
            else:
                self.prefill_chunks += 1

    def _step_decode(self, seqs: List[Sequence]) -> None:
        Rb = _bucket(len(seqs), self.econfig.max_decode_batch)
        tokens = np.zeros((Rb, 1), np.int32)
        lengths = np.zeros((Rb,), np.int32)  # pad rows write into null block
        for i, seq in enumerate(seqs):
            tokens[i, 0] = seq.last_token
            lengths[i] = seq.cache_len
        bt, seeds, counts, temps, topks = self._batch_arrays(seqs, Rb)
        _, decode_fn = self._step_fns(seqs)
        nxt, self.pool.k, self.pool.v, nsel, nval = decode_fn(
            self.params, self.pool.k, self.pool.v, jnp.asarray(bt),
            jnp.asarray(lengths), jnp.asarray(tokens), jnp.asarray(seeds),
            jnp.asarray(counts), jnp.asarray(temps), jnp.asarray(topks))
        nxt, nsel, nval = (np.asarray(nxt), np.asarray(nsel),
                           np.asarray(nval))
        now = time.monotonic()
        for i, seq in enumerate(seqs):
            seq.cache_len += 1
            seq.lamp.add(nsel[i], nval[i])
            self.agg_lamp_selected += float(nsel[i])
            self.agg_lamp_valid += float(nval[i])
            seq.on_token(int(nxt[i]), now)
            self.generated_tokens += 1

    def _step_spec(self, seqs: List[Sequence],
                   draft_lens: List[int]) -> None:
        """One speculative round over the decode batch: draft up to
        `draft_lens[i]` tokens per sequence with the low-precision
        self-draft, verify every drafted position (plus the bonus slot) in
        one multi-token LAMP forward, emit the accepted prefix + one
        verifier token, and roll back the blocks that held rejected draft
        KV. A sequence with draft budget 0 runs a verify-only round, which
        is exactly one plain decode step's progress."""
        Rb = _bucket(len(seqs), self.econfig.max_decode_batch)
        tok0 = np.zeros((Rb,), np.int32)
        lengths = np.zeros((Rb,), np.int32)  # pad rows write into null block
        kd = np.zeros((Rb,), np.int32)
        for i, seq in enumerate(seqs):
            tok0[i] = seq.last_token
            lengths[i] = seq.cache_len
            kd[i] = draft_lens[i]
        bt, seeds, counts, temps, topks = self._batch_arrays(seqs, Rb)
        bt, lengths, tok0, kd, seeds, counts, temps, topks = map(
            jnp.asarray, (bt, lengths, tok0, kd, seeds, counts, temps,
                          topks))
        draft_fn, verify_fn = self._spec_fns(seqs)
        d_toks, d_logits, self.pool.k, self.pool.v = draft_fn(
            self.params, self.pool.k, self.pool.v, bt, lengths, tok0, kd,
            seeds, counts, temps, topks)
        emit, n_acc, self.pool.k, self.pool.v, nsel, nval = verify_fn(
            self.params, self.pool.k, self.pool.v, tok0, d_toks, d_logits,
            bt, lengths, kd, seeds, counts, temps, topks)
        emit, n_acc, nsel, nval = (np.asarray(emit), np.asarray(n_acc),
                                   np.asarray(nsel), np.asarray(nval))
        now = time.monotonic()
        self.spec_rounds += 1
        for i, seq in enumerate(seqs):
            a = int(n_acc[i])
            seq.lamp.add(nsel[i], nval[i])
            self.agg_lamp_selected += float(nsel[i])
            self.agg_lamp_valid += float(nval[i])
            self.spec_verify_selected += float(nsel[i])
            self.spec_verify_valid += float(nval[i])
            seq.spec_drafted += int(draft_lens[i])
            seq.spec_accepted += a
            self.spec_drafted += int(draft_lens[i])
            self.spec_accepted += a
            # emit accepted drafts + the verifier's token, stopping at the
            # request's own limits (surplus accepted tokens are dropped and
            # their cache rolls back with the rejected ones)
            appended = 0
            for t in emit[i, :a + 1]:
                seq.on_token(int(t), now)
                appended += 1
                self.generated_tokens += 1
                if seq.should_stop():
                    break
            seq.cache_len += appended
            self.spec_emitted += appended
            seq.block_ids = self.pool.rollback(seq.block_ids, seq.cache_len)

    def _collect_finished(self, seqs: List[Sequence]) -> List[RequestOutput]:
        done = []
        now = time.monotonic()
        for seq in seqs:
            reason = seq.should_stop()
            if reason is None:
                continue
            seq.finish(reason, now)
            self.scheduler.finish(seq)
            out = RequestOutput(
                req_id=seq.req_id, prompt=seq.prompt, tokens=seq.generated,
                finish_reason=reason, latency=seq.latency(),
                ttft=seq.ttft(), num_preemptions=seq.num_preemptions,
                lamp_selected=seq.lamp.selected, lamp_valid=seq.lamp.valid,
                num_cached_tokens=seq.num_cached_tokens,
                spec_drafted=seq.spec_drafted,
                spec_accepted=seq.spec_accepted)
            self._finished.append(out)
            done.append(out)
        return done

    # -- maintenance / metrics ---------------------------------------------

    def defrag(self) -> None:
        self.pool.defrag(sorted(self.scheduler.running,
                                key=lambda s: s.arrival_time))

    @property
    def num_preemptions(self) -> int:
        return self.scheduler.num_preemptions

    def stats(self) -> Dict[str, Any]:
        elapsed = (time.monotonic() - self._start) if self._start else 0.0
        lat = [o.latency for o in self._finished]
        ttft = [o.ttft for o in self._finished]
        cached = sum(s.num_cached_tokens for s in self._seqs.values())
        return {
            "num_finished": len(self._finished),
            "elapsed_s": elapsed,
            "tokens_per_s": self.generated_tokens / elapsed if elapsed else 0.0,
            "requests_per_s": len(self._finished) / elapsed if elapsed else 0.0,
            "latency_p50_s": float(np.percentile(lat, 50)) if lat else 0.0,
            "latency_p99_s": float(np.percentile(lat, 99)) if lat else 0.0,
            "ttft_p50_s": float(np.percentile(ttft, 50)) if ttft else 0.0,
            "steps": self.total_steps,
            "prefill_steps": self.prefill_steps,
            "decode_steps": self.decode_steps,
            "prefill_chunks": self.prefill_chunks,
            "preemptions": self.num_preemptions,
            # prefix-cache telemetry
            "blocks_allocated": self.pool.total_allocs,
            "blocks_saved": self.pool.hit_blocks,
            "cached_tokens": cached,
            "prefill_tokens_run": self.prefill_tokens_run,
            "cache_hit_rate": cached / max(1, self.prefill_tokens_run
                                           + cached),
            "cow_copies": self.pool.cow_copies,
            "cache_evictions": self.pool.evictions,
            "kv_util_mean": float(np.mean(self._util_samples))
            if self._util_samples else 0.0,
            "kv_util_peak": self.pool.peak_used / self.pool.num_total,
            "lamp_recompute_rate": (self.agg_lamp_selected /
                                    self.agg_lamp_valid
                                    if self.agg_lamp_valid else 0.0),
            # hung-stream visibility: requests still queued or running
            "live_requests": (len(self.scheduler.waiting)
                              + len(self.scheduler.running)),
            # speculative decoding
            "spec_rounds": self.spec_rounds,
            "spec_drafted_tokens": self.spec_drafted,
            "spec_accepted_tokens": self.spec_accepted,
            "spec_acceptance_rate": (self.spec_accepted / self.spec_drafted
                                     if self.spec_drafted else 0.0),
            "spec_tokens_per_round": (self.spec_emitted / self.spec_rounds
                                      if self.spec_rounds else 0.0),
            "verify_recompute_rate": (self.spec_verify_selected /
                                      self.spec_verify_valid
                                      if self.spec_verify_valid else 0.0),
        }

    def run_to_completion(self, max_steps: int = 100000) -> List[RequestOutput]:
        """Drive step() until every queued request finishes.

        Raises RuntimeError when `max_steps` elapse with requests still
        live, so a hung stream (scheduler stall, runaway generation) is
        loud instead of silently dropping requests; stats()["live_requests"]
        exposes the same condition to pollers."""
        out: List[RequestOutput] = []
        for _ in range(max_steps):
            if not self.has_unfinished():
                return out
            out.extend(self.step())
        live = self.stats()["live_requests"]
        raise RuntimeError(
            f"run_to_completion exceeded max_steps={max_steps} with {live} "
            f"request(s) still live ({len(self._finished)} finished); the "
            f"stream is hung or max_steps is too small")
