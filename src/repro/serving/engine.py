"""The continuous-batching LAMP serving engine.

Step loop: `add_request()` enqueues, `step()` runs one scheduler-composed
batch (a bucketed prefill or a bucketed decode) through cached jitted model
functions over the paged KV pool, samples one token per sequence, and
returns the requests that finished this step.

Fixed-shape jit discipline: batch and sequence dims are padded to
power-of-two buckets and the block-table width is a compile-time constant
(blocks_for(max_model_len)), so the number of compiled shapes is bounded by
O(log(max_batch) * log(max_prefill_len)) per (cfg, use_lamp).

Prefill runs through the *window* path (`transformer.paged_prefill_window`):
each sequence runs the un-cached suffix of its prompt -- possibly one
`max_prefill_tokens`-sized chunk of it -- at its absolute positions against
the gathered arena view. Because every per-position computation is row-wise
and the gathered key width is constant, outputs are token-identical whether
a prompt is prefilled whole, in chunks, or on top of a shared prefix.

Fused step (`EngineConfig.fused_step`): the scheduler emits one *mixed*
StepPlan per step -- prefill windows, plain decode rows (width-1 windows at
start = cache_len) and speculative verify rows (width kd+1 windows) side by
side -- and the engine runs it as ONE bucketed jitted launch through
`transformer.paged_mixed_step` (plus the sequential draft scan when any row
drafted). Per-row (start, qlen) metadata is scalar-prefetched into the
paged-attention grid, so every mix of roles reuses the same compiled
(rows, max_window) bucket: the jit cache is keyed on one signature instead
of three. Because all three legacy paths are special cases of the same
row-wise window computation, fused outputs are token-identical to the
split paths; `mixed_exec="split"` executes the *same* mixed plans through
the legacy sub-steps as the differential-testing twin
(tests/test_fused_step.py locks the equivalence down).

Sampling is inside the jitted step and keyed per request as
fold_in(PRNGKey(seed), num_generated): a request's sample stream is
deterministic regardless of how it was batched, bucketed, or preempted.

Observability (src/repro/obs/): every step phase -- schedule, block alloc,
prefill, decode, draft, verify, host<->device sync, emit, defrag -- runs
inside an `obs.span(...)`, feeding per-phase duration histograms (always on)
and, with `ObsConfig.trace`, a ring-buffered Chrome-trace exporter. The
engine's counters live in the obs metrics registry (`stats()` is a view over
it; the legacy attribute names are properties over the same counters). LAMP
recompute counts are threaded per layer: the jitted steps return (L, B)
selected/valid counts, accumulated into per-layer counters, a bounded
recompute-rate time series, and per-request per-layer breakdowns. Jit
compiles are detected per call (the bucketed step cache growing) and logged
with their bucket shape and wall time -- recompile storms are the canonical
silent perf killer of fixed-shape serving.
"""

from __future__ import annotations

import dataclasses
import json
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.obs import ObsConfig, Observability
from repro.obs.audit import AuditConfig, ShadowAuditor

from . import sampling
from .faults import (FaultConfig, FaultInjector, StepLaunchFault)
from .fn_cache import STEP_FNS
from .kv_pool import PagedKVPool
from .policy import PolicyConfig, PolicyController, PolicySignals
from .request import SamplingParams, Sequence, SequenceStatus
from .scheduler import Scheduler, StepPlan
from .speculative import SpecConfig, spec_step_fns, speculative_accept

# families the paged-KV engine can serve (no per-request side inputs, no
# state-space cache); launchers use this to filter the arch registry.
TEXT_FAMILIES = ("dense", "moe", "gpt2")


class QueueFullError(RuntimeError):
    """Raised by `add_request` when the bounded admission queue
    (`EngineConfig.max_queue`) is full: explicit backpressure instead of an
    unbounded waiting deque under overload."""


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    block_size: int = 16
    n_blocks: int = 0               # 0 = auto-size from max_model_len
    max_model_len: int = 0          # 0 = cfg.max_seq
    max_prefill_batch: int = 8
    max_prefill_tokens: int = 2048  # prefill-step token budget = chunk size
    max_decode_batch: int = 32
    kv_dtype: str = "float32"
    use_lamp: bool = True
    # prefix caching: requests sharing a prompt prefix map their block
    # tables onto the same arena rows (refcounted, copy-on-write)
    prefix_cache: bool = True
    # chunked prefill: long prompts prefill max_prefill_tokens per step so
    # decode steps interleave and decode latency stays bounded
    chunked_prefill: bool = True
    # attention path over the paged arena: "gather" materializes each row's
    # full block-table span (reference, bit-identical to the dense cache);
    # "pallas" runs the fused paged-attention kernel (live blocks DMA'd
    # through the block-table index map, masked blocks skipped) -- the fast
    # path on TPU, interpret mode on CPU
    kernel: str = "gather"
    # LAMP self-draft speculative decoding: decode rounds draft `draft_len`
    # tokens per sequence with the pure low-precision forward (LAMP rule
    # "none"), then verify all draft_len+1 positions in one multi-token
    # paged forward with the configured LAMP rule. Greedy outputs are
    # bit-identical to non-speculative decoding; sampled outputs follow the
    # same distribution (standard accept/residual-resample rule).
    speculative: bool = False
    draft_len: int = 4
    # fused serving step: the scheduler emits one mixed StepPlan per step
    # (prefill windows + decode rows + speculative verify rows together)
    # and the engine executes it as a single bucketed jitted launch over
    # `transformer.paged_mixed_step` (plus the sequential draft scan when
    # any row drafted). On by default since the shadow-audit burn-in
    # showed zero audited-error delta fused-vs-split (serving_bench
    # --audit-only gates this); fused_step=False restores the
    # phase-segregated pre-fusion plans
    fused_step: bool = True
    # how mixed plans execute: "fused" (one launch) or "split" (the same
    # plan through the legacy prefill/decode/spec sub-steps) -- the
    # differential-testing twin; only consulted when fused_step is on
    mixed_exec: str = "fused"
    # observability: the metrics registry and per-phase histograms are
    # always on; obs.trace additionally records step-phase spans for
    # Chrome-trace export (see repro.obs.ObsConfig)
    obs: ObsConfig = ObsConfig()
    # shadow-audit subsystem (repro.obs.audit): on a deterministic sample
    # of steps, re-run up to audit.max_rows rows through the LAMP-vs-FP32
    # lockstep forward (gather path, non-donated arena) and record
    # realized-error telemetry -- lamp_audit_* metrics, stats()["audit"],
    # and (with the policy on) error-model-calibrated per-layer targets.
    # rate=0 disables the subsystem entirely
    audit: AuditConfig = AuditConfig()
    # adaptive LAMP policy loop (serving/policy.py): per-layer thresholds
    # actuated toward target recompute rates every step (traced operands,
    # never a recompile), with load-aware degradation of draft length and
    # rule tier under pool pressure. Off by default: the engine then runs
    # the static site tau, token-identical to pre-policy behavior
    policy: PolicyConfig = PolicyConfig()
    # finished RequestOutputs retained for exact end-of-run percentiles;
    # older entries age out so a long-lived engine's memory stays bounded
    finished_retention: int = 1024
    # -- fault tolerance (serving/faults.py) --------------------------------
    # deterministic fault injection: seeded chaos behind named sites; the
    # default (enabled=False) constructs no injector at all
    faults: FaultConfig = FaultConfig()
    # numerical health guard: every jitted step returns a per-row health
    # scalar (max |final logit| over the row's live positions, in-jit, so
    # the check itself costs one reduce); the guard quarantines non-finite
    # rows host-side and retries them through the recovery ladder
    # (retry -> strict rule -> gather kernel -> FP32 reference, bounded by
    # max_retries) before failing the request alone. health_max_abs > 0
    # additionally treats |logit| above it as unhealthy (0 = finite-only)
    health_guard: bool = True
    health_max_abs: float = 0.0
    max_retries: int = 4
    # bounded admission queue: add_request raises QueueFullError once this
    # many requests are waiting (0 = unbounded, the historical behavior)
    max_queue: int = 0
    # stall watchdog: consecutive no-progress steps run_to_completion
    # tolerates before attempting recovery (evict the stalled rows,
    # continue) and, only if recovery changes nothing, raising
    stall_patience: int = 64
    # paranoid mode: run pool.check_invariants() against every live
    # sequence after every step (recovery paths always check)
    paranoid: bool = False


@dataclasses.dataclass
class RequestOutput:
    req_id: int
    prompt: List[int]
    tokens: List[int]
    finish_reason: str
    latency: float
    ttft: float
    num_preemptions: int
    lamp_selected: float
    lamp_valid: float
    num_cached_tokens: int = 0      # prompt tokens served from prefix cache
                                    # (cross-request hits only)
    num_resume_cached_tokens: int = 0  # own-KV hits on preemption resume
    spec_drafted: int = 0           # tokens drafted for this request
    spec_accepted: int = 0          # drafted tokens the verifier accepted
    # per-layer LAMP breakdown (length n_layers; sums to the scalars above)
    lamp_layer_selected: Optional[List[float]] = None
    lamp_layer_valid: Optional[List[float]] = None
    # shadow-audit accumulation: steps this request was audited in, summed
    # final-logit relative error across them, and argmax flips observed
    audit_samples: int = 0
    audit_err_sum: float = 0.0
    audit_flips: int = 0
    # set only on individually-failed requests (finish_reason "timeout" /
    # "unhealthy" / "stalled"): the diagnostic the engine failed them with
    error: Optional[str] = None

    @property
    def lamp_recompute_rate(self) -> float:
        return self.lamp_selected / self.lamp_valid if self.lamp_valid else 0.0

    @property
    def lamp_layer_rates(self) -> List[float]:
        """Per-layer recompute rate for this request (empty if no LAMP)."""
        if not self.lamp_layer_selected:
            return []
        return [s / v if v else 0.0 for s, v in
                zip(self.lamp_layer_selected, self.lamp_layer_valid)]

    @property
    def spec_acceptance_rate(self) -> float:
        return (self.spec_accepted / self.spec_drafted
                if self.spec_drafted else 0.0)


def _bucket(n: int, cap: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return min(b, cap) if cap else b


def _cache_size(fn) -> int:
    """Compiled-signature count of a jitted function; -1 when the runtime
    does not expose it (compile events are then simply not recorded)."""
    try:
        return fn._cache_size()
    except Exception:
        return -1


# jitted step functions live in the shared bounded fn_cache.STEP_FNS store
# (one keyed LRU for the step/spec/mixed builders), shared across engine
# instances so re-instantiation (benchmarks, tests) never recompiles. The KV
# arenas are donated: the per-step .at[].set() updates alias the pool buffers
# in place instead of copying the whole arena every token. Sampling routes
# through the shared serving/sampling.py primitives (same key schedule as
# before: fold_in(PRNGKey(seed), num_generated)).


def _jitted_steps(cfg, use_lamp: bool, kernel: str = "gather",
                  use_topk: bool = False):
    """`use_topk` is a static trace-time switch: the per-row top-k filter
    needs a vocab sort per row per step, so batches where every request has
    top_k == 0 (the common case) use the variant that skips it entirely.
    At most two variants compile per (cfg, use_lamp, kernel). LAMP counts
    come back per layer ((L, B) arrays); the host side reduces them.
    `taus` is a traced (L,) float32 operand carrying the live per-layer
    LAMP thresholds -- deliberately *outside* the jit cache key, so the
    policy controller can move thresholds every step for free.

    The prefill fn doubles as the fused mixed step for plans without draft
    rows: a decode row is a width-1 prefill window at start = cache_len
    (`paged_prefill_window` delegates to `paged_mixed_step`), so fused mode
    adds zero new compiled functions on the no-draft path -- only new
    (rows, max_window) bucket shapes of this one signature."""
    def build():
        def _prefill(params, k, v, tokens, bt, starts, lengths, taus, seeds,
                     counts, temps, topks):
            logits, arena, (nsel, nval) = transformer.paged_prefill_window(
                cfg, params, tokens, {"k": k, "v": v}, bt, starts, lengths,
                use_lamp=use_lamp, kernel=kernel, per_layer=True, taus=taus)
            lg = logits[:, -1]
            nxt = sampling.sample_rows(lg, seeds, counts, temps,
                                       top_k=topks if use_topk else None)
            # per-row numerical health for the engine's guard: max |final
            # logit| (NaN/Inf propagate through the reduce). One in-jit
            # reduction -- the guard's whole device-side cost
            health = jnp.max(jnp.abs(lg), axis=-1)
            return nxt, health, arena["k"], arena["v"], nsel, nval

        def _decode(params, k, v, bt, lengths, tokens, taus, seeds, counts,
                    temps, topks):
            logits, arena, (nsel, nval) = transformer.paged_decode_step(
                cfg, params, {"k": k, "v": v}, bt, lengths, tokens,
                use_lamp=use_lamp, kernel=kernel, per_layer=True, taus=taus)
            lg = logits[:, -1]
            nxt = sampling.sample_rows(lg, seeds, counts, temps,
                                       top_k=topks if use_topk else None)
            health = jnp.max(jnp.abs(lg), axis=-1)
            return nxt, health, arena["k"], arena["v"], nsel, nval

        return (jax.jit(_prefill, donate_argnums=(1, 2)),
                jax.jit(_decode, donate_argnums=(1, 2)))

    return STEP_FNS.get_or_build(("step", cfg, use_lamp, kernel, use_topk),
                                 build)


def _mixed_spec_step(cfg, use_lamp: bool, kernel: str, spec: SpecConfig,
                     use_topk: bool = False):
    """The fused mixed step for plans with draft rows: one jitted call runs
    every row (prefill windows, plain decode rows, verify rows) through
    `paged_mixed_step` with all window logits kept, samples the next token
    at each row's last valid position (prefill / plain-decode rows), and
    runs `speculative_accept` over the first k+1 positions (verify rows).
    The host picks per role; unused lanes cost only the tiny sampling tail.

    Draft tokens/logits arrive over the draft bucket (R rows) and scatter
    into the mixed batch via `dec_pos` (mixed-row index per draft row; pad
    rows point out of range and mode="drop" discards them), so the draft
    scan keeps its own compact bucket while the verify shares the mixed
    launch."""
    k = spec.draft_len

    def build():
        def _mixed(params, ak, av, tokens, bt, starts, qlens, kd, dec_pos,
                   d_toks, d_logits, taus, seeds, counts, temps, topks):
            B = tokens.shape[0]
            tokens = tokens.at[dec_pos, 1:k + 1].set(d_toks, mode="drop")
            dt = jnp.zeros((B, k), d_toks.dtype)
            dt = dt.at[dec_pos].set(d_toks, mode="drop")
            dl = jnp.zeros((B,) + d_logits.shape[1:], d_logits.dtype)
            dl = dl.at[dec_pos].set(d_logits, mode="drop")
            logits, arena, (nsel, nval) = transformer.paged_mixed_step(
                cfg, params, tokens, {"k": ak, "v": av}, bt, starts, qlens,
                use_lamp=use_lamp, kernel=kernel, per_layer=True, taus=taus,
                all_logits=True)
            last = logits[jnp.arange(B), jnp.maximum(qlens, 1) - 1]
            nxt = sampling.sample_rows(last, seeds, counts, temps,
                                       top_k=topks if use_topk else None)
            emit, n_acc = speculative_accept(
                logits[:, :k + 1], dt, dl, kd, seeds, counts, temps,
                topks if use_topk else None)
            # per-row health over each row's *live* window positions only
            # (all_logits=True keeps kernel garbage past qlens[b], which
            # must not poison the check)
            live = jnp.arange(logits.shape[1])[None, :] < qlens[:, None]
            health = jnp.max(
                jnp.where(live[..., None], jnp.abs(logits), 0.0),
                axis=(1, 2))
            return nxt, emit, n_acc, health, arena["k"], arena["v"], \
                nsel, nval

        return jax.jit(_mixed, donate_argnums=(1, 2))

    return STEP_FNS.get_or_build(
        ("mixed", cfg, use_lamp, kernel, spec, use_topk), build)


def _audit_step_fn(cfg, top_k: int):
    """The shadow-audit launch: `paged_audit_window` jitted WITHOUT arena
    donation -- the pool buffers must survive the call untouched (the
    zero-token-perturbation guarantee), and only reduced error metrics come
    back. Cached per (cfg, top_k); audited row batches ride small
    power-of-two (rows, window) buckets of this one signature."""
    def build():
        def _audit(params, k, v, tokens, bt, starts, lengths, row_mask,
                   taus):
            return transformer.paged_audit_window(
                cfg, params, tokens, {"k": k, "v": v}, bt, starts, lengths,
                row_mask, taus=taus, top_k=top_k)
        return jax.jit(_audit)

    return STEP_FNS.get_or_build(("audit", cfg, top_k), build)


def reset_step_caches() -> None:
    """Benchmark/test helper: drop the shared step-function cache AND JAX's
    compiled-computation caches, so compile counts (obs compile events)
    measure from a cold start instead of riding earlier runs' work."""
    STEP_FNS.clear()
    jax.clear_caches()


class LampEngine:
    def __init__(self, cfg, params, econfig: EngineConfig = EngineConfig(),
                 *, clock: Optional[Callable[[], float]] = None):
        if cfg.family not in TEXT_FAMILIES:
            raise ValueError(
                f"serving engine supports the paged-KV text families "
                f"{TEXT_FAMILIES}, got {cfg.family!r} (state-space / "
                f"modality-frontend families need their own cache layout; "
                f"see ROADMAP open items)")
        if min(econfig.max_prefill_tokens, econfig.max_prefill_batch,
               econfig.max_decode_batch) < 1:
            raise ValueError(
                "max_prefill_tokens, max_prefill_batch and max_decode_batch "
                "must all be >= 1 (a zero prefill budget cannot make "
                "progress)")
        if econfig.kernel not in ("gather", "pallas"):
            raise ValueError(
                f"kernel must be 'gather' or 'pallas', got "
                f"{econfig.kernel!r}")
        if econfig.speculative and econfig.draft_len < 1:
            raise ValueError(
                f"speculative decoding needs draft_len >= 1, got "
                f"{econfig.draft_len}")
        if econfig.mixed_exec not in ("fused", "split"):
            raise ValueError(
                f"mixed_exec must be 'fused' or 'split', got "
                f"{econfig.mixed_exec!r}")
        self.cfg = cfg
        self.params = params
        self.econfig = econfig
        self.max_model_len = econfig.max_model_len or cfg.max_seq
        bs = econfig.block_size
        self.blocks_per_seq = -(-self.max_model_len // bs)
        n_blocks = econfig.n_blocks or 4 * self.blocks_per_seq + 1
        if n_blocks - 1 < self.blocks_per_seq:
            raise ValueError(
                f"n_blocks={n_blocks} (one reserved for the null block) "
                f"cannot hold one max-length sequence: need "
                f"{self.blocks_per_seq + 1} for max_model_len="
                f"{self.max_model_len} at block_size={bs}")
        # all engine timestamps (arrivals, ttft, latency, trace spans) come
        # from this single injectable clock: no clock-domain mixing, and a
        # fake clock makes every timing-dependent path testable
        self.obs = Observability(econfig.obs, clock=clock)
        self._now = self.obs.now
        self.pool = PagedKVPool(cfg, n_blocks=n_blocks, block_size=bs,
                                dtype=jnp.dtype(econfig.kv_dtype),
                                enable_prefix_cache=econfig.prefix_cache)
        self.scheduler = Scheduler(
            self.pool, max_prefill_batch=econfig.max_prefill_batch,
            max_prefill_tokens=econfig.max_prefill_tokens,
            max_decode_batch=econfig.max_decode_batch,
            chunked_prefill=econfig.chunked_prefill,
            spec_draft_len=econfig.draft_len if econfig.speculative else 0,
            mixed=econfig.fused_step,
            obs=self.obs)
        self._next_id = 0
        # _seqs holds only *live* sequences: finished ones are pruned in
        # _collect_finished (their cached-token tallies fold into counters)
        # so a long-lived engine does not accumulate every request ever
        self._seqs: Dict[int, Sequence] = {}
        self._finished: Deque[RequestOutput] = deque(
            maxlen=max(1, econfig.finished_retention))
        # streaming mean of pool utilization (was an unbounded sample list)
        self._util_sum = 0.0
        self._util_n = 0
        self._start: Optional[float] = None
        self._last_step_wall = 0.0

        # -- metrics registry: the single source of truth for the engine's
        # cumulative counters (stats() and the legacy attribute properties
        # below are views over it); children resolved once, so the per-step
        # cost is a float add
        reg = self.obs.registry
        steps = reg.counter("engine_steps_total",
                            help="engine steps by kind", labels=("kind",))
        self._c_prefill_steps = steps.labels("prefill")
        self._c_decode_steps = steps.labels("decode")
        self._c_spec_rounds = steps.labels("spec")
        self._c_mixed_steps = steps.labels("mixed")
        # role presence per mixed step, so the legacy prefill/decode step
        # views stay meaningful under fused plans (a mixed step with any
        # prefill row counts as a prefill step, etc.)
        mixed_roles = reg.counter(
            "engine_mixed_steps_total",
            help="mixed fused steps containing each row role",
            labels=("role",))
        self._c_mixed_prefill = mixed_roles.labels("prefill")
        self._c_mixed_decode = mixed_roles.labels("decode")
        self._c_mixed_verify = mixed_roles.labels("verify")
        launches = reg.counter(
            "engine_launches_total",
            help="jitted step-function invocations (the fused step's "
                 "reason to exist: fewer of these per engine step)",
            labels=("fn",))
        self._c_launches = {name: launches.labels(name) for name in
                            ("prefill", "decode", "draft", "verify",
                             "mixed", "audit")}
        self._c_prefill_chunks = reg.counter(
            "engine_prefill_chunks_total",
            help="partial prefill windows (prompt continued next step)")
        self._c_prefill_tokens = reg.counter(
            "engine_prefill_tokens_total",
            help="prompt tokens actually computed", unit="tokens")
        self._c_generated = reg.counter(
            "engine_generated_tokens_total", help="tokens emitted",
            unit="tokens")
        self._c_finished = reg.counter(
            "engine_requests_finished_total", help="requests completed")
        cached = reg.counter(
            "engine_cached_tokens_total",
            help="prompt tokens served from cached KV (prefix = "
                 "cross-request hits, resume = own KV after preemption)",
            unit="tokens", labels=("kind",))
        self._c_cached_prefix = cached.labels("prefix")
        self._c_cached_resume = cached.labels("resume")
        spec = reg.counter("engine_spec_tokens_total",
                           help="speculative-decoding token flow",
                           labels=("event",))
        self._c_spec_drafted = spec.labels("drafted")
        self._c_spec_accepted = spec.labels("accepted")
        self._c_spec_emitted = spec.labels("emitted")
        lamp = reg.counter("lamp_kq_products_total",
                           help="KQ products by layer and disposition "
                                "(selected = recomputed in high precision)",
                           labels=("layer", "kind"))
        L = cfg.n_layers
        self._c_lamp_sel = [lamp.labels(str(l), "selected") for l in range(L)]
        self._c_lamp_val = [lamp.labels(str(l), "valid") for l in range(L)]
        vspec = reg.counter("lamp_verify_products_total",
                            help="LAMP counts of speculative verify passes",
                            labels=("kind",))
        self._c_verify_sel = vspec.labels("selected")
        self._c_verify_val = vspec.labels("valid")
        self._h_latency = reg.histogram(
            "engine_request_latency_seconds",
            help="request arrival -> finish", unit="s")
        self._h_ttft = reg.histogram(
            "engine_request_ttft_seconds",
            help="request arrival -> first token", unit="s")
        # -- fault tolerance: recovery actions by kind, failed requests by
        # cause (the fault-injection counter itself lives in FaultInjector)
        self._c_recover_fam = reg.counter(
            "engine_recoveries_total",
            help="recovery actions absorbed without failing the engine "
                 "(retry rungs, alloc deferrals, split fallbacks, stall "
                 "evictions)", labels=("action",))
        self._c_recover: Dict[str, Any] = {}
        self._c_failed_fam = reg.counter(
            "engine_requests_failed_total",
            help="requests individually failed (engine kept serving)",
            labels=("reason",))
        # per-layer accumulators mirrored into the counters above (numpy so
        # the per-step update is one vector add), plus a bounded time series
        # of instantaneous per-layer recompute rates
        self._layer_sel = np.zeros((L,), np.float64)
        self._layer_val = np.zeros((L,), np.float64)
        self.layer_rate_series = deque(maxlen=econfig.obs.series_capacity)

        self.spec_config = (SpecConfig(draft_len=econfig.draft_len)
                            if econfig.speculative else None)

        # -- adaptive policy loop: live per-layer thresholds (always
        # threaded into the jitted steps as a traced operand; without a
        # controller they simply stay at the static site tau, which is
        # bit-identical to the pre-policy engine) and, when enabled, the
        # feedback controller that moves them
        self._taus = np.full((L,), float(cfg.lamp.kq.tau), np.float32)
        self._active_rule: Optional[str] = None
        self._cfg_cache: Dict[str, Any] = {}
        self.policy: Optional[PolicyController] = None
        if econfig.policy.enabled:
            base_rule = cfg.lamp.kq.rule
            if base_rule == "random":   # serving maps the control arm
                base_rule = "strict"
            self.policy = PolicyController(
                econfig.policy, L, self._taus, base_rule=base_rule,
                base_draft_len=(econfig.draft_len if econfig.speculative
                                else 0),
                obs=self.obs)

        # -- shadow audit: realized-error telemetry on a deterministic
        # sample of steps (obs/audit.py). Only meaningful with LAMP on --
        # the audit measures LAMP-vs-reference divergence, which is
        # identically zero without LAMP
        self.auditor: Optional[ShadowAuditor] = None
        if econfig.audit.rate > 0 and econfig.use_lamp:
            self.auditor = ShadowAuditor(econfig.audit, L, self.obs)

        # -- fault tolerance: deterministic injector (None when disabled:
        # zero hot-path cost), the quarantine of rows the health guard
        # pulled out of this step, failures to merge into step() output,
        # and host tallies the watchdog / policy read
        self.faults: Optional[FaultInjector] = (
            FaultInjector(econfig.faults, self.obs)
            if econfig.faults.enabled else None)
        self._quarantine: List[tuple] = []
        self._step_failures: List[RequestOutput] = []
        self._n_failed = 0
        self._n_recoveries = 0
        self._last_alloc_degrades = 0
        self._has_deadlines = False

    # -- legacy counter attributes: views over the metrics registry ----------

    @property
    def prefill_steps(self) -> int:
        # fused mixed steps containing prefill rows count as prefill steps,
        # so the legacy view stays meaningful under fused_step
        return int(self._c_prefill_steps.value
                   + self._c_mixed_prefill.value)

    @property
    def decode_steps(self) -> int:
        # speculative rounds are decode steps too (one round == one step),
        # as are mixed steps containing any decode/verify row
        return int(self._c_decode_steps.value + self._c_spec_rounds.value
                   + self._c_mixed_decode.value)

    @property
    def total_steps(self) -> int:
        # raw step-kind counters: a mixed step counts ONCE even when its
        # rows span roles (the derived views above may both claim it)
        return int(self._c_prefill_steps.value + self._c_decode_steps.value
                   + self._c_spec_rounds.value + self._c_mixed_steps.value)

    @property
    def mixed_steps(self) -> int:
        return int(self._c_mixed_steps.value)

    @property
    def launches(self) -> int:
        """Jitted step-function invocations across all step kinds."""
        return int(sum(c.value for c in self._c_launches.values()))

    @property
    def prefill_chunks(self) -> int:
        return int(self._c_prefill_chunks.value)

    @property
    def prefill_tokens_run(self) -> int:
        return int(self._c_prefill_tokens.value)

    @property
    def generated_tokens(self) -> int:
        return int(self._c_generated.value)

    @property
    def agg_lamp_selected(self) -> float:
        return float(self._layer_sel.sum())

    @property
    def agg_lamp_valid(self) -> float:
        return float(self._layer_val.sum())

    @property
    def spec_rounds(self) -> int:
        # mixed steps that verified drafts are speculative rounds too
        return int(self._c_spec_rounds.value + self._c_mixed_verify.value)

    @property
    def spec_drafted(self) -> int:
        return int(self._c_spec_drafted.value)

    @property
    def spec_accepted(self) -> int:
        return int(self._c_spec_accepted.value)

    @property
    def spec_emitted(self) -> int:
        return int(self._c_spec_emitted.value)

    @property
    def spec_verify_selected(self) -> float:
        return self._c_verify_sel.value

    @property
    def spec_verify_valid(self) -> float:
        return self._c_verify_val.value

    @property
    def compile_events(self):
        return self.obs.compile_events

    # step functions resolve per batch: `use_topk` selects the jit variant
    # with/without the per-row top-k vocab sort (global caches dedupe, so
    # at most two variants compile per step kind)

    def _serving_cfg(self):
        """The model config the next step traces with: the base config,
        unless the policy controller degraded the LAMP rule tier. A rule
        change swaps a *static* trace argument -- one recompile per tier
        per bucket, the deliberate last rung of the degradation ladder
        (tau and draft-length moves are recompile-free)."""
        rule = self._active_rule
        if rule is None or not self.cfg.lamp.kq.enabled:
            return self.cfg
        if rule == self.cfg.lamp.kq.rule:
            return self.cfg
        cfg = self._cfg_cache.get(rule)
        if cfg is None:
            pol = self.cfg.lamp
            cfg = self.cfg.replace(
                lamp=pol.replace(kq=pol.kq.replace(rule=rule)))
            self._cfg_cache[rule] = cfg
        return cfg

    def _step_fns(self, seqs: List[Sequence]):
        use_topk = any(s.sampling.top_k > 0 for s in seqs)
        return _jitted_steps(self._serving_cfg(), self.econfig.use_lamp,
                             self.econfig.kernel, use_topk)

    def _spec_fns(self, seqs: List[Sequence]):
        use_topk = any(s.sampling.top_k > 0 for s in seqs)
        return spec_step_fns(self._serving_cfg(), self.econfig.use_lamp,
                             self.econfig.kernel, self.spec_config,
                             use_topk)

    # -- request intake -----------------------------------------------------

    def add_request(self, prompt: List[int],
                    sampling: SamplingParams = SamplingParams(),
                    arrival_time: Optional[float] = None) -> int:
        if not prompt:
            raise ValueError("empty prompt")
        if sampling.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {sampling.max_new_tokens}")
        if len(prompt) + sampling.max_new_tokens > self.max_model_len:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new_tokens"
                f"({sampling.max_new_tokens}) exceeds max_model_len "
                f"{self.max_model_len}")
        if self.econfig.max_queue and \
                len(self.scheduler.waiting) >= self.econfig.max_queue:
            self._c_failed_fam.labels("queue_full").inc()
            if self.obs.tracer.enabled:
                self.obs.tracer.instant("reject", cat="fault",
                                        reason="queue_full")
            raise QueueFullError(
                f"admission queue full ({self.econfig.max_queue} waiting); "
                f"retry later or raise EngineConfig.max_queue")
        req_id = self._next_id
        self._next_id += 1
        seq = Sequence(req_id, prompt, sampling,
                       arrival_time if arrival_time is not None
                       else self._now())
        if sampling.deadline_s > 0:
            self._has_deadlines = True
        self._seqs[req_id] = seq
        self.scheduler.add(seq)
        return req_id

    def has_unfinished(self) -> bool:
        return self.scheduler.has_work()

    # -- the step loop ------------------------------------------------------

    def step(self) -> List[RequestOutput]:
        """Run one engine step; returns requests finished by this step
        (successfully or -- with `RequestOutput.error` set -- failed)."""
        if self._start is None:
            self._start = self._now()
        t0 = self._now()
        step_id = self.total_steps
        inj = self.faults
        if inj is not None and inj.maybe_stall(step_id):
            # injected stall: the step schedules nothing and reports the
            # configured latency spike, so the policy sees the pressure and
            # the run_to_completion watchdog sees no progress
            self._last_step_wall = inj.config.stall_s
            if self.policy is not None:
                self._policy_update()
            return self._drain_failures()
        if self._has_deadlines:
            self._expire_deadlines()
        if inj is not None and inj.fires(step_id, "alloc"):
            self.pool.arm_alloc_failure(1)
            inj.record(step_id, "alloc")
        with self.obs.span("schedule"):
            plan = self.scheduler.schedule()
        d_alloc = (self.scheduler.alloc_fault_degrades
                   - self._last_alloc_degrades)
        if d_alloc:
            self._last_alloc_degrades = self.scheduler.alloc_fault_degrades
            self._recover("alloc_defer", n=d_alloc)
        if plan is None:
            return self._drain_failures()
        # audit rows are *captured* before the sub-step runs (it mutates
        # cursors, tokens and -- via rollback -- block tables) and *executed*
        # after it, against the post-step arena: the audited window rewrites
        # its own KV inside the shadow launch, and the prefix below `starts`
        # is identical before and after the step
        audit_batch = (self._audit_capture(plan)
                       if self.auditor is not None else None)
        if plan.kind == "prefill":
            self._step_prefill(plan.seqs, plan.windows)
            self._c_prefill_steps.inc()
        elif plan.kind == "mixed":
            if self.econfig.mixed_exec == "split":
                self._step_mixed_split(plan)
            else:
                try:
                    if inj is not None and inj.fires(step_id, "step"):
                        inj.record(step_id, "step")
                        raise StepLaunchFault(
                            "injected fused-step launch failure")
                    self._step_mixed(plan)
                except StepLaunchFault:
                    # fused-step anomaly: degrade this step to the split
                    # twin -- same plan, same tokens, two/three launches.
                    # Only the *injected* fault type is caught (it is
                    # raised before any launch, so no bookkeeping or
                    # donated-arena state has moved); real exceptions stay
                    # loud rather than risk re-running a half-applied step
                    self._recover("split_fallback")
                    self._step_mixed_split(plan)
            self._c_mixed_steps.inc()
            roles = plan.roles or []
            if any(r == "prefill" for r in roles):
                self._c_mixed_prefill.inc()
            if any(r != "prefill" for r in roles):
                self._c_mixed_decode.inc()
            if self.econfig.speculative and any(plan.draft_lens):
                self._c_mixed_verify.inc()
        elif self.econfig.speculative and any(plan.draft_lens):
            self._step_spec(plan.seqs, plan.draft_lens)
            self._c_spec_rounds.inc()
        else:
            # no draft budget anywhere (spec off, block pressure shed it,
            # or every sequence is at its token limit): the plain decode
            # step is the same progress at a fraction of the compute
            self._step_decode(plan.seqs)
            self._c_decode_steps.inc()
        if self._quarantine:
            # rows the health guard pulled out of the sub-step: retry each
            # through the recovery ladder (or fail it alone), then prove
            # the pool survived the surgery
            with self.obs.span("recover", rows=len(self._quarantine)):
                self._drain_quarantine()
            self.pool.check_invariants(self._seqs.values())
        self._util_sum += self.pool.utilization
        self._util_n += 1
        if audit_batch is not None:
            # before _collect_finished, so a request audited on its
            # finishing step still folds into its cumulative histogram
            self._run_audit(audit_batch)
        with self.obs.span("emit"):
            done = self._collect_finished(plan.seqs)
        done.extend(self._drain_failures())
        if self.econfig.paranoid:
            self.pool.check_invariants(self._seqs.values())
        self._last_step_wall = self._now() - t0
        if self.policy is not None:
            self._policy_update()
        return done

    def _policy_update(self) -> None:
        """Feed this step's telemetry to the controller and apply what it
        actuated: per-layer thresholds (traced operands, free), the
        scheduler's draft budget (host int, free), and -- only under SHED
        -- the LAMP rule tier (a static swap; recompiles once per tier)."""
        # _account_lamp stamps entries with the step count *before* the
        # step counter increments (the inc happens after the sub-step
        # returns), so the entry this step just produced reads
        # total_steps - 1; anything older means this step had no LAMP
        # counts (e.g. use_lamp off) and the controller holds its EMA
        rates = None
        if (self.layer_rate_series
                and self.layer_rate_series[-1][0] == self.total_steps - 1):
            rates = self.layer_rate_series[-1][1]
        drafted = self.spec_drafted
        sig = PolicySignals(
            layer_rates=rates,
            utilization=self.pool.utilization,
            preemptions=self.scheduler.num_preemptions,
            step_latency_s=self._last_step_wall,
            spec_acceptance=(self.spec_accepted / drafted
                            if drafted else 0.0),
            recoveries=self._n_recoveries)
        act = self.policy.update(sig)
        if self.policy.config.frozen:
            return
        self._taus = np.asarray(act.taus, np.float32)
        self._active_rule = act.rule
        if self.econfig.speculative:
            self.scheduler.spec_draft_len = act.draft_len

    # -- shadow audit -------------------------------------------------------

    def _audit_capture(self, plan: StepPlan) -> Optional[Dict[str, Any]]:
        """Select and snapshot this step's audited rows (or None).

        Row selection hashes (step, request, salt) -- replayable across
        runs of the same stream. Every input the shadow launch needs is
        copied *now*: the sub-step advances prefill cursors, appends
        tokens, and rolls back block tables before the audit executes.
        Decode and speculative rows are audited as their width-1 pre-draft
        decode window (same query the serving step's first position ran);
        prefill rows replay their whole chunk window."""
        step_id = self.total_steps
        seqs = plan.seqs
        idx = self.auditor.select(step_id, [s.req_id for s in seqs])
        if not idx:
            return None
        roles = list(plan.roles or [None] * len(seqs))
        rows: List[Any] = []
        for i in idx:
            seq = seqs[i]
            if plan.kind == "prefill" or roles[i] == "prefill":
                w = plan.windows[i]
                cur = seq.prefill_cursor
                toks = list(seq.prefill_tokens()[cur:cur + w])
                start = cur
            else:
                toks = [seq.last_token]
                start = seq.cache_len
            rows.append((seq, start, toks))
        Bb = _bucket(len(rows), 0)
        Wb = _bucket(max(len(t) for _, _, t in rows), 0)
        tokens = np.zeros((Bb, Wb), np.int32)
        starts = np.zeros((Bb,), np.int32)
        lengths = np.ones((Bb,), np.int32)   # pad rows: 1 token, null table
        row_mask = np.zeros((Bb,), np.float32)
        bt = np.zeros((Bb, self.blocks_per_seq), np.int32)
        for j, (seq, start, toks) in enumerate(rows):
            tokens[j, :len(toks)] = toks
            starts[j] = start
            lengths[j] = len(toks)
            row_mask[j] = 1.0
            bt[j, :len(seq.block_ids)] = seq.block_ids
        return {"step": step_id, "seqs": [r[0] for r in rows],
                "tokens": tokens, "starts": starts, "lengths": lengths,
                "row_mask": row_mask, "bt": bt, "bucket": (Bb, Wb)}

    def _run_audit(self, batch: Dict[str, Any]) -> None:
        """Execute one captured audit batch as a single extra jitted launch
        (non-donated arena: the pool buffers -- and therefore every served
        token -- are untouched), then fold the returned error metrics into
        the auditor and, when a live policy controller is attached, run the
        error-model calibration pass."""
        Bb, Wb = batch["bucket"]
        fn = _audit_step_fn(self._serving_cfg(), self.econfig.audit.top_k)
        n0 = _cache_size(fn)
        with self.obs.span("audit", rows=len(batch["seqs"]),
                           bucket=[Bb, Wb]) as sp:
            out = fn(self.params, self.pool.k, self.pool.v,
                     jnp.asarray(batch["tokens"]), jnp.asarray(batch["bt"]),
                     jnp.asarray(batch["starts"]),
                     jnp.asarray(batch["lengths"]),
                     jnp.asarray(batch["row_mask"]),
                     jnp.asarray(self._taus))
            jax.block_until_ready(out)
        self._c_launches["audit"].inc()
        if n0 >= 0 and _cache_size(fn) > n0:
            self.obs.record_compile("audit", (Bb, Wb), sp.elapsed,
                                    self.total_steps)
        metrics = {k: np.asarray(v) for k, v in out.items()}
        self.auditor.account(batch["step"], batch["seqs"], metrics)
        if self.policy is not None and not self.policy.config.frozen:
            self.auditor.maybe_calibrate(self.policy)

    def _batch_arrays(self, seqs: List[Sequence], Bb: int):
        bt = np.zeros((Bb, self.blocks_per_seq), np.int32)
        seeds = np.zeros((Bb,), np.int32)
        counts = np.zeros((Bb,), np.int32)
        temps = np.zeros((Bb,), np.float32)
        topks = np.zeros((Bb,), np.int32)
        for i, seq in enumerate(seqs):
            bt[i, :len(seq.block_ids)] = seq.block_ids
            seeds[i] = seq.sampling.seed
            counts[i] = seq.num_generated
            temps[i] = seq.sampling.temperature
            topks[i] = seq.sampling.top_k
        return bt, seeds, counts, temps, topks

    def _account_lamp(self, seqs: List[Sequence], nsel: np.ndarray,
                      nval: np.ndarray, *, verify: bool = False,
                      verify_cols: Optional[List[int]] = None
                      ) -> None:
        """Fold one step's per-layer (L, B) LAMP counts into the per-layer
        counters, the recompute-rate time series, and each sequence's
        per-layer breakdown. `verify=True` credits the whole batch to the
        verify counters (a pure spec round); `verify_cols` credits only
        those columns (a fused mixed step whose decode rows verified while
        its prefill rows did not)."""
        sel_l = nsel.sum(axis=1)
        val_l = nval.sum(axis=1)
        self._layer_sel += sel_l
        self._layer_val += val_l
        for l in range(len(sel_l)):
            self._c_lamp_sel[l].inc(float(sel_l[l]))
            self._c_lamp_val[l].inc(float(val_l[l]))
        if verify:
            self._c_verify_sel.inc(float(sel_l.sum()))
            self._c_verify_val.inc(float(val_l.sum()))
        elif verify_cols:
            self._c_verify_sel.inc(float(nsel[:, verify_cols].sum()))
            self._c_verify_val.inc(float(nval[:, verify_cols].sum()))
        if val_l.sum() > 0:
            rates = np.divide(sel_l, val_l, out=np.zeros_like(sel_l),
                              where=val_l > 0)
            self.layer_rate_series.append((self.total_steps, rates))
            if self.obs.tracer.enabled:
                self.obs.tracer.counter(
                    "lamp_recompute_rate",
                    **{f"layer{l}": round(float(r), 6)
                       for l, r in enumerate(rates)})
        for i, seq in enumerate(seqs):
            seq.lamp.add_layers(nsel[:, i], nval[:, i])

    # -- fault tolerance ----------------------------------------------------

    def _recover(self, action: str, n: int = 1, **detail) -> None:
        """Account one absorbed recovery action (metric + trace + the host
        tally the policy ladder and stats() read)."""
        c = self._c_recover.get(action)
        if c is None:
            c = self._c_recover[action] = self._c_recover_fam.labels(action)
        c.inc(n)
        self._n_recoveries += n
        if self.obs.tracer.enabled:
            self.obs.tracer.instant(f"recover:{action}", cat="fault",
                                    **detail)

    def _unhealthy(self, h: float) -> bool:
        h = float(h)
        if not np.isfinite(h):
            return True
        cap = self.econfig.health_max_abs
        return cap > 0 and h > cap

    def _inject_nan(self, seqs: List[Sequence], health: np.ndarray,
                    spans: List[tuple]) -> np.ndarray:
        """Fault site "nan": poison one deterministic victim row -- its
        health value goes NaN and the arena KV positions it wrote this step
        (`spans[row]` = (start, width), exactly what its recovery retry
        rewrites) are overwritten with NaN. With the guard off, the
        corruption propagates like a real kernel fault would."""
        inj = self.faults
        step_id = self.total_steps
        if inj is None or not inj.fires(step_id, "nan"):
            return health
        row = inj.pick_row(step_id, "nan", [s.req_id for s in seqs])
        if row is None:
            return health
        seq = seqs[row]
        start, width = spans[row]
        bs = self.pool.block_size
        pos = [p for p in range(start, start + width)
               if p // bs < len(seq.block_ids)]
        if pos:
            blocks = jnp.asarray([seq.block_ids[p // bs] for p in pos])
            offs = jnp.asarray([p % bs for p in pos])
            self.pool.k = self.pool.k.at[:, blocks, offs].set(jnp.nan)
            self.pool.v = self.pool.v.at[:, blocks, offs].set(jnp.nan)
        health = np.array(health, np.float64)
        health[row] = np.nan
        inj.record(step_id, "nan", req=seq.req_id, start=start, width=width)
        return health

    def _inject_draft(self, dseqs: List[Sequence], kdv, d_toks, vocab: int):
        """Fault site "draft": corrupt one drafting row's proposals (each
        token bumped mod vocab). No dedicated recovery: the verify pass IS
        the recovery -- corrupted proposals disagree with the verifier and
        are rejected (greedy streams stay token-identical; a sampled
        stream's accept coin may keep a corrupt but plausible token, which
        is exactly the corruption-tolerance boundary this site probes)."""
        inj = self.faults
        step_id = self.total_steps
        if inj is None or not inj.fires(step_id, "draft"):
            return d_toks
        rows = [j for j in range(len(dseqs)) if int(kdv[j]) > 0]
        if not rows:
            return d_toks
        pick = inj.pick_row(step_id, "draft",
                            [dseqs[j].req_id for j in rows])
        j = rows[pick]
        d_toks = d_toks.at[j].set((d_toks[j] + 1) % jnp.int32(vocab))
        inj.record(step_id, "draft", req=dseqs[j].req_id)
        return d_toks

    def _retry_ladder(self) -> List[tuple]:
        """(action, cfg, use_lamp, kernel) escalation rungs for retrying a
        quarantined row, cheapest first: (0) plain re-run of the step's own
        configuration -- transient faults (and every injected one) recover
        here bit-identically, because sampling is keyed on
        (seed, num_generated), not on wall time or batch shape; (1) the
        strict LAMP rule -- maximal selective recompute; (2) the gather
        reference kernel -- rules out the fused Pallas path; (3) FP32
        reference -- no LAMP at all. Bounded by EngineConfig.max_retries."""
        e = self.econfig
        ladder = [("retry", self._serving_cfg(), e.use_lamp, e.kernel)]
        if e.use_lamp and self.cfg.lamp.kq.enabled \
                and self.cfg.lamp.kq.rule != "strict":
            pol = self.cfg.lamp
            strict = self.cfg.replace(
                lamp=pol.replace(kq=pol.kq.replace(rule="strict")))
            ladder.append(("strict", strict, True, e.kernel))
        if e.kernel != "gather":
            _, pcfg, plamp, _ = ladder[-1]
            ladder.append(("gather", pcfg, plamp, "gather"))
        ladder.append(("fp32", self.cfg, False, "gather"))
        return ladder[:max(1, e.max_retries)]

    def _retry_row(self, seq: Sequence, kind: str, window: int,
                   rcfg, rlamp: bool, rkernel: str) -> bool:
        """Re-run one quarantined row's window as a single-row prefill
        launch (decode is a width-1 window) under a ladder rung's
        configuration. Healthy result: apply the normal bookkeeping the
        quarantine skipped and return True; still unhealthy: leave the
        sequence untouched for the next rung."""
        prefill_fn, _ = _jitted_steps(rcfg, rlamp, rkernel,
                                      seq.sampling.top_k > 0)
        Wb = _bucket(window, 0)
        tokens = np.zeros((1, Wb), np.int32)
        if kind == "prefill":
            start = seq.prefill_cursor
            tokens[0, :window] = \
                seq.prefill_tokens()[start:start + window]
        else:
            start = seq.cache_len
            tokens[0, 0] = seq.last_token
        bt, seeds, counts, temps, topks = self._batch_arrays([seq], 1)
        with self.obs.span("retry", req=seq.req_id, kind=kind,
                           window=window):
            out = prefill_fn(
                self.params, self.pool.k, self.pool.v, jnp.asarray(tokens),
                jnp.asarray(bt), jnp.asarray(np.asarray([start], np.int32)),
                jnp.asarray(np.asarray([window], np.int32)),
                jnp.asarray(self._taus), jnp.asarray(seeds),
                jnp.asarray(counts), jnp.asarray(temps),
                jnp.asarray(topks))
            jax.block_until_ready(out)
            nxt, health, self.pool.k, self.pool.v, nsel, nval = out
        self._c_launches["prefill"].inc()
        if self._unhealthy(np.asarray(health)[0]):
            return False
        now = self._now()
        self._account_lamp([seq], np.asarray(nsel), np.asarray(nval))
        if kind == "prefill":
            seq.prefill_cursor += window
            seq.cache_len = seq.prefill_cursor
            self._c_prefill_tokens.inc(window)
            if self.econfig.prefix_cache:
                self.pool.register_prefix(seq.prefill_tokens(),
                                          seq.block_ids, seq.cache_len,
                                          hashes=seq.prefix_hashes)
            if seq.prefill_remaining == 0:
                seq.status = SequenceStatus.DECODE
                seq.on_token(int(np.asarray(nxt)[0]), now)
                self._c_generated.inc()
            else:
                self._c_prefill_chunks.inc()
        else:
            seq.cache_len += 1
            seq.on_token(int(np.asarray(nxt)[0]), now)
            self._c_generated.inc()
        return True

    def _drain_quarantine(self) -> None:
        """Walk every row the health guard quarantined this step through
        the recovery ladder; a row no rung can produce healthy logits for
        fails alone (diagnostic RequestOutput.error), never the engine."""
        q, self._quarantine = self._quarantine, []
        ladder = self._retry_ladder()
        for seq, kind, window in q:
            recovered = False
            for action, rcfg, rlamp, rkernel in ladder:
                if self._retry_row(seq, kind, window, rcfg, rlamp, rkernel):
                    self._recover(action, req=seq.req_id)
                    recovered = True
                    break
            if not recovered:
                self._fail_seq(
                    seq, "unhealthy",
                    f"non-finite or out-of-range logits persisted through "
                    f"{len(ladder)} recovery rung(s) "
                    f"[{'/'.join(a for a, *_ in ladder)}] at {kind} "
                    f"window={window} cache_len={seq.cache_len}")

    def _fail_seq(self, seq: Sequence, reason: str, error: str) -> None:
        """Terminal per-request failure: cancel it wherever it sits, free
        its blocks, and emit a diagnostic RequestOutput. The engine --
        and every other request -- keeps serving."""
        now = self._now()
        seq.finish(reason, now)
        self.scheduler.cancel(seq)
        self._c_failed_fam.labels(reason).inc()
        if self.obs.tracer.enabled:
            self.obs.tracer.instant("request_failed", cat="fault",
                                    req=seq.req_id, reason=reason)
        out = RequestOutput(
            req_id=seq.req_id, prompt=seq.prompt, tokens=seq.generated,
            finish_reason=reason, latency=seq.latency() or 0.0,
            ttft=seq.ttft() or 0.0,
            num_preemptions=seq.num_preemptions,
            lamp_selected=seq.lamp.selected, lamp_valid=seq.lamp.valid,
            num_cached_tokens=seq.num_cached_tokens,
            num_resume_cached_tokens=seq.num_resume_cached_tokens,
            spec_drafted=seq.spec_drafted,
            spec_accepted=seq.spec_accepted,
            audit_samples=seq.audit_samples,
            audit_err_sum=seq.audit_err_sum,
            audit_flips=seq.audit_flips,
            error=error)
        self._finished.append(out)
        self._n_failed += 1
        self._seqs.pop(seq.req_id, None)
        self._step_failures.append(out)

    def _drain_failures(self) -> List[RequestOutput]:
        if not self._step_failures:
            return []
        out, self._step_failures = self._step_failures, []
        return out

    def _expire_deadlines(self) -> None:
        """Cancel requests whose wall-clock TTL elapsed (blocks released,
        finish_reason "timeout"); runs before scheduling so an expired
        request never costs another step of compute."""
        now = self._now()
        for seq in [s for s in self._seqs.values()
                    if s.sampling.deadline_s > 0 and not s.is_finished
                    and now - s.arrival_time > s.sampling.deadline_s]:
            self._fail_seq(
                seq, "timeout",
                f"deadline_s={seq.sampling.deadline_s} exceeded after "
                f"{now - seq.arrival_time:.3f}s "
                f"({seq.num_generated} tokens generated)")

    def _stall_recover(self) -> bool:
        """The watchdog's recovery attempt after `stall_patience` steps
        without progress. Cheapest plausible fix first: clear an injected
        stall; else evict every running row (recompute-style, so resumed
        token streams are identical); else fail the oldest waiting request.
        Returns False when nothing changed -- the caller then raises."""
        if self.faults is not None and self.faults.stalled:
            self.faults.clear_stall()
            self._recover("stall_clear")
            return True
        acted = False
        evicted = 0
        while self.scheduler._preempt_youngest():
            evicted += 1
        if evicted:
            self._recover("stall_evict", n=evicted)
            acted = True
        elif self.scheduler.waiting:
            self._fail_seq(
                self.scheduler.waiting[0], "stalled",
                f"no step progress for {self.econfig.stall_patience} "
                f"steps with the request still queued")
            acted = True
        self.pool.check_invariants(self._seqs.values())
        return acted

    def _step_prefill(self, seqs: List[Sequence],
                      windows: List[int]) -> None:
        """Run one prefill window per sequence: the whole remaining prompt,
        or a `max_prefill_tokens`-bounded chunk of it. A sequence whose
        window completes its prompt samples its first token and moves to
        DECODE; otherwise it stays PREFILL with its cursor advanced."""
        Wb = _bucket(max(windows), 0)
        Bb = _bucket(len(seqs), self.econfig.max_prefill_batch)
        tokens = np.zeros((Bb, Wb), np.int32)
        starts = np.zeros((Bb,), np.int32)
        lengths = np.ones((Bb,), np.int32)   # pad rows: 1 token in null block
        for i, (seq, w) in enumerate(zip(seqs, windows)):
            cur = seq.prefill_cursor
            tokens[i, :w] = seq.prefill_tokens()[cur:cur + w]
            starts[i] = cur
            lengths[i] = w
        bt, seeds, counts, temps, topks = self._batch_arrays(seqs, Bb)
        prefill_fn, _ = self._step_fns(seqs)
        n0 = _cache_size(prefill_fn)
        with self.obs.span("prefill", rows=len(seqs), bucket=[Bb, Wb],
                           tokens=int(sum(windows))) as sp:
            out = prefill_fn(
                self.params, self.pool.k, self.pool.v, jnp.asarray(tokens),
                jnp.asarray(bt), jnp.asarray(starts), jnp.asarray(lengths),
                jnp.asarray(self._taus), jnp.asarray(seeds),
                jnp.asarray(counts), jnp.asarray(temps), jnp.asarray(topks))
        self._c_launches["prefill"].inc()
        with self.obs.span("sync"):
            jax.block_until_ready(out)
            nxt, health, self.pool.k, self.pool.v, nsel, nval = out
            nxt, health, nsel, nval = (np.asarray(nxt), np.asarray(health),
                                       np.asarray(nsel), np.asarray(nval))
        if n0 >= 0 and _cache_size(prefill_fn) > n0:
            self.obs.record_compile("prefill", (Bb, Wb), sp.elapsed,
                                    self.total_steps)
        health = self._inject_nan(
            seqs, health,
            [(s.prefill_cursor, w) for s, w in zip(seqs, windows)])
        guard = self.econfig.health_guard
        now = self._now()
        self._account_lamp(seqs, nsel, nval)
        for i, (seq, w) in enumerate(zip(seqs, windows)):
            if guard and self._unhealthy(health[i]):
                # skip ALL bookkeeping: cursor stays, the retry rewrites
                # the same window over the same (possibly poisoned) blocks
                self._quarantine.append((seq, "prefill", w))
                continue
            seq.prefill_cursor += w
            seq.cache_len = seq.prefill_cursor
            self._c_prefill_tokens.inc(w)
            if self.econfig.prefix_cache:
                # the window's full blocks now hold real KV: make them
                # matchable by later arrivals (and by our own resume); the
                # admission-time chain hashes avoid rehashing per chunk
                self.pool.register_prefix(seq.prefill_tokens(),
                                          seq.block_ids, seq.cache_len,
                                          hashes=seq.prefix_hashes)
            if seq.prefill_remaining == 0:
                seq.status = SequenceStatus.DECODE
                seq.on_token(int(nxt[i]), now)
                self._c_generated.inc()
            else:
                self._c_prefill_chunks.inc()

    def _step_decode(self, seqs: List[Sequence]) -> None:
        Rb = _bucket(len(seqs), self.econfig.max_decode_batch)
        tokens = np.zeros((Rb, 1), np.int32)
        lengths = np.zeros((Rb,), np.int32)  # pad rows write into null block
        for i, seq in enumerate(seqs):
            tokens[i, 0] = seq.last_token
            lengths[i] = seq.cache_len
        bt, seeds, counts, temps, topks = self._batch_arrays(seqs, Rb)
        _, decode_fn = self._step_fns(seqs)
        n0 = _cache_size(decode_fn)
        with self.obs.span("decode", rows=len(seqs), bucket=[Rb]) as sp:
            out = decode_fn(
                self.params, self.pool.k, self.pool.v, jnp.asarray(bt),
                jnp.asarray(lengths), jnp.asarray(tokens),
                jnp.asarray(self._taus), jnp.asarray(seeds),
                jnp.asarray(counts), jnp.asarray(temps), jnp.asarray(topks))
        self._c_launches["decode"].inc()
        with self.obs.span("sync"):
            jax.block_until_ready(out)
            nxt, health, self.pool.k, self.pool.v, nsel, nval = out
            nxt, health, nsel, nval = (np.asarray(nxt), np.asarray(health),
                                       np.asarray(nsel), np.asarray(nval))
        if n0 >= 0 and _cache_size(decode_fn) > n0:
            self.obs.record_compile("decode", (Rb,), sp.elapsed,
                                    self.total_steps)
        health = self._inject_nan(seqs, health,
                                  [(s.cache_len, 1) for s in seqs])
        guard = self.econfig.health_guard
        now = self._now()
        self._account_lamp(seqs, nsel, nval)
        for i, seq in enumerate(seqs):
            if guard and self._unhealthy(health[i]):
                self._quarantine.append((seq, "decode", 1))
                continue
            seq.cache_len += 1
            seq.on_token(int(nxt[i]), now)
            self._c_generated.inc()

    def _step_spec(self, seqs: List[Sequence],
                   draft_lens: List[int]) -> None:
        """One speculative round over the decode batch: draft up to
        `draft_lens[i]` tokens per sequence with the low-precision
        self-draft, verify every drafted position (plus the bonus slot) in
        one multi-token LAMP forward, emit the accepted prefix + one
        verifier token, and roll back the blocks that held rejected draft
        KV. A sequence with draft budget 0 runs a verify-only round, which
        is exactly one plain decode step's progress."""
        Rb = _bucket(len(seqs), self.econfig.max_decode_batch)
        tok0 = np.zeros((Rb,), np.int32)
        lengths = np.zeros((Rb,), np.int32)  # pad rows write into null block
        kd = np.zeros((Rb,), np.int32)
        for i, seq in enumerate(seqs):
            tok0[i] = seq.last_token
            lengths[i] = seq.cache_len
            kd[i] = draft_lens[i]
        bt, seeds, counts, temps, topks = self._batch_arrays(seqs, Rb)
        bt, lengths, tok0, kd, seeds, counts, temps, topks = map(
            jnp.asarray, (bt, lengths, tok0, kd, seeds, counts, temps,
                          topks))
        taus = jnp.asarray(self._taus)
        draft_fn, verify_fn = self._spec_fns(seqs)
        n0d, n0v = _cache_size(draft_fn), _cache_size(verify_fn)
        with self.obs.span("draft", rows=len(seqs), bucket=[Rb]) as spd:
            d_toks, d_logits, self.pool.k, self.pool.v = draft_fn(
                self.params, self.pool.k, self.pool.v, bt, lengths, tok0,
                kd, taus, seeds, counts, temps, topks)
        self._c_launches["draft"].inc()
        d_toks = self._inject_draft(seqs, draft_lens, d_toks,
                                    d_logits.shape[-1])
        with self.obs.span("verify", rows=len(seqs), bucket=[Rb]) as spv:
            out = verify_fn(
                self.params, self.pool.k, self.pool.v, tok0, d_toks,
                d_logits, bt, lengths, kd, taus, seeds, counts, temps,
                topks)
        self._c_launches["verify"].inc()
        with self.obs.span("sync"):
            jax.block_until_ready(out)
            emit, n_acc, health, self.pool.k, self.pool.v, nsel, nval = out
            emit, n_acc, health, nsel, nval = (
                np.asarray(emit), np.asarray(n_acc), np.asarray(health),
                np.asarray(nsel), np.asarray(nval))
        if n0d >= 0 and _cache_size(draft_fn) > n0d:
            self.obs.record_compile("draft", (Rb,), spd.elapsed,
                                    self.total_steps)
        if n0v >= 0 and _cache_size(verify_fn) > n0v:
            self.obs.record_compile("verify", (Rb,), spv.elapsed,
                                    self.total_steps)
        # poison width 1 even for drafted rows: the quarantine retries a
        # verify row as a plain decode, which rewrites only position
        # cache_len -- poison past it would outlive the recovery (the
        # rolled-back draft positions can share the kept tail block, and
        # the gather kernel streams the whole block span)
        health = self._inject_nan(seqs, health,
                                  [(s.cache_len, 1) for s in seqs])
        guard = self.econfig.health_guard
        now = self._now()
        self._account_lamp(seqs, nsel, nval, verify=True)
        for i, seq in enumerate(seqs):
            if guard and self._unhealthy(health[i]):
                # discard the whole round for this row (no drafted/accepted
                # accounting), free the draft-span blocks -- keeping one
                # slot past cache_len so the width-1 retry's write position
                # stays covered -- and retry as a plain decode step
                seq.block_ids = self.pool.rollback(seq.block_ids,
                                                   seq.cache_len + 1)
                self._quarantine.append((seq, "decode", 1))
                continue
            a = int(n_acc[i])
            seq.spec_drafted += int(draft_lens[i])
            self._c_spec_drafted.inc(int(draft_lens[i]))
            # emit accepted drafts + the verifier's token, stopping at the
            # request's own limits (surplus accepted tokens are dropped and
            # their cache rolls back with the rejected ones)
            appended = 0
            for t in emit[i, :a + 1]:
                seq.on_token(int(t), now)
                appended += 1
                self._c_generated.inc()
                if seq.should_stop():
                    break
            # acceptance accounting covers only drafts actually *kept*: a
            # stop token (or token limit) inside the accepted prefix drops
            # the surplus, and counting those would overstate the
            # acceptance rate the policy/scheduler steer by. An early stop
            # at position j < a keeps j+1 tokens, all of them drafts; a
            # full emit keeps a drafts + the verifier's token.
            kept_accepted = min(a, appended)
            seq.spec_accepted += kept_accepted
            self._c_spec_accepted.inc(kept_accepted)
            seq.cache_len += appended
            self._c_spec_emitted.inc(appended)
            seq.block_ids = self.pool.rollback(seq.block_ids, seq.cache_len)

    def _step_mixed(self, plan: StepPlan) -> None:
        """Run one mixed plan as a single fused launch: prefill windows,
        plain decode rows (width-1 windows at start = cache_len) and
        speculative verify rows (width kd+1 windows) share one bucketed
        (rows, max_window) batch through `transformer.paged_mixed_step`.
        Per-row (start, qlen) metadata is scalar-prefetched into the paged
        attention grid, so every role mix reuses the same compiled bucket.

        Plans without draft rows reuse the prefill step function verbatim
        (a mixed no-draft plan IS a prefill-window batch): one launch.
        Plans with drafts run the sequential draft scan over the decode
        rows' compact bucket first, then one mixed launch that verifies,
        samples, and accepts for every role at once: two launches, versus
        the split path's three (prefill + draft + verify)."""
        seqs, windows = plan.seqs, list(plan.windows)
        roles = list(plan.roles or ["decode"] * len(seqs))
        draft_lens = list(plan.draft_lens)
        spec_round = self.econfig.speculative and any(draft_lens)
        dec_rows = [i for i, r in enumerate(roles) if r != "prefill"]
        cap = (self.econfig.max_prefill_batch
               + self.econfig.max_decode_batch)
        Bb = _bucket(len(seqs), cap)
        Wb = _bucket(max(windows), 0)
        if spec_round:
            # the accept rule reads k+1 window positions per verify row
            Wb = max(Wb, self.spec_config.verify_width)
        tokens = np.zeros((Bb, Wb), np.int32)
        starts = np.zeros((Bb,), np.int32)
        qlens = np.ones((Bb,), np.int32)   # pad rows: 1 token in null block
        for i, seq in enumerate(seqs):
            w = windows[i]
            if roles[i] == "prefill":
                cur = seq.prefill_cursor
                tokens[i, :w] = seq.prefill_tokens()[cur:cur + w]
                starts[i] = cur
            else:
                # decode/verify: the window is [last_token, drafts...] at
                # the decode tail (drafts scatter in-jit after the scan)
                tokens[i, 0] = seq.last_token
                starts[i] = seq.cache_len
            qlens[i] = w
        bt, seeds, counts, temps, topks = self._batch_arrays(seqs, Bb)
        taus = jnp.asarray(self._taus)
        emit = n_acc = None
        if not spec_round:
            mixed_fn, _ = self._step_fns(seqs)
            n0 = _cache_size(mixed_fn)
            with self.obs.span("mixed", rows=len(seqs), bucket=[Bb, Wb],
                               tokens=int(sum(windows))) as sp:
                out = mixed_fn(
                    self.params, self.pool.k, self.pool.v,
                    jnp.asarray(tokens), jnp.asarray(bt),
                    jnp.asarray(starts), jnp.asarray(qlens), taus,
                    jnp.asarray(seeds), jnp.asarray(counts),
                    jnp.asarray(temps), jnp.asarray(topks))
            self._c_launches["mixed"].inc()
            with self.obs.span("sync"):
                jax.block_until_ready(out)
                nxt, health, self.pool.k, self.pool.v, nsel, nval = out
                nxt, health, nsel, nval = (
                    np.asarray(nxt), np.asarray(health), np.asarray(nsel),
                    np.asarray(nval))
        else:
            dseqs = [seqs[i] for i in dec_rows]
            Rb = _bucket(len(dseqs), self.econfig.max_decode_batch)
            tok0 = np.zeros((Rb,), np.int32)
            dlens = np.zeros((Rb,), np.int32)
            kdv = np.zeros((Rb,), np.int32)
            for j, i in enumerate(dec_rows):
                tok0[j] = seqs[i].last_token
                dlens[j] = seqs[i].cache_len
                kdv[j] = draft_lens[i]
            dbt, dseeds, dcounts, dtemps, dtopks = self._batch_arrays(
                dseqs, Rb)
            draft_fn, _ = self._spec_fns(dseqs)
            n0d = _cache_size(draft_fn)
            with self.obs.span("draft", rows=len(dseqs),
                               bucket=[Rb]) as spd:
                d_toks, d_logits, self.pool.k, self.pool.v = draft_fn(
                    self.params, self.pool.k, self.pool.v,
                    jnp.asarray(dbt), jnp.asarray(dlens),
                    jnp.asarray(tok0), jnp.asarray(kdv), taus,
                    jnp.asarray(dseeds), jnp.asarray(dcounts),
                    jnp.asarray(dtemps), jnp.asarray(dtopks))
            self._c_launches["draft"].inc()
            if n0d >= 0 and _cache_size(draft_fn) > n0d:
                self.obs.record_compile("draft", (Rb,), spd.elapsed,
                                        self.total_steps)
            d_toks = self._inject_draft(dseqs, kdv, d_toks,
                                        d_logits.shape[-1])
            # draft-row -> mixed-row scatter map; pad draft rows point out
            # of range, which scatter mode="drop" discards
            dec_pos = np.full((Rb,), Bb, np.int32)
            dec_pos[:len(dec_rows)] = dec_rows
            kd_full = np.zeros((Bb,), np.int32)
            for i in dec_rows:
                kd_full[i] = draft_lens[i]
            mixed_fn = _mixed_spec_step(
                self._serving_cfg(), self.econfig.use_lamp,
                self.econfig.kernel, self.spec_config,
                any(s.sampling.top_k > 0 for s in seqs))
            n0 = _cache_size(mixed_fn)
            with self.obs.span("mixed", rows=len(seqs), bucket=[Bb, Wb],
                               tokens=int(sum(windows))) as sp:
                out = mixed_fn(
                    self.params, self.pool.k, self.pool.v,
                    jnp.asarray(tokens), jnp.asarray(bt),
                    jnp.asarray(starts), jnp.asarray(qlens),
                    jnp.asarray(kd_full), jnp.asarray(dec_pos), d_toks,
                    d_logits, taus, jnp.asarray(seeds),
                    jnp.asarray(counts), jnp.asarray(temps),
                    jnp.asarray(topks))
            self._c_launches["mixed"].inc()
            with self.obs.span("sync"):
                jax.block_until_ready(out)
                (nxt, emit, n_acc, health, self.pool.k, self.pool.v, nsel,
                 nval) = out
                nxt, emit, n_acc, health, nsel, nval = (
                    np.asarray(nxt), np.asarray(emit), np.asarray(n_acc),
                    np.asarray(health), np.asarray(nsel), np.asarray(nval))
        if n0 >= 0 and _cache_size(mixed_fn) > n0:
            self.obs.record_compile("mixed", (Bb, Wb), sp.elapsed,
                                    self.total_steps)
        # decode/verify rows poison width 1 (what the decode retry
        # rewrites -- see _step_spec); prefill rows poison their window
        health = self._inject_nan(
            seqs, health,
            [(s.prefill_cursor, windows[i]) if roles[i] == "prefill"
             else (s.cache_len, 1) for i, s in enumerate(seqs)])
        guard = self.econfig.health_guard
        now = self._now()
        self._account_lamp(seqs, nsel, nval,
                           verify_cols=dec_rows if spec_round else None)
        for i, seq in enumerate(seqs):
            w = windows[i]
            if guard and self._unhealthy(health[i]):
                if roles[i] == "prefill":
                    self._quarantine.append((seq, "prefill", w))
                else:
                    # discard this row's speculative round (if any), keep
                    # one slot past cache_len for the width-1 retry's write
                    # position, and retry it as a plain decode step
                    seq.block_ids = self.pool.rollback(seq.block_ids,
                                                       seq.cache_len + 1)
                    self._quarantine.append((seq, "decode", 1))
                continue
            if roles[i] == "prefill":
                seq.prefill_cursor += w
                seq.cache_len = seq.prefill_cursor
                self._c_prefill_tokens.inc(w)
                if self.econfig.prefix_cache:
                    self.pool.register_prefix(seq.prefill_tokens(),
                                              seq.block_ids, seq.cache_len,
                                              hashes=seq.prefix_hashes)
                if seq.prefill_remaining == 0:
                    seq.status = SequenceStatus.DECODE
                    seq.on_token(int(nxt[i]), now)
                    self._c_generated.inc()
                else:
                    self._c_prefill_chunks.inc()
            elif spec_round:
                # identical bookkeeping to _step_spec (see the acceptance
                # accounting rationale there)
                a = int(n_acc[i])
                seq.spec_drafted += int(draft_lens[i])
                self._c_spec_drafted.inc(int(draft_lens[i]))
                appended = 0
                for t in emit[i, :a + 1]:
                    seq.on_token(int(t), now)
                    appended += 1
                    self._c_generated.inc()
                    if seq.should_stop():
                        break
                kept_accepted = min(a, appended)
                seq.spec_accepted += kept_accepted
                self._c_spec_accepted.inc(kept_accepted)
                seq.cache_len += appended
                self._c_spec_emitted.inc(appended)
                seq.block_ids = self.pool.rollback(seq.block_ids,
                                                   seq.cache_len)
            else:
                seq.cache_len += 1
                seq.on_token(int(nxt[i]), now)
                self._c_generated.inc()

    def _step_mixed_split(self, plan: StepPlan) -> None:
        """Execute a mixed plan through the legacy phase-segregated
        sub-steps -- `_step_mixed`'s differential-testing twin: same rows,
        same windows, same draft budgets, the same per-request tokens and
        telemetry, but two or three launches instead of one or two."""
        roles = list(plan.roles or ["decode"] * len(plan.seqs))
        pre = [i for i, r in enumerate(roles) if r == "prefill"]
        dec = [i for i, r in enumerate(roles) if r != "prefill"]
        if pre:
            self._step_prefill([plan.seqs[i] for i in pre],
                               [plan.windows[i] for i in pre])
        if dec:
            dseqs = [plan.seqs[i] for i in dec]
            dkd = [plan.draft_lens[i] for i in dec]
            if self.econfig.speculative and any(dkd):
                self._step_spec(dseqs, dkd)
            else:
                self._step_decode(dseqs)

    def _collect_finished(self, seqs: List[Sequence]) -> List[RequestOutput]:
        done = []
        now = self._now()
        for seq in seqs:
            reason = seq.should_stop()
            if reason is None:
                continue
            seq.finish(reason, now)
            self.scheduler.finish(seq)
            lamp_l_sel = lamp_l_val = None
            if seq.lamp.by_layer_selected is not None:
                lamp_l_sel = [float(s) for s in seq.lamp.by_layer_selected]
                lamp_l_val = [float(v) for v in seq.lamp.by_layer_valid]
            out = RequestOutput(
                req_id=seq.req_id, prompt=seq.prompt, tokens=seq.generated,
                finish_reason=reason, latency=seq.latency(),
                ttft=seq.ttft(), num_preemptions=seq.num_preemptions,
                lamp_selected=seq.lamp.selected, lamp_valid=seq.lamp.valid,
                num_cached_tokens=seq.num_cached_tokens,
                num_resume_cached_tokens=seq.num_resume_cached_tokens,
                spec_drafted=seq.spec_drafted,
                spec_accepted=seq.spec_accepted,
                lamp_layer_selected=lamp_l_sel,
                lamp_layer_valid=lamp_l_val,
                audit_samples=seq.audit_samples,
                audit_err_sum=seq.audit_err_sum,
                audit_flips=seq.audit_flips)
            if self.auditor is not None:
                self.auditor.finish_request(seq)
            self._finished.append(out)
            self._c_finished.inc()
            self._c_cached_prefix.inc(seq.num_cached_tokens)
            self._c_cached_resume.inc(seq.num_resume_cached_tokens)
            self._h_latency.observe(out.latency)
            self._h_ttft.observe(out.ttft)
            # prune the live-sequence map: its cached-token tallies now
            # live in the counters above, so stats() stays O(live) and the
            # engine's memory is bounded no matter how many requests it
            # has ever served
            self._seqs.pop(seq.req_id, None)
            done.append(out)
        return done

    # -- maintenance / metrics ---------------------------------------------

    def defrag(self) -> None:
        with self.obs.span("defrag"):
            self.pool.defrag(sorted(self.scheduler.running,
                                    key=lambda s: s.arrival_time))

    @property
    def num_preemptions(self) -> int:
        return self.scheduler.num_preemptions

    def _sync_gauges(self) -> None:
        """Publish point-in-time state (pool, scheduler) into the registry
        so snapshots/exposition carry it; counters update in the hot path."""
        reg = self.obs.registry
        g = reg.gauge("engine_live_requests",
                      help="requests queued or running")
        g.set(len(self.scheduler.waiting) + len(self.scheduler.running))
        reg.gauge("kv_blocks_used", help="arena blocks in use").set(
            self.pool.num_used)
        reg.gauge("kv_util", help="arena utilization").set(
            self.pool.utilization)
        reg.gauge("kv_util_peak").set(self.pool.peak_used
                                      / self.pool.num_total)
        reg.gauge("engine_preemptions", help="recompute-style evictions"
                  ).set(self.scheduler.num_preemptions)
        reg.gauge("kv_blocks_allocated_total").set(self.pool.total_allocs)
        reg.gauge("kv_blocks_prefix_hits_total").set(self.pool.hit_blocks)
        reg.gauge("kv_cow_copies_total").set(self.pool.cow_copies)
        reg.gauge("kv_cache_evictions_total").set(self.pool.evictions)

    def metrics_snapshot(self) -> Dict[str, Any]:
        """JSON-serializable dump of the whole metrics registry."""
        self._sync_gauges()
        return self.obs.registry.snapshot()

    def lamp_layer_rates(self) -> List[float]:
        """Cumulative per-layer recompute rate (len n_layers)."""
        return [float(s / v) if v else 0.0
                for s, v in zip(self._layer_sel, self._layer_val)]

    def stats(self, exact: bool = False) -> Dict[str, Any]:
        """Cumulative serving stats (a view over the metrics registry).

        Latency/TTFT percentiles come from the streaming histograms --
        O(buckets) per call, safe to poll under a live stream. Pass
        `exact=True` for end-of-run reporting: percentiles are then
        computed exactly over the retained finished requests (the last
        `finished_retention`; O(n log n))."""
        elapsed = (self._now() - self._start) if self._start else 0.0
        if exact:
            lat = [o.latency for o in self._finished]
            ttft = [o.ttft for o in self._finished]
            lat_p50 = float(np.percentile(lat, 50)) if lat else 0.0
            lat_p99 = float(np.percentile(lat, 99)) if lat else 0.0
            ttft_p50 = float(np.percentile(ttft, 50)) if ttft else 0.0
        else:
            lat_p50 = self._h_latency.quantile(0.5)
            lat_p99 = self._h_latency.quantile(0.99)
            ttft_p50 = self._h_ttft.quantile(0.5)
        # finished sequences' tallies live in the counters (_seqs holds
        # only live requests); resume self-hits are reported separately
        # and excluded from the cross-request hit rate
        cached = int(self._c_cached_prefix.value) + sum(
            s.num_cached_tokens for s in self._seqs.values())
        resume_cached = int(self._c_cached_resume.value) + sum(
            s.num_resume_cached_tokens for s in self._seqs.values())
        generated = self.generated_tokens
        n_done = int(self._c_finished.value)
        phase = {name: {"mean_us": h.mean * 1e6, "count": h.count}
                 for name, h in self.obs._phase_children.items() if h.count}
        return {
            "num_finished": n_done,
            "elapsed_s": elapsed,
            "tokens_per_s": generated / elapsed if elapsed else 0.0,
            "requests_per_s": n_done / elapsed if elapsed else 0.0,
            "latency_p50_s": lat_p50,
            "latency_p99_s": lat_p99,
            "ttft_p50_s": ttft_p50,
            "steps": self.total_steps,
            "prefill_steps": self.prefill_steps,
            "decode_steps": self.decode_steps,
            # fused-step telemetry: mixed steps count once in "steps" but
            # feed the prefill/decode views above by row role; launches is
            # the fused step's headline (jitted calls, fewer when fused)
            "mixed_steps": self.mixed_steps,
            "launches": self.launches,
            "prefill_chunks": self.prefill_chunks,
            "preemptions": self.num_preemptions,
            # prefix-cache telemetry
            "blocks_allocated": self.pool.total_allocs,
            "blocks_saved": self.pool.hit_blocks,
            "cached_tokens": cached,
            "resume_cached_tokens": resume_cached,
            "prefill_tokens_run": self.prefill_tokens_run,
            "cache_hit_rate": cached / max(1, self.prefill_tokens_run
                                           + cached),
            "cow_copies": self.pool.cow_copies,
            "cache_evictions": self.pool.evictions,
            "kv_util_mean": (self._util_sum / self._util_n
                             if self._util_n else 0.0),
            "kv_util_peak": self.pool.peak_used / self.pool.num_total,
            "lamp_recompute_rate": (self.agg_lamp_selected /
                                    self.agg_lamp_valid
                                    if self.agg_lamp_valid else 0.0),
            # per-layer LAMP telemetry (cumulative; the bounded time series
            # lives in engine.layer_rate_series / the trace counter track)
            "lamp_layer_rates": self.lamp_layer_rates(),
            # jit-cache observability (see engine.compile_events for the log)
            "compiles": len(self.compile_events),
            "compile_time_s": sum(e["wall_s"] for e in self.compile_events),
            # per-phase wall time (mean us + sample count per phase)
            "phase": phase,
            # hung-stream visibility: requests still queued or running
            "live_requests": (len(self.scheduler.waiting)
                              + len(self.scheduler.running)),
            # speculative decoding
            "spec_rounds": self.spec_rounds,
            "spec_drafted_tokens": self.spec_drafted,
            "spec_accepted_tokens": self.spec_accepted,
            "spec_acceptance_rate": (self.spec_accepted / self.spec_drafted
                                     if self.spec_drafted else 0.0),
            "spec_tokens_per_round": (self.spec_emitted / self.spec_rounds
                                      if self.spec_rounds else 0.0),
            "verify_recompute_rate": (self.spec_verify_selected /
                                      self.spec_verify_valid
                                      if self.spec_verify_valid else 0.0),
            # adaptive policy loop (serving/policy.py)
            "policy": (self.policy.stats() if self.policy is not None
                       else {"enabled": False}),
            # shadow audit (obs/audit.py): realized LAMP error telemetry
            "audit": (self.auditor.stats() if self.auditor is not None
                      else {"enabled": False}),
            # fault tolerance (serving/faults.py + the recovery ladder)
            "recoveries": self._n_recoveries,
            "failed_requests": self._n_failed,
            "faults": (self.faults.stats() if self.faults is not None
                       else {"enabled": False}),
        }

    def write_trace(self, path: Optional[str] = None) -> str:
        """Write the buffered step-phase trace as Chrome trace JSON
        (loadable in Perfetto / chrome://tracing). Requires
        ObsConfig.trace; `path` defaults to ObsConfig.trace_path."""
        return self.obs.write_trace(path)

    def _hang_diagnostic(self, n_events: int = 16) -> str:
        """Snapshot for the run_to_completion hang error: the registry's
        scalar metrics plus the trace tail, so a hung CI stream is
        debuggable from the log alone."""
        self._sync_gauges()
        scalars = {k: v for k, v in self.obs.registry.snapshot().items()
                   if isinstance(v, (int, float))}
        lines = ["registry snapshot: " + json.dumps(scalars, sort_keys=True)]
        seqs = list(self.scheduler.running) + list(self.scheduler.waiting)
        lines.append("live sequences: " + "; ".join(
            f"req {s.req_id} {s.status.value} gen={s.num_generated}"
            f"/{s.sampling.max_new_tokens} blocks={len(s.block_ids)}"
            for s in seqs[:8]))
        if self.obs.tracer.enabled:
            evs = self.obs.tracer.last(n_events)
            lines.append(f"last {len(evs)} trace events: " + "; ".join(
                f"{name}@{ts:.3f}s+{dur * 1e3:.2f}ms"
                for _, name, _, ts, dur, _ in evs))
        else:
            lines.append("trace ring empty (enable EngineConfig.obs.trace "
                         "for span-level hang forensics)")
        # accuracy regressions that stall acceptance (and therefore
        # progress) show up as flip-rate spikes in the audit ring
        if self.auditor is not None:
            tail = self.auditor.ring_tail()
            lines.append("audit ring tail: " + ("; ".join(tail) if tail
                                                else "(no audited steps)"))
        else:
            lines.append("audit off (set EngineConfig.audit.rate for "
                         "realized-error forensics)")
        return "\n".join(lines)

    def run_to_completion(self, max_steps: int = 100000) -> List[RequestOutput]:
        """Drive step() until every queued request finishes.

        Raises RuntimeError when `max_steps` elapse with requests still
        live, so a hung stream (scheduler stall, runaway generation) is
        loud instead of silently dropping requests; the error carries a
        diagnostic snapshot (registry scalars + trace tail) and
        stats()["live_requests"] exposes the same condition to pollers.

        A stall watchdog runs first: after `EngineConfig.stall_patience`
        consecutive steps with zero progress (no tokens, no prefill, no
        finishes, no failures) it attempts `_stall_recover()` -- clearing
        an injected stall, evicting wedged rows, or failing the oldest
        queued request -- and only raises when recovery changes nothing."""
        out: List[RequestOutput] = []
        idle = 0
        last = None
        for _ in range(max_steps):
            if not self.has_unfinished():
                return out
            out.extend(self.step())
            prog = (self.generated_tokens, self.prefill_tokens_run,
                    int(self._c_finished.value), self._n_failed)
            if prog == last:
                idle += 1
                if idle >= self.econfig.stall_patience:
                    if not self._stall_recover():
                        break
                    idle = 0
            else:
                idle = 0
                last = prog
        live = self.stats()["live_requests"]
        raise RuntimeError(
            f"run_to_completion exceeded max_steps={max_steps} with {live} "
            f"request(s) still live ({int(self._c_finished.value)} finished"
            f"); the "
            f"stream is hung or max_steps is too small\n"
            + self._hang_diagnostic())
