"""Componentwise forward-error model: audited per-layer errors -> targets.

The calibration half of the shadow-audit loop (obs/audit.py measures, this
module converts). Framing, after El arar et al.'s componentwise forward-error
analysis and Budzinskiy et al.'s stability analysis of transformer stacks:
the end-to-end relative error of an L-layer composition is, to first order,
the sum of per-layer *local* errors each amplified by the downstream layers,

    e_total  <~  sum_l  e_l * prod_{m>l} (1 + c_m)  =  sum_l a_l * e_l,

where e_l is the error layer l itself injects (the audit's shadow
measurement: LAMP applied to the reference stream, against the reference)
and a_l the amplification of everything above it. LAMP's knob is the
per-layer recompute rate: more recompute at layer l shrinks e_l roughly in
proportion (the selective-recompute fraction bounds the residual rounding
mass the look-ahead rule lets through). Equalizing every layer's *amplified
contribution* against a uniform split of the total error budget therefore
allocates recompute in proportion to each layer's amplified error share --
layers that inject error the stack amplifies get a larger slice of the same
total recompute budget, quiet layers give theirs up.

All functions are pure numpy on tiny (L,) arrays -- no jax, no engine state
-- so they are trivially testable and callable from the audit hot path.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

__all__ = ["amplification", "derive_target_rates", "attribute_flips",
           "relax_mask", "calibrate"]

_EPS = 1e-12


def amplification(layer_err: np.ndarray) -> np.ndarray:
    """Downstream amplification factor a_l = prod_{m>l} (1 + e_m).

    Uses the audited local errors themselves as the per-layer gain proxy
    c_m ~= e_m (a layer that perturbs its input by e also perturbs a
    perturbation passing through it by ~e, first order). Computed in
    log-space for stability; a_{L-1} == 1 (the top layer has nothing above
    it to amplify its error)."""
    e = np.asarray(layer_err, np.float64).clip(min=0.0)
    log1p = np.log1p(e)
    # suffix-sum of log(1+e_m) over m > l
    tail = np.concatenate([np.cumsum(log1p[::-1])[::-1][1:], [0.0]])
    return np.exp(tail)


def derive_target_rates(layer_err: np.ndarray, base_rate: float, *,
                        min_rate: float = 0.005, max_rate: float = 0.5,
                        power: float = 0.5) -> np.ndarray:
    """Per-layer recompute-rate targets from audited local errors.

    Each layer's share of the (conserved) total recompute budget is
    proportional to its amplified error contribution a_l * e_l, tempered by
    `power` (0.5 by default: full proportional allocation over-reacts to the
    heavy-tailed error distributions audits actually measure; the square
    root still orders layers by error but caps the spread). The result is
    renormalized so mean(targets) == base_rate -- calibration *redistributes*
    the budget the operator configured, it never inflates it -- then clamped
    to [min_rate, max_rate] (every layer keeps a recompute floor: a layer
    audited quiet today still needs look-ahead coverage to notice when its
    inputs shift).

    With uniform errors this returns base_rate for every layer (the scalar
    default is the fixed point); a layer with above-average amplified error
    always gets a target above base_rate.
    """
    if not 0.0 < base_rate <= 1.0:
        raise ValueError(f"base_rate must be in (0, 1], got {base_rate}")
    e = np.asarray(layer_err, np.float64).clip(min=0.0)
    share = (amplification(e) * e + _EPS) ** power
    t = base_rate * share / max(share.mean(), _EPS)
    t = np.clip(t, min_rate, max_rate)
    return t.astype(np.float64)


def attribute_flips(flip_rate: float, layer_err: np.ndarray) -> np.ndarray:
    """Attribute the audited end-to-end argmax flip rate back to layers.

    The audit observes flips only at the output; the error model splits
    them by each layer's amplified share of the total error mass (the same
    first-order composition bound read backwards). Zero total error
    attributes zero flips everywhere."""
    e = np.asarray(layer_err, np.float64).clip(min=0.0)
    contrib = amplification(e) * e
    total = contrib.sum()
    if total <= _EPS:
        return np.zeros_like(contrib)
    return float(flip_rate) * contrib / total


def relax_mask(flip_rate: float, layer_err: np.ndarray,
               flip_budget: float) -> np.ndarray:
    """Boolean (L,) mask: True where the degradation ladder may RELAX the
    layer (scale its target down / push its tau up under load). A layer
    whose attributed flip rate already exceeds its error budget is *frozen
    out* of relaxation -- degrading it further trades user-visible token
    flips for throughput, which the guardrail forbids."""
    return attribute_flips(flip_rate, layer_err) <= float(flip_budget)


def calibrate(layer_err: np.ndarray, flip_rate: float, base_rate: float, *,
              flip_budget: float, min_rate: float = 0.005,
              max_rate: float = 0.5, power: float = 0.5,
              ) -> Tuple[np.ndarray, np.ndarray]:
    """One calibration pass: (target_rates, relax_ok) for the controller."""
    targets = derive_target_rates(layer_err, base_rate, min_rate=min_rate,
                                  max_rate=max_rate, power=power)
    ok = relax_mask(flip_rate, layer_err, flip_budget)
    return targets, ok
