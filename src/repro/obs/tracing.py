"""Step-phase tracer: ring-buffered span events, Chrome-trace export.

The engine wraps every phase of its step loop (schedule, block alloc,
prefill window, decode, draft, verify, host<->device sync, emit, defrag) in
`span(...)`; each completed span is one fixed-size tuple written into a
preallocated ring buffer, so a hot serving loop can trace indefinitely with
bounded memory and the *last* `capacity` events always available (hang
diagnostics read the tail).

Export is Chrome trace format (the JSON object form: {"traceEvents": [...]})
with complete events (`"ph": "X"`, microsecond `ts`/`dur`) plus instant
(`"i"`) and counter (`"C"`) events -- loadable in Perfetto / chrome://tracing
as-is. Timestamps come from the injected `clock` (seconds), so a fake clock
makes the tracer fully deterministic under test; they are rebased to the
first buffered event at export time.

`NULL_TRACER` is a shared no-op with the same surface: `tracer.span(...)`
costs one attribute lookup and a constant context manager when tracing is
off, keeping the engine free of `if tracing:` branches.
"""

from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, List, Optional

# event kinds (Chrome trace "ph" values)
_COMPLETE, _INSTANT, _COUNTER = "X", "i", "C"


class _Span:
    """Reusable-shape span context manager; one is allocated per span()
    call (cheap), records on clean exit AND on exception so a crashing
    phase still shows up in the trace tail."""

    __slots__ = ("_tr", "name", "cat", "args", "t0")

    def __init__(self, tr: "StepTracer", name: str, cat: str,
                 args: Optional[Dict[str, Any]]):
        self._tr = tr
        self.name = name
        self.cat = cat
        self.args = args
        self.t0 = 0.0

    def __enter__(self) -> "_Span":
        self.t0 = self._tr._clock()
        return self

    def __exit__(self, *exc) -> None:
        tr = self._tr
        tr._record((_COMPLETE, self.name, self.cat, self.t0,
                    tr._clock() - self.t0, self.args))

    @property
    def elapsed(self) -> float:
        return self._tr._clock() - self.t0


class _NullSpan:
    __slots__ = ()
    t0 = 0.0
    elapsed = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None


_NULL_SPAN = _NullSpan()


class NullTracer:
    """No-op tracer with the full StepTracer surface."""

    enabled = False
    dropped = 0

    def span(self, name: str, cat: str = "step", **args):
        return _NULL_SPAN

    def instant(self, name: str, cat: str = "step", **args) -> None:
        pass

    def counter(self, name: str, **values: float) -> None:
        pass

    def events(self) -> List[tuple]:
        return []

    def last(self, n: int) -> List[tuple]:
        return []

    def to_chrome_trace(self) -> Dict[str, Any]:
        return {"traceEvents": []}

    def write(self, path: str) -> None:
        raise RuntimeError("tracing is disabled; enable ObsConfig.trace")


NULL_TRACER = NullTracer()


class StepTracer(NullTracer):
    enabled = True

    def __init__(self, capacity: int = 8192,
                 clock: Callable[[], float] = time.monotonic,
                 pid: int = 0, tid: int = 0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._clock = clock
        self.pid = pid
        self.tid = tid
        self._buf: List[Optional[tuple]] = [None] * capacity
        self._n = 0                     # total events ever recorded

    # -- recording ----------------------------------------------------------

    def _record(self, ev: tuple) -> None:
        self._buf[self._n % self.capacity] = ev
        self._n += 1

    def span(self, name: str, cat: str = "step", **args) -> _Span:
        return _Span(self, name, cat, args or None)

    def instant(self, name: str, cat: str = "step", **args) -> None:
        self._record((_INSTANT, name, cat, self._clock(), 0.0, args or None))

    def counter(self, name: str, **values: float) -> None:
        """Chrome counter event: Perfetto renders each named series as a
        stacked track (the per-layer recompute-rate time series)."""
        self._record((_COUNTER, name, "counter", self._clock(), 0.0,
                      dict(values)))

    # -- inspection ---------------------------------------------------------

    @property
    def dropped(self) -> int:
        return max(0, self._n - self.capacity)

    def events(self) -> List[tuple]:
        """Buffered events, oldest first. Tuple layout:
        (ph, name, cat, t_start_s, dur_s, args | None)."""
        if self._n <= self.capacity:
            return [e for e in self._buf[:self._n]]
        head = self._n % self.capacity
        return self._buf[head:] + self._buf[:head]

    def last(self, n: int) -> List[tuple]:
        return self.events()[-n:]

    # -- export -------------------------------------------------------------

    def to_chrome_trace(self) -> Dict[str, Any]:
        evs = self.events()
        t0 = min((e[3] for e in evs), default=0.0)
        out = []
        for ph, name, cat, ts, dur, args in evs:
            ev: Dict[str, Any] = {
                "name": name, "cat": cat, "ph": ph, "pid": self.pid,
                "tid": self.tid, "ts": round((ts - t0) * 1e6, 3),
            }
            if ph == _COMPLETE:
                ev["dur"] = round(dur * 1e6, 3)
            if ph == _INSTANT:
                ev["s"] = "t"           # thread-scoped instant
            if args:
                ev["args"] = args
            out.append(ev)
        return {
            "traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path
