"""Online shadow-audit: measure the error LAMP actually realizes, live.

The serving stack's telemetry (PR 6/7) observes recompute *rates* -- the
paper's control variable -- but never the *error* those rates are supposed to
suppress. This module closes that gap: on a deterministic sample of serving
steps the engine replays the step's rows through
`transformer.paged_audit_window` (LAMP arm + FP32 reference arm in lockstep,
gather path, non-donated arena, metrics-only return), so realized error is
measured in production without perturbing a single served token.

Sampling is a pure function of (step, request, salt) via a splitmix64-style
hash: re-running the same request stream audits the same rows, so an
accuracy regression seen in telemetry is *replayable* -- rerun with the same
salt and the same steps get audited again. Audited steps select up to
`max_rows` rows (ranked by the same hash) to bound the shadow batch and keep
overhead at the configured rate rather than at the row count.

Telemetry lands in the PR 6 registry/tracer as `lamp_audit_*` counters and
histograms, a Perfetto counter track, a bounded ring of recent audited steps
(surfaced by the hang diagnostic), and `stats()["audit"]`. When calibration
is on and a policy controller is attached, audited per-layer local errors
feed obs/error_model.py to derive per-layer recompute-rate targets and the
RELAXED guardrail mask (see `ShadowAuditor.maybe_calibrate`).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import Observability
from .error_model import attribute_flips, calibrate

__all__ = ["AuditConfig", "ShadowAuditor", "audit_hash", "select_rows"]

_MASK64 = (1 << 64) - 1

# relative-error histogram edges: 1e-8 .. 1, ~x10 per bucket, plus a linear
# top end so gross divergence is not one smeared bucket
ERR_EDGES = (1e-8, 1e-7, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 3.16e-2, 1e-1,
             3.16e-1, 1.0)
# top-k overlap is a fraction in [0, 1]
OVERLAP_EDGES = (0.0, 0.2, 0.4, 0.6, 0.8, 0.999)


def audit_hash(step: int, req_id: int, salt: int = 0) -> float:
    """Deterministic (step, request, salt) -> [0, 1) via splitmix64 mixing.

    Pure and platform-independent (no Python `hash`, which is salted per
    process): the audit decision for a given stream replays exactly."""
    x = (step * 0x9E3779B97F4A7C15
         + req_id * 0xBF58476D1CE4E5B9
         + salt * 0x94D049BB133111EB + 0x2545F4914F6CDD1D) & _MASK64
    x ^= x >> 30
    x = (x * 0xBF58476D1CE4E5B9) & _MASK64
    x ^= x >> 27
    x = (x * 0x94D049BB133111EB) & _MASK64
    x ^= x >> 31
    return x / 2.0 ** 64


def select_rows(step: int, req_ids: Sequence[int], rate: float, salt: int,
                max_rows: int) -> List[int]:
    """Indices of the rows audited at `step` (possibly empty).

    Two-level deterministic sampling: the *step* is audited with probability
    `rate` (hash of (step, salt) alone -- request id 0 reserved for the step
    draw), and an audited step shadow-runs up to `max_rows` of its rows,
    ranked by the per-(step, request) hash. Overhead therefore scales with
    `rate` (fraction of steps paying one bounded shadow launch), not with
    the batch size, while row choice stays replayable per request."""
    if rate <= 0.0 or not req_ids:
        return []
    if rate < 1.0 and audit_hash(step, 0, salt) >= rate:
        return []
    ranked = sorted(range(len(req_ids)),
                    key=lambda i: (audit_hash(step, int(req_ids[i]) + 1,
                                              salt), i))
    return sorted(ranked[:max(1, int(max_rows))])


@dataclasses.dataclass(frozen=True)
class AuditConfig:
    """Shadow-audit knobs (hashable: lives inside frozen EngineConfig).

    `rate` is the fraction of engine steps audited (0 disables the
    subsystem entirely -- no auditor is constructed, zero hot-path cost).
    An audited step shadow-runs at most `max_rows` of its rows in one
    extra jitted launch, so per-step overhead ~= rate * (audit launch /
    serving launch); at the defaults (rate=0.05, max_rows=4) this stays
    under the 5% CI gate. Calibration (on by default) only takes effect
    when the engine also has a policy controller attached."""
    rate: float = 0.0               # fraction of steps audited
    salt: int = 0                   # replay key for the sampling hash
    max_rows: int = 4               # shadow-batch row cap per audited step
    top_k: int = 5                  # overlap set size for topk telemetry
    ring_capacity: int = 64         # recent audited steps kept for stats()
    ema: float = 0.2                # EMA weight for smoothed error/flip rate
    calibrate: bool = True          # feed error-model targets to the policy
    calibrate_every: int = 4        # audited steps between target refreshes
    min_samples: int = 2            # audited steps before first calibration
    flip_budget: float = 0.02       # per-layer attributed flip-rate budget
    min_rate: float = 0.005         # target clamp floor (error model)
    max_rate: float = 0.5           # target clamp ceiling (error model)

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"audit rate must be in [0, 1], got {self.rate}")
        if not 0.0 < self.ema <= 1.0:
            raise ValueError(f"audit ema must be in (0, 1], got {self.ema}")
        for f in ("max_rows", "top_k", "ring_capacity", "calibrate_every",
                  "min_samples"):
            if getattr(self, f) < 1:
                raise ValueError(f"audit {f} must be >= 1")
        if not 0.0 <= self.flip_budget <= 1.0:
            raise ValueError("audit flip_budget must be in [0, 1]")
        if not 0.0 < self.min_rate <= self.max_rate <= 1.0:
            raise ValueError("audit rate clamp must satisfy "
                             "0 < min_rate <= max_rate <= 1")


class ShadowAuditor:
    """Accounting + calibration state for the shadow-audit subsystem.

    The engine owns scheduling and shadow execution (it knows plans,
    buckets and jit caches); this object owns everything downstream of the
    metrics dict the audit launch returns: registry counters/histograms,
    per-layer error EMAs, the audited-step ring, per-request accumulation,
    and the calibration pass into the policy controller."""

    def __init__(self, config: AuditConfig, n_layers: int,
                 obs: Observability) -> None:
        self.config = config
        self.n_layers = n_layers
        self.obs = obs
        L = n_layers
        # smoothed per-layer local/cumulative error and end-to-end flip rate
        self.kq_err = np.zeros((L,), np.float64)
        self.router_err = np.zeros((L,), np.float64)
        self.cum_err = np.zeros((L,), np.float64)
        self.flip_rate = 0.0
        self.logit_rel = 0.0
        self.audited_steps = 0
        self.audited_rows = 0
        self.calibrations = 0
        self.ring: Deque[Dict[str, Any]] = deque(maxlen=config.ring_capacity)
        self._last_targets: Optional[np.ndarray] = None
        self._last_relax_ok: Optional[np.ndarray] = None

        reg = obs.registry
        c = reg.counter("lamp_audit_steps_total",
                        help="engine steps shadow-audited")
        self._c_steps = c
        self._c_rows = reg.counter("lamp_audit_rows_total",
                                   help="request rows shadow-audited")
        self._c_flips = reg.counter(
            "lamp_audit_flips_total",
            help="audited rows whose greedy argmax token flipped "
                 "LAMP-vs-reference")
        err = reg.counter(
            "lamp_audit_layer_err_total",
            help="summed audited per-layer relative error by site "
                 "(kq/router = local shadow error, cum = carried "
                 "hidden-state drift); divide by lamp_audit_steps_total "
                 "for the mean",
            labels=("layer", "site"))
        self._c_kq = [err.labels(str(l), "kq") for l in range(L)]
        self._c_router = [err.labels(str(l), "router") for l in range(L)]
        self._c_cum = [err.labels(str(l), "cum") for l in range(L)]
        self._h_rel = reg.histogram(
            "lamp_audit_logit_rel_err", edges=ERR_EDGES,
            help="per audited row: final-logit relative L2 error")
        self._h_abs = reg.histogram(
            "lamp_audit_logit_max_abs_err", edges=ERR_EDGES,
            help="per audited row: final-logit max abs error")
        self._h_topk = reg.histogram(
            "lamp_audit_topk_overlap", edges=OVERLAP_EDGES,
            help="per audited row: top-k overlap LAMP-vs-reference")
        self._h_req = reg.histogram(
            "lamp_audit_request_cum_err", edges=ERR_EDGES,
            help="per finished request: mean audited logit relative error "
                 "over its audited steps")
        self._c_calib = reg.counter(
            "lamp_audit_calibrations_total",
            help="error-model target refreshes pushed to the policy")

    # -- sampling -----------------------------------------------------------

    def select(self, step: int, req_ids: Sequence[int]) -> List[int]:
        c = self.config
        return select_rows(step, req_ids, c.rate, c.salt, c.max_rows)

    # -- accounting ---------------------------------------------------------

    def account(self, step: int, seqs: Sequence[Any],
                metrics: Dict[str, np.ndarray]) -> None:
        """Fold one audit launch's metrics dict (numpy, per-layer arrays
        full-length, per-row arrays already sliced to the live rows which
        correspond 1:1 to `seqs`) into counters, EMAs and the ring."""
        n = len(seqs)
        kq = np.asarray(metrics["kq_err"], np.float64)
        router = np.asarray(metrics["router_err"], np.float64)
        cum = np.asarray(metrics["cum_err"], np.float64)
        rel = np.asarray(metrics["logit_rel"], np.float64)[:n]
        mabs = np.asarray(metrics["logit_max_abs"], np.float64)[:n]
        flip = np.asarray(metrics["flip"], np.float64)[:n]
        topk = np.asarray(metrics["topk"], np.float64)[:n]

        self._c_steps.inc()
        self._c_rows.inc(n)
        self._c_flips.inc(float(flip.sum()))
        for l in range(self.n_layers):
            self._c_kq[l].inc(float(kq[l]))
            self._c_router[l].inc(float(router[l]))
            self._c_cum[l].inc(float(cum[l]))
        for i in range(n):
            self._h_rel.observe(float(rel[i]))
            self._h_abs.observe(float(mabs[i]))
            self._h_topk.observe(float(topk[i]))

        a = self.config.ema
        first = self.audited_steps == 0
        blend = (lambda old, new: new) if first else (
            lambda old, new: (1 - a) * old + a * new)
        self.kq_err = blend(self.kq_err, kq)
        self.router_err = blend(self.router_err, router)
        self.cum_err = blend(self.cum_err, cum)
        self.flip_rate = float(blend(self.flip_rate, float(flip.mean())))
        self.logit_rel = float(blend(self.logit_rel, float(rel.mean())))
        self.audited_steps += 1
        self.audited_rows += n

        for i, seq in enumerate(seqs):
            seq.audit_samples += 1
            seq.audit_err_sum += float(rel[i])
            seq.audit_flips += int(flip[i])

        self.ring.append({
            "step": int(step), "rows": n,
            "flip_rate": float(flip.mean()),
            "logit_rel_err": float(rel.mean()),
            "topk_overlap": float(topk.mean()),
            "worst_layer": int(np.argmax(kq)) if kq.size else 0,
        })
        self.obs.tracer.counter("lamp_audit",
                                flip_rate=self.flip_rate,
                                logit_rel_err=self.logit_rel)

    def finish_request(self, seq: Any) -> None:
        """Per-request cumulative-error histogram, observed at finish."""
        if getattr(seq, "audit_samples", 0) > 0:
            self._h_req.observe(seq.audit_err_sum / seq.audit_samples)

    # -- calibration --------------------------------------------------------

    def maybe_calibrate(self, policy: Any) -> bool:
        """Push error-derived targets + the RELAXED guardrail mask into the
        policy controller. Returns True when a refresh happened."""
        c = self.config
        if (not c.calibrate or policy is None
                or self.audited_steps < c.min_samples
                or self.audited_steps % c.calibrate_every != 0):
            return False
        err = self.kq_err + self.router_err
        targets, ok = calibrate(
            err, self.flip_rate, policy.config.target_rate,
            flip_budget=c.flip_budget, min_rate=c.min_rate,
            max_rate=c.max_rate)
        policy.set_error_targets(targets, ok)
        self._last_targets, self._last_relax_ok = targets, ok
        self.calibrations += 1
        self._c_calib.inc()
        return True

    # -- inspection ---------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "enabled": True,
            "rate": self.config.rate,
            "audited_steps": self.audited_steps,
            "audited_rows": self.audited_rows,
            "flip_rate": self.flip_rate,
            "logit_rel_err": self.logit_rel,
            "layer_kq_err": [float(x) for x in self.kq_err],
            "layer_router_err": [float(x) for x in self.router_err],
            "layer_cum_err": [float(x) for x in self.cum_err],
            "attributed_flips": [float(x) for x in attribute_flips(
                self.flip_rate, self.kq_err + self.router_err)],
            "calibrations": self.calibrations,
        }
        if self._last_targets is not None:
            d["targets"] = [float(x) for x in self._last_targets]
            d["relax_ok"] = [bool(x) for x in self._last_relax_ok]
        return d

    def ring_tail(self, n: int = 8) -> List[str]:
        """Last n audited steps, formatted for the hang diagnostic."""
        return [
            (f"step={e['step']} rows={e['rows']} "
             f"flip_rate={e['flip_rate']:.3f} "
             f"logit_rel_err={e['logit_rel_err']:.2e} "
             f"topk={e['topk_overlap']:.2f} worst_layer={e['worst_layer']}")
            for e in list(self.ring)[-n:]
        ]
