"""Serving observability: metrics registry + step-phase tracing.

LAMP's accuracy/throughput trade is steered by *telemetry* -- the recompute
rate is the paper's control variable -- so the serving stack treats
observability as a first-class subsystem rather than a pile of ad-hoc
counters:

  metrics.py  -- Counter / Gauge / Histogram registry with labeled children,
                 dict snapshots and Prometheus text exposition. The engine's
                 `stats()` is a view over one of these.
  tracing.py  -- ring-buffered step-phase span tracer exporting Chrome trace
                 format JSON (chrome://tracing / Perfetto loadable).

`Observability` bundles both behind a single injectable clock: every span it
opens is timed into a per-phase duration histogram (always on -- a dict
lookup and two float adds) and, when `ObsConfig.trace` is set, also recorded
as a trace event. Compile events (a new entry appearing in a bucketed jit
cache) are logged with their bucket shape and wall time -- recompile storms
are the canonical silent perf killer of fixed-shape serving, and this makes
them visible in `stats()`, the metrics snapshot, and the trace.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

from .metrics import (Counter, DEFAULT_TIME_EDGES, Gauge, Histogram,
                      MetricsRegistry)
from .tracing import NULL_TRACER, NullTracer, StepTracer

# per-phase duration edges (seconds): 10us .. 10s, ~x3 per bucket
PHASE_EDGES = (1e-5, 3.16e-5, 1e-4, 3.16e-4, 1e-3, 3.16e-3, 1e-2, 3.16e-2,
               1e-1, 3.16e-1, 1.0, 3.16, 10.0)


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Observability knobs (hashable: lives inside frozen EngineConfig).

    The metrics registry and per-phase duration histograms are always on
    (their hot-path cost is a cached dict lookup plus float adds);
    `trace` additionally records every phase span into the ring buffer for
    Chrome-trace export."""
    trace: bool = False             # record step-phase spans
    trace_capacity: int = 8192      # ring-buffer size (events)
    trace_path: str = ""            # default write_trace() destination
    series_capacity: int = 512      # per-layer recompute-rate series length
    compile_log_capacity: int = 256  # compile_events retained
    jax_profile_dir: str = ""       # opt-in jax.profiler.trace passthrough


class _ObsSpan:
    """Times one engine phase: always observes the per-phase histogram,
    and records a trace span when tracing is enabled."""

    __slots__ = ("_obs", "name", "args", "t0")

    def __init__(self, obs: "Observability", name: str,
                 args: Optional[Dict[str, Any]]):
        self._obs = obs
        self.name = name
        self.args = args
        self.t0 = 0.0

    def __enter__(self) -> "_ObsSpan":
        self.t0 = self._obs.now()
        return self

    def __exit__(self, *exc) -> None:
        obs = self._obs
        dt = obs.now() - self.t0
        obs.phase_hist(self.name).observe(dt)
        if obs.tracer.enabled:
            obs.tracer._record(("X", self.name, "step", self.t0, dt,
                                self.args))

    @property
    def elapsed(self) -> float:
        return self._obs.now() - self.t0


class Observability:
    """One engine's observability bundle: registry + tracer + clock."""

    def __init__(self, config: ObsConfig = ObsConfig(),
                 clock: Optional[Callable[[], float]] = None):
        self.config = config
        self.now: Callable[[], float] = clock or time.monotonic
        self.registry = MetricsRegistry()
        self.tracer = (StepTracer(config.trace_capacity, clock=self.now)
                       if config.trace else NULL_TRACER)
        self._phase_fam = self.registry.histogram(
            "engine_phase_seconds", edges=PHASE_EDGES,
            help="wall time per engine step phase", unit="s",
            labels=("phase",))
        self._phase_children: Dict[str, Histogram] = {}
        self._compile_counter = self.registry.counter(
            "engine_compiles_total", help="jit compiles by step kind",
            labels=("kind",))
        self.compile_events: Deque[Dict[str, Any]] = deque(
            maxlen=config.compile_log_capacity)

    # -- phase spans --------------------------------------------------------

    def phase_hist(self, name: str) -> Histogram:
        h = self._phase_children.get(name)
        if h is None:
            h = self._phase_fam.labels(name)
            self._phase_children[name] = h
        return h

    def span(self, name: str, **args) -> _ObsSpan:
        return _ObsSpan(self, name, args or None)

    # -- compile events -----------------------------------------------------

    def record_compile(self, kind: str, shape: Any, wall_s: float,
                       step: int) -> None:
        """Log one jit compile: `shape` is the bucket signature that grew
        the cache (e.g. (batch_bucket, window_bucket)); `wall_s` the wall
        time of the compiling call (dispatch + compile)."""
        self._compile_counter.labels(kind).inc()
        self.compile_events.append({
            "kind": kind, "shape": tuple(shape), "wall_s": wall_s,
            "step": step, "t": self.now(),
        })
        if self.tracer.enabled:
            self.tracer.instant(f"compile:{kind}", cat="compile",
                                shape=str(tuple(shape)),
                                wall_ms=round(wall_s * 1e3, 3))

    # -- export -------------------------------------------------------------

    def write_trace(self, path: Optional[str] = None) -> str:
        path = path or self.config.trace_path
        if not path:
            raise ValueError("no trace path: pass one or set "
                             "ObsConfig.trace_path")
        return self.tracer.write(path)

    @contextlib.contextmanager
    def profile(self):
        """Opt-in `jax.profiler.trace` passthrough around a serving run:
        no-op unless ObsConfig.jax_profile_dir is set."""
        if not self.config.jax_profile_dir:
            yield
            return
        import jax
        jax.profiler.start_trace(self.config.jax_profile_dir)
        try:
            yield
        finally:
            jax.profiler.stop_trace()


__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NullTracer",
    "NULL_TRACER", "StepTracer", "ObsConfig", "Observability",
    "DEFAULT_TIME_EDGES", "PHASE_EDGES",
]

# imported last: audit.py needs Observability from this module
from .audit import AuditConfig, ShadowAuditor, audit_hash  # noqa: E402
from .error_model import calibrate, derive_target_rates, relax_mask  # noqa: E402

__all__ += ["AuditConfig", "ShadowAuditor", "audit_hash", "calibrate",
            "derive_target_rates", "relax_mask"]
