"""Lightweight serving-metrics registry.

Three instrument kinds, Prometheus-shaped but dependency-free:

  * Counter   -- monotone float; inc() rejects negative deltas.
  * Gauge     -- last-write-wins float.
  * Histogram -- fixed bucket edges chosen at registration; observe() is a
                 bisect + two adds, and quantile(q) returns a streaming
                 estimate by linear interpolation inside the target bucket
                 (bounded by the observed min/max, so single-bucket
                 distributions do not smear across the whole edge span).

Instruments register by name once; re-registering returns the same object
(so engine re-instantiation in tests/benchmarks cannot double-register) and
re-registering under a different kind raises. A registration with
`labels=(...)` returns a _Family whose `.labels(v1, v2, ...)` children are
memoized by value tuple -- resolve children once outside the hot path and
the per-event cost is one float add; even unresolved, a labels() call is a
single dict lookup.

`snapshot()` renders everything to plain JSON-serializable dicts;
`to_prometheus()` renders the standard text exposition format (counters get
the `_total` convention from their registered name, histograms emit
cumulative `_bucket{le=...}` rows plus `_sum`/`_count`).
"""

from __future__ import annotations

from bisect import bisect_left
from typing import Any, Dict, List, Optional, Sequence, Tuple

# default latency-style edges (seconds): 100us .. ~100s, x4 per bucket
DEFAULT_TIME_EDGES = (1e-4, 4e-4, 1.6e-3, 6.4e-3, 2.56e-2, 0.1024, 0.4096,
                      1.6384, 6.5536, 26.2144, 104.8576)


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increments must be >= 0, got {n}")
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Histogram:
    """Fixed-bucket histogram with cumulative-`le` semantics: bucket i
    counts observations v <= edges[i]; everything above the last edge lands
    in the implicit +Inf bucket."""

    __slots__ = ("edges", "counts", "sum", "count", "vmin", "vmax")

    def __init__(self, edges: Sequence[float]) -> None:
        edges = tuple(float(e) for e in edges)
        if not edges or list(edges) != sorted(set(edges)):
            raise ValueError(
                f"histogram edges must be non-empty and strictly "
                f"increasing, got {edges}")
        self.edges = edges
        self.counts = [0] * (len(edges) + 1)   # [..., +Inf]
        self.sum = 0.0
        self.count = 0
        self.vmin = float("inf")
        self.vmax = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect_left(self.edges, v)] += 1
        self.sum += v
        self.count += 1
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Streaming quantile estimate from the bucket counts.

        Finds the bucket holding the q-th observation and interpolates
        linearly inside it; the first/last populated buckets interpolate
        from the observed min / toward the observed max instead of the raw
        edge span, so estimates never leave [vmin, vmax]."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.edges[i - 1] if i > 0 else self.vmin
                hi = self.edges[i] if i < len(self.edges) else self.vmax
                lo = max(lo, self.vmin)
                hi = min(hi, self.vmax)
                frac = (target - cum) / c
                return lo + (hi - lo) * max(0.0, min(1.0, frac))
            cum += c
        return self.vmax   # pragma: no cover - unreachable (cum == count)


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Family:
    """A labeled metric: children memoized by label-value tuple."""

    __slots__ = ("name", "kind", "label_names", "_edges", "_children")

    def __init__(self, name: str, kind: str, label_names: Tuple[str, ...],
                 edges: Optional[Sequence[float]] = None) -> None:
        self.name = name
        self.kind = kind
        self.label_names = label_names
        self._edges = edges
        self._children: Dict[Tuple[str, ...], Any] = {}

    def labels(self, *values: Any):
        key = tuple(str(v) for v in values)
        child = self._children.get(key)
        if child is None:
            if len(key) != len(self.label_names):
                raise ValueError(
                    f"{self.name} takes labels {self.label_names}, "
                    f"got {key}")
            child = (Histogram(self._edges) if self.kind == "histogram"
                     else _KINDS[self.kind]())
            self._children[key] = child
        return child

    def items(self):
        return self._children.items()


class MetricsRegistry:
    """Name -> instrument map with typed registration and exposition."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Any] = {}
        self._meta: Dict[str, Tuple[str, str, str]] = {}  # kind, help, unit

    # -- registration -------------------------------------------------------

    def _register(self, name: str, kind: str, help: str, unit: str,
                  labels: Tuple[str, ...],
                  edges: Optional[Sequence[float]]):
        m = self._metrics.get(name)
        if m is not None:
            if self._meta[name][0] != kind:
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{self._meta[name][0]}, cannot re-register as {kind}")
            return m
        if labels:
            m = _Family(name, kind, tuple(labels), edges)
        elif kind == "histogram":
            m = Histogram(edges)
        else:
            m = _KINDS[kind]()
        self._metrics[name] = m
        self._meta[name] = (kind, help, unit)
        return m

    def counter(self, name: str, help: str = "", unit: str = "",
                labels: Sequence[str] = ()):
        return self._register(name, "counter", help, unit, tuple(labels),
                              None)

    def gauge(self, name: str, help: str = "", unit: str = "",
              labels: Sequence[str] = ()):
        return self._register(name, "gauge", help, unit, tuple(labels), None)

    def histogram(self, name: str, edges: Sequence[float] = DEFAULT_TIME_EDGES,
                  help: str = "", unit: str = "",
                  labels: Sequence[str] = ()):
        return self._register(name, "histogram", help, unit, tuple(labels),
                              edges)

    def get(self, name: str):
        return self._metrics.get(name)

    # -- exposition ---------------------------------------------------------

    @staticmethod
    def _render(kind: str, m) -> Any:
        if kind == "histogram":
            cum, buckets = 0, {}
            for e, c in zip(m.edges, m.counts):
                cum += c
                buckets[f"{e:g}"] = cum
            buckets["+Inf"] = m.count
            return {"count": m.count, "sum": m.sum, "mean": m.mean,
                    "p50": m.quantile(0.5), "p99": m.quantile(0.99),
                    "buckets": buckets}
        return m.value

    def snapshot(self) -> Dict[str, Any]:
        """Plain-dict view: scalar for unlabeled counters/gauges, nested
        dicts keyed "k=v,..." for families, bucket/summary dicts for
        histograms. JSON-serializable."""
        out: Dict[str, Any] = {}
        for name, m in self._metrics.items():
            kind = self._meta[name][0]
            if isinstance(m, _Family):
                out[name] = {
                    ",".join(f"{k}={v}" for k, v in zip(m.label_names, key)):
                    self._render(kind, child) for key, child in m.items()}
            else:
                out[name] = self._render(kind, m)
        return out

    def to_prometheus(self) -> str:
        """Standard text exposition format."""
        lines: List[str] = []
        for name, m in self._metrics.items():
            kind, help, unit = self._meta[name]
            if help:
                lines.append(f"# HELP {name} {help}"
                             + (f" ({unit})" if unit else ""))
            lines.append(f"# TYPE {name} {kind}")
            fams = m.items() if isinstance(m, _Family) else [((), m)]
            names = m.label_names if isinstance(m, _Family) else ()
            for key, child in fams:
                lbl = ",".join(f'{k}="{v}"' for k, v in zip(names, key))
                if kind == "histogram":
                    cum = 0
                    for e, c in zip(child.edges, child.counts):
                        cum += c
                        le = (lbl + "," if lbl else "") + f'le="{e:g}"'
                        lines.append(f"{name}_bucket{{{le}}} {cum}")
                    le = (lbl + "," if lbl else "") + 'le="+Inf"'
                    lines.append(f"{name}_bucket{{{le}}} {child.count}")
                    sfx = f"{{{lbl}}}" if lbl else ""
                    lines.append(f"{name}_sum{sfx} {child.sum:g}")
                    lines.append(f"{name}_count{sfx} {child.count}")
                else:
                    sfx = f"{{{lbl}}}" if lbl else ""
                    lines.append(f"{name}{sfx} {child.value:g}")
        return "\n".join(lines) + "\n"
