"""Batched serving loop with LAMP inference.

prefill -> decode loop with temperature sampling, continuous logging of the
LAMP recompute rate, and the optional `logits` LAMP site (the final
unembed -> sampling-softmax composition, the serving analogue of the paper's
KQ site -- used for the attention-free rwkv6).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import lamp as L
from repro.core.mixed_matmul import dot_ps
from repro.models import api


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 = greedy
    seed: int = 0
    use_lamp: bool = True
    cache_len: int = 512
    top_k: int = 0               # 0 = unfiltered


def _sample(logits, key, temperature, top_k: int = 0):
    """Routed through the shared serving sampler so this loop and the
    continuous-batching engine cannot diverge on temperature/top-k
    semantics again (greedy at temp <= 0, Gumbel-max otherwise -- the
    Gumbel-max draw is bit-identical to the categorical() this used)."""
    from repro.serving import sampling
    return sampling.sample(logits, key, temperature, top_k=top_k)


# jitted decode closures keyed on (cfg, use_lamp): repeated generate() calls
# (and the serving engine's static-batch baseline) must not recompile.
_DECODE_CACHE: Dict[Any, Any] = {}


def decode_fn(cfg, use_lamp: bool):
    fn = _DECODE_CACHE.get((cfg, use_lamp))
    if fn is None:
        fn = jax.jit(lambda p, c, t: api.decode_step(
            cfg, p, c, t, use_lamp=use_lamp))
        _DECODE_CACHE[(cfg, use_lamp)] = fn
    return fn


def generate(cfg, params, batch: Dict[str, Any], serve: ServeConfig,
             ) -> Dict[str, Any]:
    """batch: prompt dict (tokens (B, S) + stub modality inputs)."""
    B = batch["tokens"].shape[0]
    cache = api.init_cache(cfg, B, serve.cache_len, jnp.float32)
    t0 = time.monotonic()
    logits, cache = api.prefill(cfg, params, batch, cache,
                                use_lamp=serve.use_lamp)
    prefill_s = time.monotonic() - t0
    key = jax.random.PRNGKey(serve.seed)

    decode = decode_fn(cfg, serve.use_lamp)

    key, sub = jax.random.split(key)
    toks = _sample(logits[:, -1], sub, serve.temperature,
                   serve.top_k)[:, None]
    out = [toks]
    t0 = time.monotonic()
    for i in range(serve.max_new_tokens - 1):
        key, sub = jax.random.split(key)
        logits, cache = decode(params, cache, toks)
        toks = _sample(logits[:, -1], sub, serve.temperature,
                       serve.top_k)[:, None]
        out.append(toks)
    decode_s = time.monotonic() - t0
    tokens = jnp.concatenate(out, axis=1)
    return {
        "tokens": tokens,
        "prefill_s": prefill_s,
        "decode_tok_per_s": B * (serve.max_new_tokens - 1) / max(decode_s, 1e-9),
    }


def lamp_logits_softmax(logits: jnp.ndarray, mu: int, tau: float):
    """LAMP at the LM-head site: treat the unembed matmul's output as y and
    the sampling softmax as f; rule (8) flags the entries whose rounding
    error shifts the sampling distribution. Simulation helper used by the
    rwkv6 serving benchmark (the arch has no attention softmax)."""
    from repro.core.numerics import round_to_mantissa
    y_low = round_to_mantissa(logits.astype(jnp.float32), mu)
    mask = L.select_softmax_strict(y_low, tau)
    y = jnp.where(mask, logits.astype(jnp.float32), y_low)
    return L.masked_softmax(y), jnp.mean(mask.astype(jnp.float32))
