"""Fault-tolerant training loop.

Integrates the substrates: sharded train_step, deterministic resumable data,
async atomic checkpointing, straggler monitoring, optional gradient
compression, preemption-signal handling. Runs identically on the 1-device
CPU mesh (tests/examples) and a production mesh (device placement comes from
the same sharding rules the dry-run validates).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import DataConfig, SyntheticDataset
from repro.distributed import sharding as SH
from repro.distributed.straggler import StragglerMonitor, StragglerPolicy
from repro.launch import steps as ST
from repro.models import api
from repro.optim import adamw


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    seed: int = 0
    num_microbatches: int = 1
    attn_impl: str = "auto"


class PreemptionGuard:
    """Converts SIGTERM/SIGINT into a graceful save-and-exit flag."""

    def __init__(self, install: bool = True):
        self.requested = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._handler)
            except ValueError:   # not main thread (tests)
                pass

    def _handler(self, signum, frame):
        self.requested = True


def train(cfg, mesh, loop: TrainLoopConfig,
          opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
          data_cfg: Optional[DataConfig] = None,
          extra_batch: Optional[Callable[[int], Dict[str, Any]]] = None,
          ) -> Dict[str, Any]:
    """Train `cfg` on `mesh`. Resumes from the latest checkpoint if present.

    `extra_batch(step)` supplies stub modality inputs (frames/image_embeds)
    for whisper/llava families.
    """
    data_cfg = data_cfg or DataConfig(vocab=cfg.vocab, seq_len=128,
                                      global_batch=8, seed=loop.seed)
    data = SyntheticDataset(data_cfg)
    mgr = CheckpointManager(loop.checkpoint_dir, keep_last=loop.keep_last)
    guard = PreemptionGuard()
    monitor = StragglerMonitor(StragglerPolicy())

    p_shape = jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(loop.seed)))
    o_shape = jax.eval_shape(adamw.init_state, p_shape)
    pspecs = SH.param_specs(p_shape, mesh)
    ospecs = SH.opt_specs(o_shape, pspecs)

    start_step = mgr.latest_step()
    if start_step is not None:
        state = mgr.restore({"params": p_shape, "opt": o_shape},
                            shardings={"params": pspecs, "opt": ospecs})
        params, opt_state = state["params"], state["opt"]
        print(f"[train] resumed from step {start_step}")
        start_step += 1
    else:
        with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
            params = jax.jit(
                lambda k: api.init_params(cfg, k),
                out_shardings=pspecs)(jax.random.PRNGKey(loop.seed))
            opt_state = jax.jit(adamw.init_state, out_shardings=ospecs)(params)
        start_step = 0

    step_fn = ST.make_train_step(cfg, opt_cfg,
                                 num_microbatches=loop.num_microbatches,
                                 attn_impl=loop.attn_impl)
    jitted = jax.jit(step_fn,
                     in_shardings=(pspecs, ospecs, None),
                     out_shardings=(pspecs, ospecs, None),
                     donate_argnums=(0, 1))

    metrics_hist = []
    with mesh:
        for step in range(start_step, loop.total_steps):
            t0 = time.monotonic()
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
            if extra_batch is not None:
                batch.update(extra_batch(step))
            params, opt_state, metrics = jitted(params, opt_state, batch)
            metrics = jax.device_get(metrics)
            dt = time.monotonic() - t0
            action = monitor.record_step(dt)
            if step % loop.log_every == 0:
                print(f"[train] step={step} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
            metrics_hist.append({k: float(v) for k, v in metrics.items()})
            want_ckpt = (step + 1) % loop.checkpoint_every == 0
            if action == "checkpoint_and_replace" or guard.requested or want_ckpt:
                mgr.save(step, {"params": params, "opt": opt_state})
                if guard.requested:
                    print(f"[train] preemption: checkpointed at {step}, exiting")
                    break
        mgr.save(loop.total_steps - 1, {"params": params, "opt": opt_state},
                 blocking=True)
    mgr.wait()
    return {"params": params, "opt": opt_state, "metrics": metrics_hist,
            "monitor_events": monitor.events}
