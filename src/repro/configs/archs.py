"""The 10 assigned architectures (exact published dims) + GPT-2 family."""

from repro.core.policy import LampPolicy

from .base import ModelConfig, register


@register("whisper-medium")
def whisper_medium() -> ModelConfig:
    # [arXiv:2212.04356; unverified] enc-dec, conv frontend stubbed.
    return ModelConfig(
        name="whisper-medium", family="whisper",
        n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab=51865, act="gelu", norm="layernorm", pos="learned",
        enc_seq=1500, max_seq=33792,
        source="arXiv:2212.04356",
    )


@register("qwen3-moe-30b-a3b")
def qwen3_moe() -> ModelConfig:
    # [hf:Qwen/Qwen3-30B-A3B; hf] 128 experts top-8, GQA kv=4, qk-norm.
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=768, vocab=151936, act="swiglu", norm="rmsnorm", pos="rope",
        rope_theta=1e6, qk_norm=True, n_experts=128, top_k=8,
        max_seq=40960, source="hf:Qwen/Qwen3-30B-A3B",
    )


@register("olmoe-1b-7b")
def olmoe() -> ModelConfig:
    # [arXiv:2409.02060; hf] 64 experts top-8.
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab=50304, act="swiglu", norm="rmsnorm", pos="rope",
        qk_norm=True, n_experts=64, top_k=8,
        max_seq=4096, source="arXiv:2409.02060",
    )


@register("gemma-7b")
def gemma_7b() -> ModelConfig:
    # [arXiv:2403.08295; hf] GeGLU, head_dim=256, tied + scaled embeddings.
    return ModelConfig(
        name="gemma-7b", family="dense",
        n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
        d_ff=24576, vocab=256000, act="geglu", norm="rmsnorm", pos="rope",
        tie_embeddings=True, scale_embed=True,
        max_seq=8192, source="arXiv:2403.08295",
    )


@register("starcoder2-15b")
def starcoder2() -> ModelConfig:
    # [arXiv:2402.19173; hf] GQA kv=4, RoPE, LayerNorm + GELU.
    return ModelConfig(
        name="starcoder2-15b", family="dense",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
        d_ff=24576, vocab=49152, act="gelu", norm="layernorm", pos="rope",
        rope_theta=1e5, max_seq=16384, source="arXiv:2402.19173",
    )


@register("glm4-9b")
def glm4() -> ModelConfig:
    # [hf:THUDM/glm-4-9b; hf] GQA kv=2, partial rotary (0.5).
    return ModelConfig(
        name="glm4-9b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab=151552, act="swiglu", norm="rmsnorm", pos="rope",
        rope_fraction=0.5, max_seq=131072, source="hf:THUDM/glm-4-9b",
    )


@register("mistral-large-123b")
def mistral_large() -> ModelConfig:
    # [hf:mistralai/Mistral-Large-Instruct-2407; unverified] GQA kv=8.
    return ModelConfig(
        name="mistral-large-123b", family="dense",
        n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
        d_ff=28672, vocab=32768, act="swiglu", norm="rmsnorm", pos="rope",
        rope_theta=1e6, max_seq=131072,
        source="hf:mistralai/Mistral-Large-Instruct-2407",
    )


@register("llava-next-mistral-7b")
def llava_next() -> ModelConfig:
    # [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] anyres tiling
    # stubbed: input_specs() supplies 576 base-grid patch embeddings.
    return ModelConfig(
        name="llava-next-mistral-7b", family="llava",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000, act="swiglu", norm="rmsnorm", pos="rope",
        n_patches=576, max_seq=32768,
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    )


@register("hymba-1.5b")
def hymba_15b() -> ModelConfig:
    # [arXiv:2411.13676; hf] parallel attn+mamba heads, SWA, meta tokens.
    return ModelConfig(
        name="hymba-1.5b", family="hymba",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
        d_ff=5504, vocab=32001, act="swiglu", norm="rmsnorm", pos="rope",
        ssm_state=16, window=1024, n_meta_tokens=128,
        max_seq=8192, source="arXiv:2411.13676",
    )


@register("rwkv6-7b")
def rwkv6() -> ModelConfig:
    # [arXiv:2404.05892; hf] Finch: attention-free, data-dependent decay.
    return ModelConfig(
        name="rwkv6-7b", family="rwkv6",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
        d_ff=14336, vocab=65536, act="relu2", norm="layernorm", pos="none",
        lamp=LampPolicy.disabled(),  # KQ-LAMP inapplicable (DESIGN.md Sec 6)
        max_seq=4096, source="arXiv:2404.05892",
    )


# --- GPT-2 family for the paper's own experiments (Sec 4, App C) -----------

@register("gpt2")
def gpt2() -> ModelConfig:
    """Alias for the paper's default GPT-2 small setting."""
    return gpt2_small().replace(name="gpt2")


@register("gpt2-small")
def gpt2_small() -> ModelConfig:
    return ModelConfig(
        name="gpt2-small", family="gpt2",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab=50257, act="gelu", norm="layernorm", pos="learned",
        tie_embeddings=True, max_seq=1024, dtype="float32",
        lamp=LampPolicy.paper_default(), source="gpt2",
    )


@register("gpt2-xl")
def gpt2_xl() -> ModelConfig:
    return ModelConfig(
        name="gpt2-xl", family="gpt2",
        n_layers=48, d_model=1600, n_heads=25, n_kv_heads=25,
        d_ff=6400, vocab=50257, act="gelu", norm="layernorm", pos="learned",
        tie_embeddings=True, max_seq=1024, dtype="float32",
        lamp=LampPolicy.paper_default(), source="gpt2",
    )
