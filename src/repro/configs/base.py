"""Config system: model configs, input shapes, and the arch registry."""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

from repro.core.policy import LampPolicy


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config object for every architecture family.

    Family selects the block structure:
      dense   -- decoder-only transformer (GQA + MLP)
      moe     -- decoder-only with MoE FFN (top-k router)
      gpt2    -- GPT-2 (LayerNorm, learned pos, MHA) for the paper repro
      llava   -- dense backbone + patch-embedding frontend stub
      whisper -- encoder-decoder + frame-embedding frontend stub
      hymba   -- hybrid: parallel attention (SWA) + Mamba heads per layer
      rwkv6   -- attention-free RWKV-6 "Finch"
    """
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    act: str = "swiglu"              # gelu | geglu | swiglu | relu2
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    pos: str = "rope"                # rope | learned | none
    rope_theta: float = 1e4
    rope_fraction: float = 1.0       # glm4 applies RoPE to half the head dim
    qk_norm: bool = False            # qwen3/olmoe RMS-norm on q,k heads
    tie_embeddings: bool = False
    scale_embed: bool = False        # gemma: embeddings * sqrt(d)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    window: Optional[int] = None     # sliding-window attention
    n_meta_tokens: int = 0           # hymba learnable meta tokens
    # encoder-decoder
    n_enc_layers: int = 0
    enc_seq: int = 0                 # whisper: 1500 frame embeddings (stub)
    # vlm
    n_patches: int = 0               # llava: patch tokens from the stub frontend
    max_seq: int = 8192              # learned-pos table size
    dtype: str = "bfloat16"
    lamp: LampPolicy = dataclasses.field(default_factory=LampPolicy.deployment)
    source: str = ""                 # provenance note

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "rwkv6"

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve 500k-token contexts? (SSM / hybrid-SWA only)"""
        return self.family in ("rwkv6", "hymba")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """(runnable, reason-if-not). long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 500k-token KV cache/attention is "
                       "quadratic -- skipped per assignment (DESIGN.md Sec 6)")
    return True, ""


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    return sorted(_REGISTRY)


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            vocab: int = 512) -> ModelConfig:
    """Shrink a config to CPU-smoke scale, preserving family features."""
    heads = max(2, min(cfg.n_heads, 4))
    kv = max(1, min(cfg.n_kv_heads, heads))
    hd = max(8, d_model // heads)
    kw = dict(
        n_layers=min(cfg.n_layers, layers),
        d_model=d_model,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=hd,
        d_ff=d_model * 2,
        vocab=vocab,
        max_seq=512,
        dtype="float32",
    )
    if cfg.n_experts:
        kw.update(n_experts=min(cfg.n_experts, 8), top_k=min(cfg.top_k, 2))
    if cfg.n_enc_layers:
        kw.update(n_enc_layers=min(cfg.n_enc_layers, layers))
    if cfg.enc_seq:
        kw.update(enc_seq=16)
    if cfg.n_patches:
        kw.update(n_patches=8)
    if cfg.window:
        kw.update(window=32)
    if cfg.n_meta_tokens:
        kw.update(n_meta_tokens=4)
    return cfg.replace(name=cfg.name + "-reduced", **kw)
