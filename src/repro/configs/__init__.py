"""Arch registry: one config per assigned architecture (+ GPT-2 repro).

Every config carries its provenance tag from the assignment table. Dims are
the published ones; simplifications (biases dropped, partial-rotary, stub
frontends) are noted in DESIGN.md Sec 6/7.
"""

from .base import (
    ModelConfig,
    InputShape,
    SHAPES,
    shape_applicable,
    get_config,
    list_archs,
    reduced,
    register,
)
from . import archs  # noqa: F401  (populates the registry)

ASSIGNED_ARCHS = [
    "whisper-medium",
    "qwen3-moe-30b-a3b",
    "olmoe-1b-7b",
    "gemma-7b",
    "starcoder2-15b",
    "glm4-9b",
    "mistral-large-123b",
    "llava-next-mistral-7b",
    "hymba-1.5b",
    "rwkv6-7b",
]
