"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent
per-channel decay. Implements time-mix (wkv recurrence) + channel-mix (relu^2)
blocks with token-shift and LoRA-style data-dependent interpolation.

LAMP applicability (DESIGN.md Sec 6): RWKV has no token softmax, so the
paper's KQ rule does not apply. Two LAMP sites remain: (a) the Sec 3.1
activation rule -- note relu^2 has constant condition number 2 (phi' y / phi
= 2 for y > 0), so LAMP selection there degenerates to all-or-nothing; (b)
the final logits -> sampling-softmax composition, handled by the serving
layer's `logits` site. The architecture is therefore implemented WITHOUT
KQ-LAMP, as required by the assignment.

Recurrence (per head h, head dim n=64):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          S in R^{n x n}
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import layers as LY

LORA_R = 32
HEAD_DIM = 64


def _n_heads(cfg) -> int:
    return cfg.d_model // HEAD_DIM


def block_params(cfg, key) -> Dict[str, Any]:
    d = cfg.d_model
    dt = LY.dtype_of(cfg)
    H = _n_heads(cfg)
    ks = jax.random.split(key, 16)
    sc = d ** -0.5

    def lin(k, m, n, s=None):
        return (jax.random.normal(k, (m, n)) * (s or m ** -0.5)).astype(dt)

    return {
        "ln1_w": jnp.ones((d,), dt), "ln1_b": jnp.zeros((d,), dt),
        "ln2_w": jnp.ones((d,), dt), "ln2_b": jnp.zeros((d,), dt),
        # time-mix
        "tm_mu": (jax.random.uniform(ks[0], (5, d))).astype(dt),  # r,k,v,w,g
        "tm_lora_down": lin(ks[1], d, LORA_R),
        "tm_lora_up": (jax.random.normal(ks[2], (5, LORA_R, d)) * LORA_R ** -0.5).astype(dt),
        "w_base": (jax.random.normal(ks[3], (d,)) * 0.5 - 6.0).astype(dt),
        "w_lora_down": lin(ks[4], d, LORA_R),
        "w_lora_up": lin(ks[5], LORA_R, d),
        "u": (jax.random.normal(ks[6], (H, HEAD_DIM)) * 0.1).astype(dt),
        "wr": lin(ks[7], d, d, sc), "wk": lin(ks[8], d, d, sc),
        "wv": lin(ks[9], d, d, sc), "wg": lin(ks[10], d, d, sc),
        "wo": lin(ks[11], d, d, sc),
        "ln_x": jnp.ones((d,), dt),
        # channel-mix
        "cm_mu": (jax.random.uniform(ks[12], (2, d))).astype(dt),  # r,k
        "cm_wk": lin(ks[13], d, cfg.d_ff, sc),
        "cm_wv": lin(ks[14], cfg.d_ff, d, cfg.d_ff ** -0.5),
        "cm_wr": lin(ks[15], d, d, sc),
    }


def init_params(cfg, key) -> Dict[str, Any]:
    k_emb, k_blocks = jax.random.split(key)
    blocks = jax.vmap(lambda k: block_params(cfg, k))(
        jax.random.split(k_blocks, cfg.n_layers))
    d, dt = cfg.d_model, LY.dtype_of(cfg)
    return {
        "embed": LY.embed_params(cfg, k_emb),
        "blocks": blocks,
        "lnf_w": jnp.ones((d,), dt), "lnf_b": jnp.zeros((d,), dt),
    }


def _ddlerp(p, x, x_prev):
    """Data-dependent interpolation of Finch: 5 mixed streams (r,k,v,w,g)."""
    base = x + (x_prev - x) * p["tm_mu"][:, None, None, :]          # (5,B,T,d)
    lora = jnp.tanh(x @ p["tm_lora_down"])                          # (B,T,R)
    dyn = jnp.einsum("btr,srd->sbtd", lora, p["tm_lora_up"])
    mix = jnp.clip(p["tm_mu"][:, None, None, :] + dyn, 0.0, 1.0)
    return x + (x_prev - x) * mix, base  # use dynamic mix; base unused


def _wkv_scan(rf, kf, vf, w, u, S0):
    """Paper-faithful per-timestep recurrence (baseline). (B,T,H,n) inputs."""
    def step(S, xs):
        r_t, k_t, v_t, w_t = xs                                      # (B,H,n)
        kv = k_t[..., :, None] * v_t[..., None, :]                   # (B,H,n,n)
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, w))
    S, ys = jax.lax.scan(step, S0, xs)
    return S, jnp.moveaxis(ys, 0, 1)


def _wkv_chunked(rf, kf, vf, w, u, S0, chunk: int):
    """Chunked WKV recurrence (beyond-paper perf path; EXPERIMENTS Sec Perf).

    The state is carried once per `chunk` steps instead of every step
    (HBM state traffic / chunk); intra-block interactions use explicit
    pairwise decay coefficients exp(L_{t-1} - L_s) for s < t, which are
    ALWAYS <= 1 (decay products over (s, t-1]), so the formulation is
    numerically safe for any decay magnitude -- no 1/P division blowups.
    Exactly equal to the step scan in exact arithmetic.
    """
    B, T, H, n = rf.shape
    C = chunk
    nb = -(-T // C)
    pad = nb * C - T
    if pad:
        padw = [(0, 0), (0, pad), (0, 0), (0, 0)]
        rf = jnp.pad(rf, padw)
        kf = jnp.pad(kf, padw)
        vf = jnp.pad(vf, padw)
        w = jnp.pad(w, padw, constant_values=1.0)   # decay 1 = no-op
    from repro.distributed.sharding import shard_hint
    blk = lambda t: shard_hint(jnp.moveaxis(t.reshape(B, nb, C, H, n), 1, 0),
                               None, "batch", None, "model", None)
    tri = jnp.tril(jnp.ones((C, C), bool), -1)       # strict lower: s < t

    def block(S, xs):
        rc, kc, vc, wc = xs                           # (B,C,H,n)
        # clamp in log space: 1e-38 is subnormal and flushes to 0 on some
        # backends, and log(0) = -inf poisons Lprev = L - logw with NaN.
        logw = jnp.maximum(jnp.log(jnp.maximum(wc, 1e-30)), -60.0)
        L = jnp.cumsum(logw, axis=1)                  # L_t = sum_{u<=t} log w_u
        Lprev = L - logw                              # L_{t-1}
        # inter-block: y_t += (r_t * exp(L_{t-1})) . S       [coeff <= 1]
        y_inter = jnp.einsum("bthi,bhij->bthj", rc * jnp.exp(Lprev), S)
        # intra-block: Att[t,s] = sum_i r_ti k_si exp(L_{t-1,i} - L_{s,i})
        D = Lprev[:, :, None] - L[:, None, :]         # (B,C,C,H,n), <= 0 on tril
        E = jnp.where(tri[None, :, :, None, None], jnp.exp(D), 0.0)
        att = jnp.einsum("bthi,bshi,btshi->btsh", rc, kc, E)
        y_intra = jnp.einsum("btsh,bshj->bthj", att, vc)
        # current-step bonus: y_t += (r_t . (u * k_t)) v_t
        coeff = jnp.einsum("bthi,hi,bthi->bth", rc, u, kc)
        y = y_inter + y_intra + coeff[..., None] * vc
        # state: S' = exp(L_C) * S + sum_s (exp(L_C - L_s) * k_s)^T v_s
        k_eff = kc * jnp.exp(L[:, -1][:, None] - L)   # coeff <= 1
        S = jnp.exp(L[:, -1])[..., None] * S + \
            jnp.einsum("bshi,bshj->bhij", k_eff, vc)
        return shard_hint(S, "batch", "model", None, None), y

    # remat: recompute exp(D)/E in the backward pass instead of stacking a
    # (nb, B, C, C, H, n) residual across blocks (EXPERIMENTS Sec Perf)
    block = jax.checkpoint(block, prevent_cse=False)
    S0 = shard_hint(S0, "batch", "model", None, None)
    S, ys = jax.lax.scan(block, S0, (blk(rf), blk(kf), blk(vf), blk(w)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nb * C, H, n)
    return S, y[:, :T]


def time_mix(cfg, p, x, state, *, wkv_chunk: int = 0):
    """x: (B,T,d); state: {'S': (B,H,n,n), 'x_prev': (B,d)}."""
    B, T, d = x.shape
    H = _n_heads(cfg)
    n = HEAD_DIM
    x_prev = jnp.concatenate([state["x_prev"][:, None, :], x[:, :-1]], axis=1)
    mixed, _ = _ddlerp(p, x, x_prev)
    xr, xk, xv, xw, xg = mixed
    r = (xr @ p["wr"]).reshape(B, T, H, n)
    k = (xk @ p["wk"]).reshape(B, T, H, n)
    v = (xv @ p["wv"]).reshape(B, T, H, n)
    g = jax.nn.silu((xg @ p["wg"]).astype(jnp.float32))
    w_log = p["w_base"].astype(jnp.float32) + \
        (jnp.tanh(xw @ p["w_lora_down"]) @ p["w_lora_up"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log)).reshape(B, T, H, n)                # decay in (0,1)
    u = p["u"].astype(jnp.float32)

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    wf = w.astype(jnp.float32)
    from repro.core.attention import baseline_mode
    if baseline_mode():
        wkv_chunk = 0
    if wkv_chunk and T > 1:
        S, ys = _wkv_chunked(rf, kf, vf, wf, u,
                             state["S"].astype(jnp.float32), wkv_chunk)
    else:
        S, ys = _wkv_scan(rf, kf, vf, wf, u, state["S"].astype(jnp.float32))
    y = ys.reshape(B, T, d)                                          # (B,T,d)
    # per-head group norm
    yh = y.reshape(B, T, H, n)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(B, T, d) * p["ln_x"].astype(jnp.float32)
    out = ((y * g).astype(x.dtype)) @ p["wo"]
    new_state = {"S": S.astype(state["S"].dtype), "x_prev": x[:, -1, :]}
    return out, new_state


def channel_mix(p, x, state):
    x_prev = jnp.concatenate([state[:, None, :], x[:, :-1]], axis=1)
    xk = x + (x_prev - x) * p["cm_mu"][0][None, None, :]
    xr = x + (x_prev - x) * p["cm_mu"][1][None, None, :]
    k = jax.nn.relu((xk @ p["cm_wk"]).astype(jnp.float32))
    k = (k * k).astype(x.dtype)
    return jax.nn.sigmoid((xr @ p["cm_wr"]).astype(jnp.float32)).astype(x.dtype) \
        * (k @ p["cm_wv"]), x[:, -1, :]


def block_apply(cfg, p, x, state, *, wkv_chunk: int = 0):
    h = LY.layer_norm(x, p["ln1_w"], p["ln1_b"])
    a, tm_state = time_mix(cfg, p, h, {"S": state["S"], "x_prev": state["tm_x"]},
                           wkv_chunk=wkv_chunk)
    x = x + a
    h = LY.layer_norm(x, p["ln2_w"], p["ln2_b"])
    c, cm_x = channel_mix(p, h, state["cm_x"])
    x = x + c
    return x, {"S": tm_state["S"], "tm_x": tm_state["x_prev"], "cm_x": cm_x}


def init_state(cfg, batch: int, dtype=jnp.float32) -> Dict[str, Any]:
    H, n, d, L = _n_heads(cfg), HEAD_DIM, cfg.d_model, cfg.n_layers
    dt = LY.dtype_of(cfg)
    return {
        "S": jnp.zeros((L, batch, H, n, n), dtype),
        "tm_x": jnp.zeros((L, batch, d), dt),
        "cm_x": jnp.zeros((L, batch, d), dt),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def forward(cfg, params, tokens, *, state=None, remat: bool = False,
            wkv_chunk: int = 0, **_):
    """Full-sequence forward. Returns (logits, new_state, aux)."""
    B, S = tokens.shape
    x = LY.embed(cfg, params["embed"], tokens, jnp.arange(S))
    if state is None:
        state = init_state(cfg, B)

    def body(carry, xs):
        xc = carry
        p_l, st_l = xs
        y, st = block_apply(cfg, p_l, xc, st_l, wkv_chunk=wkv_chunk)
        return y, st
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    st_in = {"S": state["S"], "tm_x": state["tm_x"], "cm_x": state["cm_x"]}
    x, st_out = jax.lax.scan(body, x, (params["blocks"], st_in))
    x = LY.layer_norm(x, params["lnf_w"], params["lnf_b"])
    logits = LY.unembed(cfg, params["embed"], x)
    new_state = {**st_out, "length": state["length"] + S}
    return logits, new_state, {}


def loss_fn(cfg, params, batch, *, remat: bool = True, wkv_chunk: int = 0, **_):
    logits, _, aux = forward(cfg, params, batch["tokens"], remat=remat,
                             wkv_chunk=wkv_chunk)
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = batch["tokens"][:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss, {"loss": loss, **aux}


def prefill(cfg, params, tokens, state=None, *, wkv_chunk: int = 64, **_):
    logits, state, _ = forward(cfg, params, tokens, state=state,
                               wkv_chunk=wkv_chunk)
    return logits[:, -1:], state


def decode_step(cfg, params, state, tokens, **_):
    """tokens (B, 1). Constant-memory decode: one recurrence step per layer."""
    logits, state, _ = forward(cfg, params, tokens, state=state)
    return logits, state
