"""Uniform model API across all families.

    init_params(cfg, key)                     -> params pytree
    loss_fn(cfg, params, batch, **kw)         -> (loss, metrics)   [train]
    forward_logits(cfg, params, batch, **kw)  -> logits             [eval]
    init_cache(cfg, batch_size, max_len)      -> cache/state pytree
    prefill(cfg, params, batch, cache, **kw)  -> (last_logits, cache)
    decode_step(cfg, params, cache, tok, **kw)-> (logits, cache)

`batch` is a dict: tokens (B,S) always; frames (B,enc_seq,d) for whisper;
image_embeds (B,P,d) for llava. Modality frontends are stubs per the
assignment: those arrays arrive precomputed from input_specs().
"""

from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from . import hymba, rwkv, transformer, whisper

_DENSE_FAMILIES = ("dense", "moe", "gpt2", "llava")


def init_params(cfg, key):
    if cfg.family in _DENSE_FAMILIES:
        return transformer.init_params(cfg, key)
    if cfg.family == "rwkv6":
        return rwkv.init_params(cfg, key)
    if cfg.family == "hymba":
        return hymba.init_params(cfg, key)
    if cfg.family == "whisper":
        return whisper.init_params(cfg, key)
    raise ValueError(f"unknown family {cfg.family!r}")


def loss_fn(cfg, params, batch: Dict[str, Any], **kw):
    if cfg.family in _DENSE_FAMILIES:
        return transformer.loss_fn(cfg, params, batch, **kw)
    if cfg.family == "rwkv6":
        kw.pop("use_lamp", None)
        kw.pop("attn_impl", None)
        kw.pop("moe_groups", None)
        return rwkv.loss_fn(cfg, params, batch, **kw)
    if cfg.family == "hymba":
        kw.pop("moe_groups", None)
        return hymba.loss_fn(cfg, params, batch, **kw)
    if cfg.family == "whisper":
        kw.pop("moe_groups", None)
        return whisper.loss_fn(cfg, params, batch, **kw)
    raise ValueError(f"unknown family {cfg.family!r}")


def forward_logits(cfg, params, batch: Dict[str, Any], **kw):
    if cfg.family in _DENSE_FAMILIES:
        logits, _ = transformer.forward(cfg, params, batch["tokens"],
                                        image_embeds=batch.get("image_embeds"),
                                        **kw)
        return logits
    if cfg.family == "rwkv6":
        kw.pop("use_lamp", None)
        kw.pop("attn_impl", None)
        logits, _, _ = rwkv.forward(cfg, params, batch["tokens"], **kw)
        return logits
    if cfg.family == "hymba":
        logits, _, _ = hymba.forward(cfg, params, batch["tokens"], **kw)
        return logits
    if cfg.family == "whisper":
        logits, _ = whisper.forward(cfg, params, batch["tokens"],
                                    frames=batch["frames"], **kw)
        return logits
    raise ValueError(f"unknown family {cfg.family!r}")


def init_cache(cfg, batch_size: int, max_len: int, dtype=jnp.bfloat16):
    if cfg.family in _DENSE_FAMILIES:
        return transformer.init_cache(cfg, batch_size, max_len, dtype)
    if cfg.family == "rwkv6":
        return rwkv.init_state(cfg, batch_size)
    if cfg.family == "hymba":
        return hymba.init_cache(cfg, batch_size, max_len, dtype)
    if cfg.family == "whisper":
        return whisper.init_cache(cfg, batch_size, max_len, dtype)
    raise ValueError(f"unknown family {cfg.family!r}")


def prefill(cfg, params, batch: Dict[str, Any], cache, **kw):
    if cfg.family in _DENSE_FAMILIES:
        return transformer.prefill(cfg, params, batch["tokens"], cache,
                                   image_embeds=batch.get("image_embeds"), **kw)
    if cfg.family == "rwkv6":
        kw.pop("use_lamp", None)
        kw.pop("attn_impl", None)
        return rwkv.prefill(cfg, params, batch["tokens"], cache, **kw)
    if cfg.family == "hymba":
        return hymba.prefill(cfg, params, batch["tokens"], cache, **kw)
    if cfg.family == "whisper":
        return whisper.prefill(cfg, params, batch["tokens"], cache,
                               frames=batch["frames"], **kw)
    raise ValueError(f"unknown family {cfg.family!r}")


def decode_step(cfg, params, cache, tokens, **kw):
    if cfg.family in _DENSE_FAMILIES:
        return transformer.decode_step(cfg, params, cache, tokens, **kw)
    if cfg.family == "rwkv6":
        kw.pop("use_lamp", None)
        return rwkv.decode_step(cfg, params, cache, tokens, **kw)
    if cfg.family == "hymba":
        return hymba.decode_step(cfg, params, cache, tokens, **kw)
    if cfg.family == "whisper":
        return whisper.decode_step(cfg, params, cache, tokens, **kw)
    raise ValueError(f"unknown family {cfg.family!r}")
