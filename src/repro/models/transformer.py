"""Decoder-only transformer LM (dense / MoE / GPT-2 / LLaVA backbone).

Layers are stacked: every block parameter leaf has a leading (L,) axis and the
stack is driven by jax.lax.scan (keeps the lowered HLO size independent of
depth -- essential for 88-layer configs and fast multi-pod compiles).

The LAMP policy is a first-class runtime switch: `use_lamp=True` routes
attention through the LAMP evaluators (strict rule for materialized softmax,
relaxed rule (9) for the online-softmax path) and MoE routing through the
router-LAMP site.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import LampPolicy, LampSite

from . import layers as LY
from . import moe as MOE


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def block_params(cfg, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"attn": LY.attn_params(cfg, ks[0])}
    d = cfg.d_model
    dt = LY.dtype_of(cfg)
    if cfg.norm == "layernorm":
        p["ln1_w"], p["ln1_b"] = jnp.ones((d,), dt), jnp.zeros((d,), dt)
        p["ln2_w"], p["ln2_b"] = jnp.ones((d,), dt), jnp.zeros((d,), dt)
    else:
        p["ln1_w"], p["ln2_w"] = jnp.zeros((d,), dt), jnp.zeros((d,), dt)
    if cfg.family == "moe":
        p["moe"] = MOE.moe_params(cfg, ks[1])
    else:
        p["mlp"] = LY.mlp_params(cfg, ks[1])
    return p


def init_params(cfg, key) -> Dict[str, Any]:
    k_emb, k_blocks, k_f = jax.random.split(key, 3)
    blocks = jax.vmap(lambda k: block_params(cfg, k))(
        jax.random.split(k_blocks, cfg.n_layers))
    p = {"embed": LY.embed_params(cfg, k_emb), "blocks": blocks}
    d, dt = cfg.d_model, LY.dtype_of(cfg)
    if cfg.norm == "layernorm":
        p["lnf_w"], p["lnf_b"] = jnp.ones((d,), dt), jnp.zeros((d,), dt)
    else:
        p["lnf_w"] = jnp.zeros((d,), dt)
    if cfg.family == "llava":
        # frontend stub: projector from (stub) vision embedding space to d.
        p["mm_proj"] = (jax.random.normal(k_f, (d, d)) * d ** -0.5).astype(dt)
    return p


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

class BlockCtx(NamedTuple):
    positions: jnp.ndarray
    lamp_kq: LampSite
    lamp_router: LampSite
    attn_impl: str
    moe_groups: int


def block_apply(cfg, p, x, ctx: BlockCtx):
    # NOTE: a Megatron-style sequence-parallel residual (seq sharded over
    # the model axis between blocks) was tried and REVERTED: it halves the
    # TP all-reduce but the residual all-gathers cost more under the
    # result-bytes traffic metric (EXPERIMENTS Sec Perf, refuted iteration).
    h = LY.apply_norm(cfg, x, p, "ln1")
    a, rate = LY.attention_sublayer(
        cfg, p["attn"], h, positions=ctx.positions, lamp_site=ctx.lamp_kq,
        causal=True, attn_impl=ctx.attn_impl)
    x = x + a
    h = LY.apply_norm(cfg, x, p, "ln2")
    if cfg.family == "moe":
        m, metrics = MOE.moe_dispatch(cfg, p["moe"], h, lamp_site=ctx.lamp_router,
                                      num_groups=ctx.moe_groups)
        aux = {"attn_lamp_rate": rate, **metrics}
    else:
        m = LY.mlp_apply(cfg, p["mlp"], h)
        aux = {"attn_lamp_rate": rate}
    return x + m, aux


def scan_blocks(cfg, blocks, x, ctx: BlockCtx, *, remat: bool = False):
    def body(carry, p_l):
        y, aux = block_apply(cfg, p_l, carry, ctx)
        return y, aux
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, aux = jax.lax.scan(body, x, blocks)
    return x, jax.tree.map(jnp.mean, aux)


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def _ctx(cfg, positions, use_lamp: bool, attn_impl: str, moe_groups: int) -> BlockCtx:
    pol: LampPolicy = cfg.lamp
    off = LampSite(enabled=False)
    return BlockCtx(
        positions=positions,
        lamp_kq=pol.kq if use_lamp and pol.kq.enabled else off,
        lamp_router=pol.router if use_lamp and pol.router.enabled else off,
        attn_impl=attn_impl,
        moe_groups=moe_groups,
    )


def forward(cfg, params, tokens: jnp.ndarray, *,
            image_embeds: Optional[jnp.ndarray] = None,
            use_lamp: bool = False, attn_impl: str = "auto",
            remat: bool = False, moe_groups: int = 1,
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """tokens: (B, S) -> logits (B, S_total, vocab) float32.

    For llava, `image_embeds` (B, P, d) from the stub frontend are projected
    and prepended; logits cover the full (P + S) sequence.
    """
    B, S = tokens.shape
    prefix = 0
    if cfg.family == "llava":
        if image_embeds is None:
            raise ValueError("llava forward requires image_embeds")
        prefix = image_embeds.shape[1]
        img = (image_embeds.astype(LY.dtype_of(cfg)) @ params["mm_proj"])
        positions = jnp.arange(prefix + S)
        x = jnp.concatenate(
            [img, LY.embed(cfg, params["embed"], tokens, positions[prefix:])], axis=1)
    else:
        positions = jnp.arange(S)
        x = LY.embed(cfg, params["embed"], tokens, positions)

    ctx = _ctx(cfg, positions, use_lamp, attn_impl, moe_groups)
    x, aux = scan_blocks(cfg, params["blocks"], x, ctx, remat=remat)
    if cfg.norm == "layernorm":
        x = LY.layer_norm(x, params["lnf_w"], params["lnf_b"])
    else:
        x = LY.rms_norm(x, params["lnf_w"])
    logits = LY.unembed(cfg, params["embed"], x)
    return logits, aux


def loss_fn(cfg, params, batch: Dict[str, jnp.ndarray], *,
            use_lamp: bool = False, attn_impl: str = "auto",
            remat: bool = True, moe_groups: int = 1):
    """Next-token cross entropy. batch: {tokens (B,S), [image_embeds]}."""
    tokens = batch["tokens"]
    logits, aux = forward(cfg, params, tokens,
                          image_embeds=batch.get("image_embeds"),
                          use_lamp=use_lamp, attn_impl=attn_impl,
                          remat=remat, moe_groups=moe_groups)
    if cfg.family == "llava":
        P = batch["image_embeds"].shape[1]
        logits = logits[:, P:]
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    if cfg.family == "moe":
        loss = loss + 0.01 * aux["moe_aux_loss"]
    return loss, {"loss": loss, **aux}


# ---------------------------------------------------------------------------
# KV-cache serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    L, Hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((L, batch, max_len, Hkv, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, Hkv, hd), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def prefill(cfg, params, tokens: jnp.ndarray, cache: Dict[str, Any], *,
            image_embeds: Optional[jnp.ndarray] = None, use_lamp: bool = True,
            attn_impl: str = "auto", moe_groups: int = 1):
    """Run the full prompt, fill the cache, return last-position logits."""
    B, S = tokens.shape
    prefix = 0
    if cfg.family == "llava":
        prefix = image_embeds.shape[1]
        img = image_embeds.astype(LY.dtype_of(cfg)) @ params["mm_proj"]
        positions = jnp.arange(prefix + S)
        x = jnp.concatenate(
            [img, LY.embed(cfg, params["embed"], tokens, positions[prefix:])], axis=1)
    else:
        positions = jnp.arange(S)
        x = LY.embed(cfg, params["embed"], tokens, positions)
    T = prefix + S
    ctx = _ctx(cfg, positions, use_lamp, attn_impl, moe_groups)

    def body(carry, xs):
        xc = carry
        p_l, ck, cv = xs
        h = LY.apply_norm(cfg, xc, p_l, "ln1")
        # compute k/v once here so we can both attend and store them
        q, k, v = LY._project_qkv(cfg, p_l["attn"], h, ctx.positions)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), 0, axis=1)
        H, Hkv = cfg.n_heads, cfg.n_kv_heads
        qh = jnp.swapaxes(q, 1, 2)
        kh = LY._repeat_kv(jnp.swapaxes(k, 1, 2), H // Hkv)
        vh = LY._repeat_kv(jnp.swapaxes(v, 1, 2), H // Hkv)
        from repro.core import attention as CA
        impl = ctx.attn_impl
        if impl == "auto":
            impl = "full" if T <= 2048 else "chunked"
        if impl == "full":
            if ctx.lamp_kq.enabled:
                o, _ = CA.attention_lamp(qh, kh, vh, ctx.lamp_kq, causal=True,
                                         window=cfg.window)
            else:
                o = CA.attention_reference(qh, kh, vh, causal=True, window=cfg.window)
        else:
            if ctx.lamp_kq.enabled:
                site = ctx.lamp_kq if ctx.lamp_kq.rule == "relaxed" \
                    else ctx.lamp_kq.replace(rule="relaxed")
                o, _ = CA.chunked_attention_lamp(qh, kh, vh, site, causal=True,
                                                 window=cfg.window,
                                                 onepass=site.onepass)
            else:
                o = CA.chunked_attention(qh, kh, vh, causal=True, window=cfg.window)
        o = jnp.swapaxes(o, 1, 2).reshape(xc.shape[0], T, -1).astype(xc.dtype)
        xc = xc + o @ p_l["attn"]["wo"]
        h = LY.apply_norm(cfg, xc, p_l, "ln2")
        if cfg.family == "moe":
            m, _ = MOE.moe_dispatch(cfg, p_l["moe"], h, lamp_site=ctx.lamp_router,
                                    num_groups=ctx.moe_groups)
        else:
            m = LY.mlp_apply(cfg, p_l["mlp"], h)
        return xc + m, (ck, cv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
    cache = {"k": ks, "v": vs,
             "length": jnp.full((B,), T, jnp.int32)}
    if cfg.norm == "layernorm":
        x = LY.layer_norm(x, params["lnf_w"], params["lnf_b"])
    else:
        x = LY.rms_norm(x, params["lnf_w"])
    logits = LY.unembed(cfg, params["embed"], x[:, -1:])
    return logits, cache


def decode_step(cfg, params, cache: Dict[str, Any], tokens: jnp.ndarray, *,
                use_lamp: bool = True, moe_dropless: bool = True,
                moe_groups: int = 1):
    """One decode step. tokens: (B, 1). Returns (logits (B,1,V), new cache)."""
    B = tokens.shape[0]
    length = cache["length"]
    x = LY.embed(cfg, params["embed"], tokens, length[:, None])
    pol = cfg.lamp
    site = pol.kq if (use_lamp and pol.kq.enabled) else LampSite(enabled=False)
    r_site = pol.router if (use_lamp and pol.router.enabled) else LampSite(enabled=False)

    def body(carry, xs):
        xc = carry
        p_l, ck, cv = xs
        h = LY.apply_norm(cfg, xc, p_l, "ln1")
        a, ck, cv, _ = LY.attention_decode_sublayer(
            cfg, p_l["attn"], h, cache_k=ck, cache_v=cv, length=length,
            lamp_site=site)
        xc = xc + a
        h = LY.apply_norm(cfg, xc, p_l, "ln2")
        if cfg.family == "moe":
            m, _ = MOE.moe_dispatch(cfg, p_l["moe"], h, lamp_site=r_site,
                                    dropless=moe_dropless, num_groups=moe_groups)
        else:
            m = LY.mlp_apply(cfg, p_l["mlp"], h)
        return xc + m, (ck, cv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
    if cfg.norm == "layernorm":
        x = LY.layer_norm(x, params["lnf_w"], params["lnf_b"])
    else:
        x = LY.rms_norm(x, params["lnf_w"])
    logits = LY.unembed(cfg, params["embed"], x)
    new_cache = {"k": ks, "v": vs, "length": length + 1}
    return logits, new_cache


# ---------------------------------------------------------------------------
# Paged KV-cache serving (block tables over a shared arena)
# ---------------------------------------------------------------------------
#
# Layout: one arena per layer, (L, n_blocks, block_size, Hkv, hd). A sequence
# owns an ordered list of blocks; flat index t within the gathered view of a
# row's block table == absolute token position t, so attention semantics are
# identical to the dense cache (padded tail masked by `lengths`). Block 0 is
# reserved as a null/scratch block: block-table padding points at it and
# padded slots write into it.

def _serving_site(site: LampSite) -> LampSite:
    """The App C.4 'random' control arm needs a resampled key per call and is
    a benchmark-only configuration; serving maps it to the strict rule."""
    if site.enabled and site.rule == "random":
        return site.replace(rule="strict")
    return site


def init_paged_cache(cfg, n_blocks: int, block_size: int,
                     dtype=jnp.bfloat16) -> Dict[str, Any]:
    L, Hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((L, n_blocks, block_size, Hkv, hd), dtype),
        "v": jnp.zeros((L, n_blocks, block_size, Hkv, hd), dtype),
    }


def paged_prefill(cfg, params, tokens: jnp.ndarray, arena: Dict[str, Any],
                  block_tables: jnp.ndarray, lengths: jnp.ndarray, *,
                  use_lamp: bool = True, moe_groups: int = 1,
                  kernel: str = "gather", per_layer: bool = False,
                  taus=None):
    """Prefill a padded batch of prompts into the paged arena.

    tokens: (B, S) left-aligned prompts padded to the bucket length S;
    lengths: (B,) true prompt lengths; block_tables: (B, n_max). Padded rows
    (lengths clamped to >= 1 by the caller) write only into the null block.

    Returns (last_logits (B, 1, V), arena, (n_selected (B,), n_valid (B,)))
    with last_logits taken at each row's final *valid* position and LAMP
    counts attributed per request (padded query rows excluded).

    Implemented as the degenerate window of `paged_prefill_window` (every row
    starts at position 0), so the full-prompt and chunked/prefix-cached
    prefill paths share one computation and stay token-identical.
    """
    starts = jnp.zeros_like(lengths)
    return paged_prefill_window(cfg, params, tokens, arena, block_tables,
                                starts, lengths, use_lamp=use_lamp,
                                moe_groups=moe_groups, kernel=kernel,
                                per_layer=per_layer, taus=taus)


def paged_prefill_window(cfg, params, tokens: jnp.ndarray,
                         arena: Dict[str, Any], block_tables: jnp.ndarray,
                         starts: jnp.ndarray, lengths: jnp.ndarray, *,
                         use_lamp: bool = True, moe_groups: int = 1,
                         kernel: str = "gather", per_layer: bool = False,
                         taus=None):
    """Prefill a *window* of each prompt against an existing block table.

    Row b runs tokens at absolute positions starts[b] .. starts[b] +
    lengths[b] - 1; KV for positions < starts[b] must already be in the
    arena through block_tables[b] (a shared prefix-cache hit or an earlier
    chunk of the same prompt). Queries attend to the gathered arena view --
    the cached prefix plus this window's just-written KV -- so per-position
    outputs are identical to a single full prefill no matter how the prompt
    is split into windows or how much of it came from the cache.

    tokens: (B, W) window tokens, left-aligned, padded to the bucket width W;
    starts: (B,) cached tokens per row (0 = fresh prompt); lengths: (B,)
    valid tokens in this window (>= 1; padded rows use starts=0, lengths=1
    and a null block table, writing only into the null block).

    kernel="gather" (reference) pays a constant gathered width (the full
    block-table span, as in decode): that is what buys the identity
    guarantee, but attention over more keys than the prompt needs costs
    extra FLOPs/bytes when max_model_len >> prompt. kernel="pallas" runs
    the fused paged-attention kernel instead: blocks are DMA'd through the
    block-table index map and fully-masked blocks (past each q-tile's
    causal bound) are skipped, with the same row-wise numerics -- outputs
    stay token-identical to the gather path (differential-tested). Sites
    the kernel does not implement (the "random" control rule) fall back
    to gather.

    Returns (last_logits (B, 1, V), arena, (n_selected (B,), n_valid (B,)))
    with last_logits at each row's final valid *window* position (only
    meaningful for rows whose window completes the prompt) and LAMP counts
    covering the KQ products actually computed in this window. With
    `per_layer=True` the counts keep their layer axis -- (L, B) instead of
    (B,) -- so serving can attribute recompute work per layer per request.
    `taus` is an optional (L,) float32 array of per-layer LAMP thresholds
    overriding the static site tau -- a *traced operand*, so the serving
    policy controller can move thresholds every step without recompiling.
    """
    return paged_mixed_step(cfg, params, tokens, arena, block_tables,
                            starts, lengths, use_lamp=use_lamp,
                            moe_groups=moe_groups, kernel=kernel,
                            per_layer=per_layer, taus=taus,
                            all_logits=False)


def paged_verify_window(cfg, params, tokens: jnp.ndarray,
                        arena: Dict[str, Any], block_tables: jnp.ndarray,
                        starts: jnp.ndarray, lengths: jnp.ndarray, *,
                        use_lamp: bool = True, moe_groups: int = 1,
                        kernel: str = "gather", per_layer: bool = False,
                        taus=None):
    """Multi-query decode-verify step: the speculative verifier.

    Identical computation to `paged_prefill_window` -- row b runs `tokens`
    at absolute positions starts[b] .. starts[b] + lengths[b] - 1 against
    its block table, (re)writing the window's KV into the arena -- but
    returns logits for *every* window position (B, W, V) instead of only
    the last valid one, so the caller can score all k drafted tokens plus
    the bonus position in one batched forward pass. Because the windowed
    path is row-wise over a constant gathered key width (gather) or an
    equivalent fused kernel (pallas), position j's logits are exactly what
    a non-speculative decode step at that position would have produced, and
    the rewritten KV is the selective-recompute-quality KV the plain decode
    path would have cached.

    Returns (logits (B, W, V) float32, arena,
    (n_selected (B,), n_valid (B,))). Logits at positions >= lengths[b]
    are computed over padded queries and must be ignored. `per_layer=True`
    keeps the counts' layer axis: (L, B).
    """
    return paged_mixed_step(cfg, params, tokens, arena, block_tables,
                            starts, lengths, use_lamp=use_lamp,
                            moe_groups=moe_groups, kernel=kernel,
                            per_layer=per_layer, taus=taus,
                            all_logits=True)


def paged_mixed_step(cfg, params, tokens: jnp.ndarray,
                     arena: Dict[str, Any], block_tables: jnp.ndarray,
                     starts: jnp.ndarray, lengths: jnp.ndarray, *,
                     use_lamp: bool = True, moe_groups: int = 1,
                     kernel: str = "gather", per_layer: bool = False,
                     taus=None, all_logits: bool = False):
    """One fused serving step over a *mixed* row batch.

    The unification: a decode row is a width-1 window at starts[b] ==
    cache_len, a chunked-prefill row a width-w window at its cursor, a
    speculative verify row a width-(k+1) window at its rollback point --
    all the same computation `_paged_window_apply` already performs. This
    entry therefore subsumes `paged_decode_step`, `paged_prefill_window`
    and `paged_verify_window`: one jitted launch per engine step, whose
    per-row (start, length) metadata rides into the Pallas kernel as
    scalar-prefetch operands (`qlens`) so every row walks exactly its own
    live KV blocks -- no recompile across role mixes, and the gather branch
    is the bit-for-bit CPU/reference twin of the same signature.

    tokens: (B, W) window tokens left-aligned per row, padded to the bucket
    width W; starts: (B,) tokens already cached per row; lengths: (B,) live
    tokens in this window (1 for decode rows, k+1 for verify rows, the
    chunk width for prefill rows; padded rows use starts=0, lengths=1 and a
    null block table).

    `all_logits=False` returns logits (B, 1, V) at each row's last valid
    window position (the sampling position for prefill-completing and
    decode rows); `all_logits=True` returns (B, W, V) so a speculative
    verifier can score every drafted position. Counts are (n_selected,
    n_valid), each (B,) -- or (L, B) with `per_layer=True`.

    MoE caveat: capacity-based (non-dropless) routing is batch-composition
    dependent, so fused-vs-split token identity is only guaranteed for
    dense families and dropless MoE.
    """
    B = tokens.shape[0]
    x, arena, counts = _paged_window_apply(
        cfg, params, tokens, arena, block_tables, starts, lengths,
        use_lamp=use_lamp, moe_groups=moe_groups, kernel=kernel,
        per_layer=per_layer, taus=taus)
    if not all_logits:
        x = x[jnp.arange(B), jnp.maximum(lengths, 1) - 1][:, None]
    logits = LY.unembed(cfg, params["embed"], x)
    return logits, arena, counts


def _paged_window_apply(cfg, params, tokens, arena, block_tables, starts,
                        lengths, *, use_lamp, moe_groups, kernel,
                        per_layer: bool = False, taus=None):
    """Shared window forward: runs the block stack over one window per row
    and returns the final-norm hidden states (B, W, d), the updated arena,
    and per-row LAMP (n_selected, n_valid) -- summed over layers by
    default, or stacked per layer as (L, B) arrays when `per_layer=True`
    (the scan already produces the layer axis; the flag only skips the
    reduction, so the telemetry costs nothing extra on device).

    `taus` ((L,) float32, optional) carries per-layer KQ thresholds as scan
    operands: layer l's attention uses taus[l] instead of the static
    site.tau, so the serving policy controller can retune thresholds
    between steps without changing the jit cache key."""
    B, W = tokens.shape
    n_max = block_tables.shape[1]
    bs = arena["k"].shape[2]
    positions = starts[:, None] + jnp.arange(W)[None, :]              # (B, W)
    x = LY.embed(cfg, params["embed"], tokens, positions)
    ctx = _ctx(cfg, positions, use_lamp, "full", moe_groups)
    site = _serving_site(ctx.lamp_kq)
    valid_tok = jnp.arange(W)[None, :] < lengths[:, None]             # (B, W)
    blk_idx = jnp.clip(positions // bs, 0, n_max - 1)
    blk = jnp.where(valid_tok,
                    jnp.take_along_axis(block_tables, blk_idx, axis=1), 0)
    off = jnp.where(valid_tok, positions % bs, 0)
    qmask = valid_tok.astype(jnp.float32)
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    from repro.kernels.paged_attention import supports_site
    use_pallas = kernel == "pallas" and supports_site(site)
    if taus is None:
        taus = jnp.full((cfg.n_layers,), float(site.tau), jnp.float32)

    def body(carry, xs):
        xc = carry
        p_l, ck, cv, tau_l = xs
        h = LY.apply_norm(cfg, xc, p_l, "ln1")
        q, k, v = LY._project_qkv(cfg, p_l["attn"], h, positions)
        ck = ck.at[blk, off].set(k.astype(ck.dtype))
        cv = cv.at[blk, off].set(v.astype(cv.dtype))
        qh = jnp.swapaxes(q, 1, 2)
        from repro.core import attention as CA
        if use_pallas:
            from repro.kernels import ops as KOPS
            # per-row qlens = live window widths: the mixed-row convention
            # (decode rows ride as width-1 windows, verify rows as k+1);
            # rows walk only their own live blocks -- bit-identical at live
            # positions to the full-bucket walk (see paged_attention.py)
            o, nsel_rows = KOPS.paged_prefill_attention(
                qh, ck, cv, block_tables, starts, site, tau=tau_l,
                qlens=lengths, window=cfg.window)
            if site.enabled:
                cap = n_max * bs if cfg.window is None else cfg.window
                nval_rows = jnp.clip(positions + 1, 0, cap
                                     ).astype(jnp.float32) * H
                nsel = jnp.sum(nsel_rows * qmask, axis=1)
                nval = jnp.sum(nval_rows * qmask, axis=1)
            else:
                nsel = jnp.zeros((B,), jnp.float32)
                nval = jnp.zeros((B,), jnp.float32)
        else:
            # gather the full per-row view (cached prefix + this window);
            # gathered flat index t == absolute position t, as in decode
            ks = ck[block_tables].reshape(B, n_max * bs, Hkv, hd)
            vs = cv[block_tables].reshape(B, n_max * bs, Hkv, hd)
            kh = LY._repeat_kv(jnp.moveaxis(ks, 2, 1), H // Hkv)
            vh = LY._repeat_kv(jnp.moveaxis(vs, 2, 1), H // Hkv)
            if site.enabled:
                o, aux = CA.attention_lamp(qh, kh, vh, site, causal=True,
                                           window=cfg.window, offset=starts,
                                           reduce=False, tau=tau_l)
                nsel = jnp.sum(aux.n_selected * qmask, axis=1)
                nval = jnp.sum(aux.n_valid * qmask, axis=1)
            else:
                o = CA.attention_reference(qh, kh, vh, causal=True,
                                           window=cfg.window, offset=starts)
                nsel = jnp.zeros((B,), jnp.float32)
                nval = jnp.zeros((B,), jnp.float32)
        o = jnp.swapaxes(o, 1, 2).reshape(xc.shape[0], W, -1).astype(xc.dtype)
        xc = xc + o @ p_l["attn"]["wo"]
        h = LY.apply_norm(cfg, xc, p_l, "ln2")
        if cfg.family == "moe":
            m, _ = MOE.moe_dispatch(cfg, p_l["moe"], h, lamp_site=ctx.lamp_router,
                                    num_groups=ctx.moe_groups)
        else:
            m = LY.mlp_apply(cfg, p_l["mlp"], h)
        return xc + m, (ck, cv, nsel, nval)

    x, (ks, vs, nsel, nval) = jax.lax.scan(
        body, x, (params["blocks"], arena["k"], arena["v"], taus))
    if cfg.norm == "layernorm":
        x = LY.layer_norm(x, params["lnf_w"], params["lnf_b"])
    else:
        x = LY.rms_norm(x, params["lnf_w"])
    if not per_layer:
        nsel, nval = jnp.sum(nsel, axis=0), jnp.sum(nval, axis=0)
    return x, {"k": ks, "v": vs}, (nsel, nval)


def paged_audit_window(cfg, params, tokens, arena, block_tables, starts,
                       lengths, row_mask, *, moe_groups: int = 1,
                       taus=None, top_k: int = 5) -> Dict[str, jnp.ndarray]:
    """Shadow-audit forward: the LAMP serving arm and the FP32 reference arm
    run in lockstep over the same window batch, and only *error telemetry*
    comes back -- never logits to sample from and never an updated arena, so
    calling this can not perturb served tokens (the engine additionally
    passes the arena without donation, leaving the pool buffers untouched).

    Row b replays tokens at absolute positions starts[b] .. starts[b] +
    lengths[b] - 1 against its block table, exactly like
    `paged_mixed_step(kernel="gather")`: decode rows ride as width-1 windows,
    speculative rows as their pre-draft width-1 decode window, prefill rows
    as their chunk window. Three streams per layer:

      * lamp:   the serving computation (LAMP attention, live `taus`),
                carried through the stack -- its KV writes go into a
                functional copy of the arena slice;
      * ref:    the same computation with LAMP disabled (uniform FP32
                attention via `attention_reference`), the high-precision
                oracle, carried separately;
      * shadow: LAMP attention applied to the *ref* carry's input -- its
                divergence from the ref attention isolates layer l's *local*
                KQ-site error, uncontaminated by error inherited from layers
                below (the quantity the componentwise forward-error bound
                composes; see obs/error_model.py).

    `row_mask` (B,) zeroes padded bucket rows out of every reduction.
    Returns a dict of reduced metrics (tiny host transfer):
      kq_err / router_err / cum_err : (L,) mean per-token relative L2 error
        (local KQ-site, local router-site, cumulative hidden-state drift);
      logit_rel / logit_max_abs : (B,) final-position logit error;
      flip : (B,) 1.0 where the greedy argmax token differs;
      topk : (B,) |top-k(lamp) intersect top-k(ref)| / k.
    Per-row entries for padded rows are garbage -- callers slice the live
    prefix. MoE rows also audit the router site; dense families report 0.
    """
    B, W = tokens.shape
    n_max = block_tables.shape[1]
    bs = arena["k"].shape[2]
    positions = starts[:, None] + jnp.arange(W)[None, :]              # (B, W)
    ctx = _ctx(cfg, positions, True, "full", moe_groups)
    site = _serving_site(ctx.lamp_kq)
    r_site = ctx.lamp_router
    off = LampSite(enabled=False)
    valid_tok = jnp.arange(W)[None, :] < lengths[:, None]             # (B, W)
    blk_idx = jnp.clip(positions // bs, 0, n_max - 1)
    blk = jnp.where(valid_tok,
                    jnp.take_along_axis(block_tables, blk_idx, axis=1), 0)
    off_idx = jnp.where(valid_tok, positions % bs, 0)
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if taus is None:
        taus = jnp.full((cfg.n_layers,), float(site.tau), jnp.float32)

    w = valid_tok.astype(jnp.float32) * row_mask.astype(jnp.float32)[:, None]
    wsum = jnp.maximum(jnp.sum(w), 1.0)

    def werr(a, b):
        # per-token relative L2 error over the feature axis, averaged over
        # live tokens of live rows
        af, bf = a.astype(jnp.float32), b.astype(jnp.float32)
        num = jnp.sqrt(jnp.sum((af - bf) ** 2, axis=-1))
        den = jnp.sqrt(jnp.sum(bf ** 2, axis=-1)) + 1e-30
        return jnp.sum((num / den) * w) / wsum

    from repro.core import attention as CA

    def gathered(ck, cv):
        ks = ck[block_tables].reshape(B, n_max * bs, Hkv, hd)
        vs = cv[block_tables].reshape(B, n_max * bs, Hkv, hd)
        kh = LY._repeat_kv(jnp.moveaxis(ks, 2, 1), H // Hkv)
        vh = LY._repeat_kv(jnp.moveaxis(vs, 2, 1), H // Hkv)
        return kh, vh

    def flat(o):
        # (B, H, W, hd) attention layout -> (B, W, H*hd) feature rows
        return jnp.swapaxes(o, 1, 2).reshape(B, W, -1)

    def attn(qh, kh, vh, lamp_site, tau_l):
        if lamp_site.enabled:
            o, _ = CA.attention_lamp(qh, kh, vh, lamp_site, causal=True,
                                     window=cfg.window, offset=starts,
                                     reduce=False, tau=tau_l)
        else:
            o = CA.attention_reference(qh, kh, vh, causal=True,
                                       window=cfg.window, offset=starts)
        return o

    def arm(xc, p_l, ck, cv, lamp_site, tau_l):
        # one residual block of one stream; returns the new carry plus the
        # ref-stream intermediates the shadow computation needs
        h = LY.apply_norm(cfg, xc, p_l, "ln1")
        q, k, v = LY._project_qkv(cfg, p_l["attn"], h, positions)
        ck = ck.at[blk, off_idx].set(k.astype(ck.dtype))
        cv = cv.at[blk, off_idx].set(v.astype(cv.dtype))
        qh = jnp.swapaxes(q, 1, 2)
        kh, vh = gathered(ck, cv)
        o = attn(qh, kh, vh, lamp_site, tau_l)
        xc = xc + flat(o).astype(xc.dtype) @ p_l["attn"]["wo"]
        h2 = LY.apply_norm(cfg, xc, p_l, "ln2")
        if cfg.family == "moe":
            m, _ = MOE.moe_dispatch(cfg, p_l["moe"], h2,
                                    lamp_site=(r_site if lamp_site.enabled
                                               else off),
                                    num_groups=ctx.moe_groups)
        else:
            m = LY.mlp_apply(cfg, p_l["mlp"], h2)
        return xc + m, (qh, kh, vh, o, h2)

    def body(carry, xs):
        x_l, x_r = carry
        p_l, ck, cv, tau_l = xs
        x_l, _ = arm(x_l, p_l, ck, cv, site, tau_l)
        x_r, (qh_r, kh_r, vh_r, o_r, h2_r) = arm(x_r, p_l, ck, cv, off, tau_l)
        # local KQ-site error: LAMP applied to the reference stream's own
        # inputs, against the reference attention on those same inputs
        o_s = attn(qh_r, kh_r, vh_r, site, tau_l)
        kq_err = werr(flat(o_s), flat(o_r))
        if cfg.family == "moe" and r_site.enabled:
            m_s, _ = MOE.moe_dispatch(cfg, p_l["moe"], h2_r, lamp_site=r_site,
                                      num_groups=ctx.moe_groups)
            m_r, _ = MOE.moe_dispatch(cfg, p_l["moe"], h2_r, lamp_site=off,
                                      num_groups=ctx.moe_groups)
            router_err = werr(m_s, m_r)
        else:
            router_err = jnp.float32(0.0)
        cum_err = werr(x_l, x_r)
        return (x_l, x_r), (kq_err, router_err, cum_err)

    x0 = LY.embed(cfg, params["embed"], tokens, positions)
    (x_l, x_r), (kq_err, router_err, cum_err) = jax.lax.scan(
        body, (x0, x0), (params["blocks"], arena["k"], arena["v"], taus))

    def final(x):
        if cfg.norm == "layernorm":
            x = LY.layer_norm(x, params["lnf_w"], params["lnf_b"])
        else:
            x = LY.rms_norm(x, params["lnf_w"])
        x = x[jnp.arange(B), jnp.maximum(lengths, 1) - 1][:, None]
        return LY.unembed(cfg, params["embed"], x)[:, 0].astype(jnp.float32)

    lg_l, lg_r = final(x_l), final(x_r)                               # (B, V)
    diff = lg_l - lg_r
    logit_rel = (jnp.sqrt(jnp.sum(diff ** 2, axis=-1))
                 / (jnp.sqrt(jnp.sum(lg_r ** 2, axis=-1)) + 1e-30))
    logit_max_abs = jnp.max(jnp.abs(diff), axis=-1)
    flip = (jnp.argmax(lg_l, axis=-1)
            != jnp.argmax(lg_r, axis=-1)).astype(jnp.float32)
    k = max(1, min(int(top_k), int(cfg.vocab)))
    _, idx_l = jax.lax.top_k(lg_l, k)
    _, idx_r = jax.lax.top_k(lg_r, k)
    topk = jnp.mean((idx_l[:, :, None] == idx_r[:, None, :]
                     ).any(-1).astype(jnp.float32), axis=-1)
    return {"kq_err": kq_err, "router_err": router_err, "cum_err": cum_err,
            "logit_rel": logit_rel, "logit_max_abs": logit_max_abs,
            "flip": flip, "topk": topk}


def paged_decode_step(cfg, params, arena: Dict[str, Any],
                      block_tables: jnp.ndarray, lengths: jnp.ndarray,
                      tokens: jnp.ndarray, *, use_lamp: bool = True,
                      moe_dropless: bool = True, moe_groups: int = 1,
                      kernel: str = "gather", per_layer: bool = False,
                      taus=None):
    """One continuous-batch decode step over the paged arena.

    tokens: (R, 1) last sampled token per slot; lengths: (R,) cache fill
    (the new token's KV lands at position lengths[r]). kernel selects the
    attention path: "gather" (reference, materializes the block-table span)
    or "pallas" (fused kernel, live blocks only). Returns
    (logits (R, 1, V), arena, (n_selected (R,), n_valid (R,))); counts
    keep their layer axis -- (L, R) -- with `per_layer=True`. `taus`
    ((L,) float32, optional) supplies traced per-layer KQ thresholds --
    see `paged_prefill_window`.
    """
    x = LY.embed(cfg, params["embed"], tokens, lengths[:, None])
    pol = cfg.lamp
    site = _serving_site(pol.kq if (use_lamp and pol.kq.enabled)
                         else LampSite(enabled=False))
    r_site = pol.router if (use_lamp and pol.router.enabled) \
        else LampSite(enabled=False)
    if taus is None:
        taus = jnp.full((cfg.n_layers,), float(site.tau), jnp.float32)

    def body(carry, xs):
        xc = carry
        p_l, ck, cv, tau_l = xs
        h = LY.apply_norm(cfg, xc, p_l, "ln1")
        a, ck, cv, nsel, nval = LY.paged_attention_decode_sublayer(
            cfg, p_l["attn"], h, arena_k=ck, arena_v=cv,
            block_tables=block_tables, lengths=lengths, lamp_site=site,
            kernel=kernel, tau=tau_l)
        xc = xc + a
        h = LY.apply_norm(cfg, xc, p_l, "ln2")
        if cfg.family == "moe":
            m, _ = MOE.moe_dispatch(cfg, p_l["moe"], h, lamp_site=r_site,
                                    dropless=moe_dropless, num_groups=moe_groups)
        else:
            m = LY.mlp_apply(cfg, p_l["mlp"], h)
        return xc + m, (ck, cv, nsel, nval)

    x, (ks, vs, nsel, nval) = jax.lax.scan(
        body, x, (params["blocks"], arena["k"], arena["v"], taus))
    if cfg.norm == "layernorm":
        x = LY.layer_norm(x, params["lnf_w"], params["lnf_b"])
    else:
        x = LY.rms_norm(x, params["lnf_w"])
    logits = LY.unembed(cfg, params["embed"], x)
    if not per_layer:
        nsel, nval = jnp.sum(nsel, axis=0), jnp.sum(nval, axis=0)
    return logits, {"k": ks, "v": vs}, (nsel, nval)
