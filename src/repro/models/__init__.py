"""Model zoo: 10 assigned architectures + GPT-2 family for the paper repro."""

from . import api, hymba, layers, moe, rwkv, transformer, whisper
from .api import (
    init_params,
    loss_fn,
    forward_logits,
    init_cache,
    prefill,
    decode_step,
)
