"""Whisper-medium (arXiv:2212.04356): encoder-decoder speech transformer.

The conv/mel frontend is a STUB per the assignment: `input_specs()` supplies
precomputed frame embeddings (B, enc_seq=1500, d) directly to the encoder.
Encoder: bidirectional self-attention. Decoder: causal self-attention +
cross-attention to the encoder output. LayerNorm + GELU, learned positions,
tied decoder embeddings (as in the released model).

LAMP applies at three softmax sites: encoder self-attn, decoder self-attn,
and cross-attn KQ products.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import LampSite

from . import layers as LY


def _enc_block_params(cfg, key):
    ks = jax.random.split(key, 2)
    d, dt = cfg.d_model, LY.dtype_of(cfg)
    return {
        "ln1_w": jnp.ones((d,), dt), "ln1_b": jnp.zeros((d,), dt),
        "ln2_w": jnp.ones((d,), dt), "ln2_b": jnp.zeros((d,), dt),
        "attn": LY.attn_params(cfg, ks[0]),
        "mlp": LY.mlp_params(cfg, ks[1]),
    }


def _dec_block_params(cfg, key):
    ks = jax.random.split(key, 3)
    d, dt = cfg.d_model, LY.dtype_of(cfg)
    return {
        "ln1_w": jnp.ones((d,), dt), "ln1_b": jnp.zeros((d,), dt),
        "ln2_w": jnp.ones((d,), dt), "ln2_b": jnp.zeros((d,), dt),
        "ln3_w": jnp.ones((d,), dt), "ln3_b": jnp.zeros((d,), dt),
        "attn": LY.attn_params(cfg, ks[0]),
        "xattn": LY.attn_params(cfg, ks[1]),
        "mlp": LY.mlp_params(cfg, ks[2]),
    }


def init_params(cfg, key) -> Dict[str, Any]:
    k_emb, k_enc, k_dec, k_ep = jax.random.split(key, 4)
    d, dt = cfg.d_model, LY.dtype_of(cfg)
    enc = jax.vmap(lambda k: _enc_block_params(cfg, k))(
        jax.random.split(k_enc, cfg.n_enc_layers))
    dec = jax.vmap(lambda k: _dec_block_params(cfg, k))(
        jax.random.split(k_dec, cfg.n_layers))
    return {
        "embed": LY.embed_params(cfg, k_emb),          # decoder tokens (+pos)
        "enc_pos": (jax.random.normal(k_ep, (cfg.enc_seq, d)) * 0.01).astype(dt),
        "enc_blocks": enc,
        "dec_blocks": dec,
        "enc_lnf_w": jnp.ones((d,), dt), "enc_lnf_b": jnp.zeros((d,), dt),
        "lnf_w": jnp.ones((d,), dt), "lnf_b": jnp.zeros((d,), dt),
    }


def encode(cfg, params, frames: jnp.ndarray, *, use_lamp: bool = False,
           attn_impl: str = "auto") -> jnp.ndarray:
    """frames: (B, enc_seq, d) precomputed embeddings (frontend stub)."""
    x = frames.astype(LY.dtype_of(cfg)) + params["enc_pos"][None]
    T = x.shape[1]
    positions = jnp.arange(T)
    site = cfg.lamp.kq if (use_lamp and cfg.lamp.kq.enabled) else LampSite(enabled=False)

    def body(carry, p_l):
        xc = carry
        h = LY.layer_norm(xc, p_l["ln1_w"], p_l["ln1_b"])
        a, _ = LY.attention_sublayer(cfg, p_l["attn"], h, positions=positions,
                                     lamp_site=site, causal=False,
                                     attn_impl=attn_impl)
        xc = xc + a
        h = LY.layer_norm(xc, p_l["ln2_w"], p_l["ln2_b"])
        return xc + LY.mlp_apply(cfg, p_l["mlp"], h), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return LY.layer_norm(x, params["enc_lnf_w"], params["enc_lnf_b"])


def _cross_kv(cfg, p_x, enc_out):
    B, Te, _ = enc_out.shape
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    k = (enc_out @ p_x["wk"]).reshape(B, Te, Hkv, hd)
    v = (enc_out @ p_x["wv"]).reshape(B, Te, Hkv, hd)
    return k, v


def decode_full(cfg, params, tokens: jnp.ndarray, enc_out: jnp.ndarray, *,
                use_lamp: bool = False, attn_impl: str = "auto",
                remat: bool = False):
    """Teacher-forced decoder over the full token sequence."""
    B, S = tokens.shape
    positions = jnp.arange(S)
    x = LY.embed(cfg, params["embed"], tokens, positions)
    site = cfg.lamp.kq if (use_lamp and cfg.lamp.kq.enabled) else LampSite(enabled=False)

    def body(carry, p_l):
        xc = carry
        h = LY.layer_norm(xc, p_l["ln1_w"], p_l["ln1_b"])
        a, _ = LY.attention_sublayer(cfg, p_l["attn"], h, positions=positions,
                                     lamp_site=site, causal=True,
                                     attn_impl=attn_impl)
        xc = xc + a
        h = LY.layer_norm(xc, p_l["ln2_w"], p_l["ln2_b"])
        kv = _cross_kv(cfg, p_l["xattn"], enc_out)
        a, _ = LY.attention_sublayer(cfg, p_l["xattn"], h, positions=positions,
                                     lamp_site=site, causal=False,
                                     attn_impl=attn_impl, kv=kv)
        xc = xc + a
        h = LY.layer_norm(xc, p_l["ln3_w"], p_l["ln3_b"])
        return xc + LY.mlp_apply(cfg, p_l["mlp"], h), None
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = LY.layer_norm(x, params["lnf_w"], params["lnf_b"])
    return LY.unembed(cfg, params["embed"], x)


def forward(cfg, params, tokens, *, frames=None, use_lamp: bool = False,
            attn_impl: str = "auto", remat: bool = False, **_):
    enc_out = encode(cfg, params, frames, use_lamp=use_lamp, attn_impl=attn_impl)
    logits = decode_full(cfg, params, tokens, enc_out, use_lamp=use_lamp,
                         attn_impl=attn_impl, remat=remat)
    return logits, {}


def loss_fn(cfg, params, batch, *, use_lamp: bool = False, remat: bool = True,
            attn_impl: str = "auto", **_):
    logits, aux = forward(cfg, params, batch["tokens"], frames=batch["frames"],
                          use_lamp=use_lamp, attn_impl=attn_impl, remat=remat)
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = batch["tokens"][:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss, {"loss": loss, **aux}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    L, Hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((L, batch, max_len, Hkv, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, Hkv, hd), dtype),
        "xk": jnp.zeros((L, batch, cfg.enc_seq, Hkv, hd), dtype),
        "xv": jnp.zeros((L, batch, cfg.enc_seq, Hkv, hd), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def prefill(cfg, params, tokens, cache, *, frames=None, use_lamp: bool = True,
            attn_impl: str = "auto", **_):
    """Encode audio, precompute cross K/V per layer, prefill decoder cache."""
    B, S = tokens.shape
    enc_out = encode(cfg, params, frames, use_lamp=use_lamp, attn_impl=attn_impl)
    positions = jnp.arange(S)
    x = LY.embed(cfg, params["embed"], tokens, positions)
    site = cfg.lamp.kq if (use_lamp and cfg.lamp.kq.enabled) else LampSite(enabled=False)

    def body(carry, xs):
        xc = carry
        p_l, ck, cv = xs
        h = LY.layer_norm(xc, p_l["ln1_w"], p_l["ln1_b"])
        q, k, v = LY._project_qkv(cfg, p_l["attn"], h, positions)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), 0, axis=1)
        a, _ = LY.attention_sublayer(cfg, p_l["attn"], h, positions=positions,
                                     lamp_site=site, causal=True,
                                     attn_impl=attn_impl)
        xc = xc + a
        h = LY.layer_norm(xc, p_l["ln2_w"], p_l["ln2_b"])
        xk, xv = _cross_kv(cfg, p_l["xattn"], enc_out)
        a, _ = LY.attention_sublayer(cfg, p_l["xattn"], h, positions=positions,
                                     lamp_site=site, causal=False,
                                     attn_impl=attn_impl, kv=(xk, xv))
        xc = xc + a
        h = LY.layer_norm(xc, p_l["ln3_w"], p_l["ln3_b"])
        return xc + LY.mlp_apply(cfg, p_l["mlp"], h), (ck, cv, xk, xv)

    x, (ks, vs, xks, xvs) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"]))
    x = LY.layer_norm(x, params["lnf_w"], params["lnf_b"])
    logits = LY.unembed(cfg, params["embed"], x[:, -1:])
    new_cache = {"k": ks, "v": vs, "xk": xks.astype(cache["xk"].dtype),
                 "xv": xvs.astype(cache["xv"].dtype),
                 "length": jnp.full((B,), S, jnp.int32)}
    return logits, new_cache


def decode_step(cfg, params, cache, tokens, *, use_lamp: bool = True, **_):
    B = tokens.shape[0]
    length = cache["length"]
    x = LY.embed(cfg, params["embed"], tokens, length[:, None])
    site = cfg.lamp.kq if (use_lamp and cfg.lamp.kq.enabled) else LampSite(enabled=False)

    def body(carry, xs):
        xc = carry
        p_l, ck, cv, xk, xv = xs
        h = LY.layer_norm(xc, p_l["ln1_w"], p_l["ln1_b"])
        a, ck, cv, _ = LY.attention_decode_sublayer(
            cfg, p_l["attn"], h, cache_k=ck, cache_v=cv, length=length,
            lamp_site=site)
        xc = xc + a
        h = LY.layer_norm(xc, p_l["ln2_w"], p_l["ln2_b"])
        a, _, _, _ = LY.attention_decode_sublayer(
            cfg, p_l["xattn"], h, cache_k=xk, cache_v=xv, length=length,
            lamp_site=site, kv_cross=(xk.astype(xc.dtype), xv.astype(xc.dtype)))
        xc = xc + a
        h = LY.layer_norm(xc, p_l["ln3_w"], p_l["ln3_b"])
        return xc + LY.mlp_apply(cfg, p_l["mlp"], h), (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = LY.layer_norm(x, params["lnf_w"], params["lnf_b"])
    logits = LY.unembed(cfg, params["embed"], x)
    new_cache = {**cache, "k": ks, "v": vs, "length": length + 1}
    return logits, new_cache
