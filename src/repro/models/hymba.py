"""Hymba (arXiv:2411.13676): hybrid-head LM -- every layer runs attention
heads and Mamba (selective-SSM) heads IN PARALLEL on the same input, then
fuses the two branch outputs (each RMS-normalized, learnable per-branch
scales). Attention is sliding-window GQA (global attention only in a few
layers of the real model; we use SWA uniformly, window=cfg.window), which
keeps the KV cache bounded and makes the arch sub-quadratic -> long_500k
runs. 128 learnable meta tokens are prepended to the sequence.

LAMP: the attention branch gets the paper's KQ rule; the SSM branch is
attention-free (no softmax) so LAMP does not apply there (DESIGN.md Sec 6).

Simplifications vs the released checkpoints (noted per DESIGN.md Sec 7):
one shared Mamba state size N=cfg.ssm_state, depthwise conv kernel 4,
branch fusion by normalized averaging rather than per-head interleaving.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import LampSite

from . import layers as LY

CONV_K = 4


def block_params(cfg, key) -> Dict[str, Any]:
    d, dt = cfg.d_model, LY.dtype_of(cfg)
    N = cfg.ssm_state
    ks = jax.random.split(key, 10)

    def lin(k, m, n):
        return (jax.random.normal(k, (m, n)) * m ** -0.5).astype(dt)

    return {
        "ln1_w": jnp.zeros((d,), dt),
        "ln2_w": jnp.zeros((d,), dt),
        "attn": LY.attn_params(cfg, ks[0]),
        # mamba branch
        "m_in": lin(ks[1], d, 2 * d),                 # x and gate
        "m_conv": (jax.random.normal(ks[2], (CONV_K, d)) * 0.3).astype(dt),
        "m_dt": lin(ks[3], d, d),
        "m_dt_bias": jnp.zeros((d,), dt),
        "m_bc": lin(ks[4], d, 2 * N),                 # B and C projections
        "m_A_log": (jnp.log(jnp.linspace(1.0, float(N), N))[None, :]
                    * jnp.ones((d, 1))).astype(jnp.float32),
        "m_D": jnp.ones((d,), jnp.float32),
        "m_out": lin(ks[5], d, d),
        # branch fusion
        "fuse_na": jnp.zeros((d,), dt),               # rmsnorm scales
        "fuse_ns": jnp.zeros((d,), dt),
        "fuse_beta": jnp.ones((2,), jnp.float32),
        "mlp": LY.mlp_params(cfg, ks[6]),
    }


def init_params(cfg, key) -> Dict[str, Any]:
    k_emb, k_blocks, k_meta = jax.random.split(key, 3)
    blocks = jax.vmap(lambda k: block_params(cfg, k))(
        jax.random.split(k_blocks, cfg.n_layers))
    d, dt = cfg.d_model, LY.dtype_of(cfg)
    return {
        "embed": LY.embed_params(cfg, k_emb),
        "meta": (jax.random.normal(k_meta, (cfg.n_meta_tokens, d)) * 0.02).astype(dt),
        "blocks": blocks,
        "lnf_w": jnp.zeros((d,), dt),
    }


def _ssm_scan(xf, dt_soft, B_t, C_t, A, D, h0):
    """Selective scan. xf,(B,T,d); dt (B,T,d); B_t,C_t (B,T,N); A (d,N);
    h0 (B,d,N). Returns (y (B,T,d), hT)."""
    dA = jnp.exp(dt_soft[..., None] * (-jnp.exp(A))[None, None])     # (B,T,d,N)
    dBx = dt_soft[..., None] * B_t[:, :, None, :] * xf[..., None]    # (B,T,d,N)

    def step(h, xs):
        dA_t, dBx_t, C_tt = xs
        h = dA_t * h + dBx_t
        y = jnp.einsum("bdn,bn->bd", h, C_tt)
        return h, y

    xs = (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBx, 1, 0), jnp.moveaxis(C_t, 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + xf * D[None, None]
    return y, hT


def mamba_branch(cfg, p, x, conv_state, ssm_state):
    """x: (B,T,d). conv_state: (B, CONV_K-1, d); ssm_state: (B, d, N)."""
    B, T, d = x.shape
    N = cfg.ssm_state
    h = x @ p["m_in"]
    xin, gate = h[..., :d], h[..., d:]
    # causal depthwise conv (kernel CONV_K)
    xpad = jnp.concatenate([conv_state.astype(xin.dtype), xin], axis=1)
    new_conv_state = xpad[:, -(CONV_K - 1):, :]
    w = p["m_conv"].astype(jnp.float32)
    xc = sum(xpad[:, i:i + T, :].astype(jnp.float32) * w[i][None, None]
             for i in range(CONV_K))
    xf = jax.nn.silu(xc)
    dt_soft = jax.nn.softplus((xf.astype(x.dtype) @ p["m_dt"]).astype(jnp.float32)
                              + p["m_dt_bias"].astype(jnp.float32))
    bc = (xf.astype(x.dtype) @ p["m_bc"]).astype(jnp.float32)
    B_t, C_t = bc[..., :N], bc[..., N:]
    y, hT = _ssm_scan(xf, dt_soft, B_t, C_t, p["m_A_log"], p["m_D"],
                      ssm_state.astype(jnp.float32))
    y = y * jax.nn.silu(gate.astype(jnp.float32))
    out = y.astype(x.dtype) @ p["m_out"]
    return out, new_conv_state, hT.astype(ssm_state.dtype)


def block_apply(cfg, p, x, *, positions, lamp_site: LampSite, attn_impl: str,
                state: Dict[str, Any]):
    h = LY.rms_norm(x, p["ln1_w"])
    a, rate = LY.attention_sublayer(cfg, p["attn"], h, positions=positions,
                                    lamp_site=lamp_site, causal=True,
                                    attn_impl=attn_impl, window=cfg.window)
    s, conv_st, ssm_st = mamba_branch(cfg, p, h, state["conv"], state["ssm"])
    beta = p["fuse_beta"].astype(jnp.float32)
    fused = (LY.rms_norm(a, p["fuse_na"]).astype(jnp.float32) * beta[0]
             + LY.rms_norm(s, p["fuse_ns"]).astype(jnp.float32) * beta[1]) * 0.5
    x = x + fused.astype(x.dtype)
    h = LY.rms_norm(x, p["ln2_w"])
    x = x + LY.mlp_apply(cfg, p["mlp"], h)
    return x, {"conv": conv_st, "ssm": ssm_st}, rate


def init_state(cfg, batch: int) -> Dict[str, Any]:
    L, d, N = cfg.n_layers, cfg.d_model, cfg.ssm_state
    dt = LY.dtype_of(cfg)
    return {
        "conv": jnp.zeros((L, batch, CONV_K - 1, d), dt),
        "ssm": jnp.zeros((L, batch, d, N), jnp.float32),
    }


def forward(cfg, params, tokens, *, use_lamp: bool = False,
            attn_impl: str = "auto", remat: bool = False, state=None, **_):
    B, S = tokens.shape
    M = cfg.n_meta_tokens
    x = LY.embed(cfg, params["embed"], tokens, jnp.arange(S))
    meta = jnp.broadcast_to(params["meta"][None], (B, M, cfg.d_model)).astype(x.dtype)
    x = jnp.concatenate([meta, x], axis=1)
    positions = jnp.arange(M + S)
    site = cfg.lamp.kq if (use_lamp and cfg.lamp.kq.enabled) else LampSite(enabled=False)
    if state is None:
        state = init_state(cfg, B)

    def body(carry, xs):
        xc = carry
        p_l, st_l = xs
        y, st, rate = block_apply(cfg, p_l, xc, positions=positions,
                                  lamp_site=site, attn_impl=attn_impl, state=st_l)
        return y, (st, rate)
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    x, (st_out, rates) = jax.lax.scan(body, x, (params["blocks"], state))
    x = LY.rms_norm(x, params["lnf_w"])
    logits = LY.unembed(cfg, params["embed"], x[:, M:])
    return logits, st_out, {"attn_lamp_rate": jnp.mean(rates)}


def loss_fn(cfg, params, batch, *, use_lamp: bool = False, remat: bool = True, **_):
    logits, _, aux = forward(cfg, params, batch["tokens"], use_lamp=use_lamp,
                             remat=remat)
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = batch["tokens"][:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss, {"loss": loss, **aux}


# ---------------------------------------------------------------------------
# Serving: ring-buffer SWA cache + SSM state
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """SWA cache is bounded at `window` regardless of max_len."""
    L, Hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    W = min(cfg.window or max_len, max_len) + cfg.n_meta_tokens
    st = init_state(cfg, batch)
    return {
        "k": jnp.zeros((L, batch, W, Hkv, hd), dtype),
        "v": jnp.zeros((L, batch, W, Hkv, hd), dtype),
        "conv": st["conv"], "ssm": st["ssm"],
        "length": jnp.zeros((batch,), jnp.int32),
    }


def prefill(cfg, params, tokens, cache, *, use_lamp: bool = True,
            attn_impl: str = "auto", **_):
    """Prefill via full forward; keep the last `window` K/V in the ring."""
    B, S = tokens.shape
    M = cfg.n_meta_tokens
    W = cache["k"].shape[2]
    x = LY.embed(cfg, params["embed"], tokens, jnp.arange(S))
    meta = jnp.broadcast_to(params["meta"][None], (B, M, cfg.d_model)).astype(x.dtype)
    x = jnp.concatenate([meta, x], axis=1)
    positions = jnp.arange(M + S)
    site = cfg.lamp.kq if (use_lamp and cfg.lamp.kq.enabled) else LampSite(enabled=False)

    def body(carry, xs):
        xc = carry
        p_l, st_l, ck, cv = xs
        h = LY.rms_norm(xc, p_l["ln1_w"])
        q, k, v = LY._project_qkv(cfg, p_l["attn"], h, positions)
        # write the last W positions into the ring (prefill fills it)
        take = min(W, M + S)
        ck = jax.lax.dynamic_update_slice_in_dim(
            ck, k[:, -take:].astype(ck.dtype), 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cv, v[:, -take:].astype(cv.dtype), 0, axis=1)
        y, st, _ = block_apply(cfg, p_l, xc, positions=positions, lamp_site=site,
                               attn_impl=attn_impl, state=st_l)
        return y, (st, ck, cv)

    st_in = {"conv": cache["conv"], "ssm": cache["ssm"]}
    x, (st_out, ks, vs) = jax.lax.scan(
        body, x, (params["blocks"], st_in, cache["k"], cache["v"]))
    x = LY.rms_norm(x, params["lnf_w"])
    logits = LY.unembed(cfg, params["embed"], x[:, -1:])
    new_cache = {"k": ks, "v": vs, **st_out,
                 "length": jnp.full((B,), M + S, jnp.int32)}
    return logits, new_cache


def decode_step(cfg, params, cache, tokens, *, use_lamp: bool = True, **_):
    """One token; SWA ring-buffer via modular write, SSM single-step."""
    B = tokens.shape[0]
    length = cache["length"]
    W = cache["k"].shape[2]
    x = LY.embed(cfg, params["embed"], tokens, length[:, None])
    site = cfg.lamp.kq if (use_lamp and cfg.lamp.kq.enabled) else LampSite(enabled=False)

    def body(carry, xs):
        xc = carry
        p_l, ck, cv, conv_st, ssm_st = xs
        h = LY.rms_norm(xc, p_l["ln1_w"])
        q, k, v = LY._project_qkv(cfg, p_l["attn"], h, length[:, None])
        slot = jnp.minimum(length, W - 1)  # ring write (shift-free approximation)
        bidx = jnp.arange(B)
        ck = ck.at[bidx, slot].set(k[:, 0].astype(ck.dtype))
        cv = cv.at[bidx, slot].set(v[:, 0].astype(cv.dtype))
        H, Hkv = cfg.n_heads, cfg.n_kv_heads
        from repro.core import attention as CA
        qh = jnp.swapaxes(q, 1, 2)
        kh = LY._repeat_kv(jnp.moveaxis(ck.astype(x.dtype), 2, 1), H // Hkv)
        vh = LY._repeat_kv(jnp.moveaxis(cv.astype(x.dtype), 2, 1), H // Hkv)
        eff = jnp.minimum(length + 1, W)
        a, _ = CA.decode_attention_lamp(qh, kh, vh, eff, site)
        a = jnp.swapaxes(a, 1, 2).reshape(B, 1, -1).astype(xc.dtype) @ p_l["attn"]["wo"]
        s, conv_st, ssm_st = mamba_branch(cfg, p_l, h, conv_st, ssm_st)
        beta = p_l["fuse_beta"].astype(jnp.float32)
        fused = (LY.rms_norm(a, p_l["fuse_na"]).astype(jnp.float32) * beta[0]
                 + LY.rms_norm(s, p_l["fuse_ns"]).astype(jnp.float32) * beta[1]) * 0.5
        xc = xc + fused.astype(xc.dtype)
        h2 = LY.rms_norm(xc, p_l["ln2_w"])
        xc = xc + LY.mlp_apply(cfg, p_l["mlp"], h2)
        return xc, (ck, cv, conv_st, ssm_st)

    x, (ks, vs, convs, ssms) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"],
                  cache["conv"], cache["ssm"]))
    x = LY.rms_norm(x, params["lnf_w"])
    logits = LY.unembed(cfg, params["embed"], x)
    new_cache = {"k": ks, "v": vs, "conv": convs, "ssm": ssms,
                 "length": length + 1}
    return logits, new_cache
