"""Shared model layers: norms, RoPE, GQA attention, MLP variants, embeddings.

All layers are pure functions over explicit param pytrees, computed in the
config dtype with FP32 islands where numerics require (norm statistics,
attention logits/softmax via repro.core.attention, final logits).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import attention as A
from repro.core.policy import LampSite


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32) + b.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(cfg, x: jnp.ndarray, p: Dict[str, jnp.ndarray], prefix: str) -> jnp.ndarray:
    if cfg.norm == "layernorm":
        return layer_norm(x, p[f"{prefix}_w"], p[f"{prefix}_b"])
    return rms_norm(x, p[f"{prefix}_w"])


def norm_params(cfg, key, d: int) -> Dict[str, jnp.ndarray]:
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), dtype_of(cfg)), "b": jnp.zeros((d,), dtype_of(cfg))}
    return {"w": jnp.zeros((d,), dtype_of(cfg))}


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
         fraction: float = 1.0) -> jnp.ndarray:
    """x: (B, T, H, D); positions: (B, T) or (T,). Rotates the first
    `fraction` of D (glm4 uses 0.5)."""
    D = x.shape[-1]
    rot = int(D * fraction) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]      # (T, half)
        ang = ang[None, :, None, :]                                        # (1,T,1,half)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs             # (B,T,half)
        ang = ang[:, :, None, :]                                           # (B,T,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., :half].astype(jnp.float32), xr[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# GQA attention sublayer
# ---------------------------------------------------------------------------

def attn_params(cfg, key) -> Dict[str, jnp.ndarray]:
    d, hd = cfg.d_model, cfg.hd
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    sc = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, H * hd)) * sc).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, Hkv * hd)) * sc).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, Hkv * hd)) * sc).astype(dt),
        "wo": (jax.random.normal(ks[3], (H * hd, d)) * (H * hd) ** -0.5).astype(dt),
    }
    if cfg.qk_norm:
        p["qn_w"] = jnp.zeros((hd,), dt)
        p["kn_w"] = jnp.zeros((hd,), dt)
    return p


def _project_qkv(cfg, p, x, positions):
    from repro.distributed.sharding import shard_hint
    B, T, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    # explicit batch/head sharding hints: without them SPMD propagation can
    # drop the batch sharding inside scan bodies and replicate the full
    # attention compute on every device (EXPERIMENTS Sec Perf, hillclimb C)
    q = shard_hint((x @ p["wq"]).reshape(B, T, H, hd),
                   "batch", None, "model", None)
    k = shard_hint((x @ p["wk"]).reshape(B, T, Hkv, hd),
                   "batch", None, "model", None)
    v = shard_hint((x @ p["wv"]).reshape(B, T, Hkv, hd),
                   "batch", None, "model", None)
    if cfg.qk_norm:
        q = rms_norm(q, p["qn_w"])
        k = rms_norm(k, p["kn_w"])
    if cfg.pos == "rope":
        q = rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    return q, k, v


def _repeat_kv(t: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return t
    return jnp.repeat(t, n_rep, axis=1)


def attention_sublayer(cfg, p, x, *, positions, lamp_site: LampSite,
                       causal: bool = True, attn_impl: str = "auto",
                       block: int = 512, kv: Optional[Tuple] = None,
                       window: Optional[int] = None,
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence attention (train / prefill). Returns (out, recompute_rate).

    `kv`: optional externally-supplied (k, v) in (B, T, Hkv, hd) layout for
    cross-attention (whisper decoder): q comes from x, k/v from the encoder.
    """
    B, T, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, k, v = _project_qkv(cfg, p, x, positions)
    if kv is not None:
        k, v = kv
    # (B, H, T, hd)
    q = jnp.swapaxes(q, 1, 2)
    k = _repeat_kv(jnp.swapaxes(k, 1, 2), H // Hkv)
    v = _repeat_kv(jnp.swapaxes(v, 1, 2), H // Hkv)
    window = window if window is not None else cfg.window

    if attn_impl == "auto":
        attn_impl = "full" if max(T, k.shape[2]) <= 2048 else "chunked"

    rate = jnp.zeros((), jnp.float32)
    if attn_impl == "full":
        if lamp_site.enabled:
            if lamp_site.rule == "random":
                # App C.4 control arm: LAMP-sized random recompute set
                out, aux = A.attention_lamp(
                    q, k, v, lamp_site.replace(rule="strict"), causal=causal,
                    window=window, random_key=jax.random.PRNGKey(0))
            else:
                out, aux = A.attention_lamp(q, k, v, lamp_site, causal=causal,
                                            window=window)
            rate = aux.recompute_rate
        else:
            out = A.attention_reference(q, k, v, causal=causal, window=window)
    elif attn_impl == "chunked":
        if lamp_site.enabled:
            site = lamp_site if lamp_site.rule == "relaxed" else lamp_site.replace(rule="relaxed")
            out, aux = A.chunked_attention_lamp(q, k, v, site, causal=causal,
                                                block=block, window=window,
                                                onepass=site.onepass)
            rate = aux.recompute_rate
        else:
            out = A.chunked_attention(q, k, v, causal=causal, block=block,
                                      window=window)
    else:
        raise ValueError(f"unknown attn_impl {attn_impl!r}")

    out = jnp.swapaxes(out, 1, 2).reshape(B, T, H * hd).astype(x.dtype)
    return out @ p["wo"], rate


def attention_decode_sublayer(cfg, p, x, *, cache_k, cache_v, length,
                              lamp_site: LampSite, kv_cross: Optional[Tuple] = None,
                              window: Optional[int] = None):
    """Single-token decode. x: (B, 1, d); cache_k/v: (B, S, Hkv, hd).

    Returns (out, new_cache_k, new_cache_v, recompute_rate). The new token's
    k/v are written at position `length` (per sequence).
    """
    B, T, _ = x.shape
    assert T == 1
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    positions = length[:, None]  # (B, 1) absolute position of the new token
    q, k, v = _project_qkv(cfg, p, x, positions)
    if kv_cross is None:
        # scatter new k/v into the cache at `length`
        bidx = jnp.arange(B)
        cache_k = cache_k.at[bidx, length].set(k[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[bidx, length].set(v[:, 0].astype(cache_v.dtype))
        use_k, use_v = cache_k, cache_v
        eff_len = length + 1
    else:
        use_k, use_v = kv_cross
        eff_len = jnp.full((B,), use_k.shape[1], jnp.int32)

    qh = jnp.swapaxes(q, 1, 2)                                   # (B,H,1,hd)
    window = window if window is not None else cfg.window

    # sequence-parallel path: when the KV cache's seq axis is sharded over
    # a >1 'model' mesh axis, run the shard_map distributed online softmax
    # (grouped GQA, cache read once, O(B*H*hd) combine) instead of letting
    # XLA all-gather the cache (EXPERIMENTS Sec Perf, hillclimb B).
    try:
        am = jax.sharding.get_abstract_mesh()
        names = getattr(am, "axis_names", ()) if am is not None else ()
    except Exception:
        names = ()
    S = use_k.shape[1]
    from repro.core.attention import baseline_mode
    if ("model" in names and am.shape["model"] > 1
            and S % am.shape["model"] == 0 and not baseline_mode()):
        from repro.distributed.collectives import sp_decode_attention
        out = sp_decode_attention(
            am, qh, jnp.moveaxis(use_k, 2, 1), jnp.moveaxis(use_v, 2, 1),
            eff_len, mu=lamp_site.mu if lamp_site.enabled else 23,
            tau=lamp_site.tau, lamp=lamp_site.enabled, window=window)
        rate = jnp.zeros((), jnp.float32)  # not tracked on the sp path
    else:
        kh = _repeat_kv(jnp.moveaxis(use_k, 2, 1), H // Hkv)      # (B,H,S,hd)
        vh = _repeat_kv(jnp.moveaxis(use_v, 2, 1), H // Hkv)
        out, aux = A.decode_attention_lamp(
            qh, kh, vh, eff_len,
            lamp_site if lamp_site.enabled else lamp_site.replace(enabled=False),
            window=window)
        rate = aux.recompute_rate
    out = jnp.swapaxes(out, 1, 2).reshape(B, 1, H * hd).astype(x.dtype)
    return out @ p["wo"], cache_k, cache_v, rate


def paged_attention_decode_sublayer(cfg, p, x, *, arena_k, arena_v,
                                    block_tables, lengths,
                                    lamp_site: LampSite,
                                    window: Optional[int] = None,
                                    kernel: str = "gather",
                                    tau=None):
    """Single-token decode against a paged KV arena (one layer).

    x: (R, 1, d) hidden states for R slots of a continuous batch.
    arena_k/v: (n_blocks, block_size, Hkv, hd) shared block arena.
    block_tables: (R, n_max) int32; row r lists the arena blocks holding
        sequence r's KV in position order (0 = reserved null block for
        padding — never read thanks to the length mask, writes to it are
        scratch).
    lengths: (R,) tokens already cached; the new token's k/v are written at
        absolute position `lengths[r]`, i.e. block `block_tables[r, len//bs]`
        offset `len % bs`.

    kernel="gather" (reference): the per-sequence view reshapes the gathered
    blocks so gathered flat index t == absolute position t, which makes the
    computation bit-identical to the dense-cache path for valid positions.
    kernel="pallas": the fused paged-attention kernel reads live arena
    blocks directly through the block-table index map (no gather, masked
    blocks skipped); falls back to gather for sites the kernel does not
    implement (the benchmark-only "random" rule).
    tau: optional traced scalar overriding lamp_site.tau (the serving policy
    controller threads per-layer thresholds through the jitted steps so
    moving them never recompiles).
    Returns (out, arena_k, arena_v, n_selected (R,), n_valid (R,)).
    """
    R = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    bs = arena_k.shape[1]
    q, k, v = _project_qkv(cfg, p, x, lengths[:, None])
    ridx = jnp.arange(R)
    blk = block_tables[ridx, lengths // bs]
    off = lengths % bs
    arena_k = arena_k.at[blk, off].set(k[:, 0].astype(arena_k.dtype))
    arena_v = arena_v.at[blk, off].set(v[:, 0].astype(arena_v.dtype))
    qh = jnp.swapaxes(q, 1, 2)                                # (R,H,1,hd)
    window = window if window is not None else cfg.window

    from repro.kernels.paged_attention import supports_site
    if kernel == "pallas" and supports_site(lamp_site):
        from repro.kernels import ops as KOPS
        eff = lengths + 1
        out, nsel = KOPS.paged_decode_attention(
            qh, arena_k, arena_v, block_tables, eff, lamp_site, tau=tau,
            window=window)
        cap = eff if window is None else jnp.minimum(eff, window)
        nval = (cap * H).astype(jnp.float32)
    else:
        ks = arena_k[block_tables].reshape(R, -1, Hkv, hd)
        vs = arena_v[block_tables].reshape(R, -1, Hkv, hd)
        kh = _repeat_kv(jnp.moveaxis(ks, 2, 1), H // Hkv)     # (R,H,S,hd)
        vh = _repeat_kv(jnp.moveaxis(vs, 2, 1), H // Hkv)
        out, aux = A.decode_attention_lamp(qh, kh, vh, lengths + 1, lamp_site,
                                           window=window, reduce=False,
                                           tau=tau)
        nsel, nval = aux.n_selected, aux.n_valid
    out = jnp.swapaxes(out, 1, 2).reshape(R, 1, H * hd).astype(x.dtype)
    return out @ p["wo"], arena_k, arena_v, nsel, nval


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def mlp_params(cfg, key, d_ff: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    dt = dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    gated = cfg.act in ("swiglu", "geglu")
    wi_cols = 2 * ff if gated else ff
    return {
        "wi": (jax.random.normal(k1, (d, wi_cols)) * d ** -0.5).astype(dt),
        "wo": (jax.random.normal(k2, (ff, d)) * ff ** -0.5).astype(dt),
    }


def mlp_apply(cfg, p, x: jnp.ndarray) -> jnp.ndarray:
    h = x @ p["wi"]
    if cfg.act in ("swiglu", "geglu"):
        ff = p["wo"].shape[0]
        g, u = h[..., :ff], h[..., ff:]
        act = jax.nn.silu(g.astype(jnp.float32)) if cfg.act == "swiglu" \
            else jax.nn.gelu(g.astype(jnp.float32), approximate=True)
        h = (act * u.astype(jnp.float32)).astype(x.dtype)
    elif cfg.act == "gelu":
        h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    elif cfg.act == "relu2":
        r = jax.nn.relu(h.astype(jnp.float32))
        h = (r * r).astype(x.dtype)
    else:
        raise ValueError(f"unknown act {cfg.act!r}")
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Embeddings / unembedding
# ---------------------------------------------------------------------------

def embed_params(cfg, key) -> Dict[str, jnp.ndarray]:
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    p = {"tok": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.02).astype(dt)}
    if cfg.pos == "learned":
        p["pos"] = (jax.random.normal(ks[1], (cfg.max_seq, cfg.d_model)) * 0.01).astype(dt)
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(ks[2], (cfg.d_model, cfg.vocab))
                        * cfg.d_model ** -0.5).astype(dt)
    return p


def embed(cfg, p, tokens: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    from repro.distributed.sharding import shard_hint
    x = p["tok"][tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.pos == "learned":
        x = x + p["pos"][positions]
    return shard_hint(x, "batch", None, None)


def unembed(cfg, p, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = p["tok"].T
    else:
        w = p["unembed"]
    return (x.astype(jnp.float32) @ w.astype(jnp.float32))
