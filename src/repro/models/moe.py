"""Mixture-of-Experts FFN with top-k routing and sort-based capacity dispatch.

The router softmax is a LAMP site (beyond-paper extension, DESIGN.md Sec 6):
router logits are a matmul feeding a softmax, exactly the composition the
paper analyzes -- a "confused" router (near-uniform top-k mass) is where
rounding errors flip expert choices, and rule (8) flags precisely those rows.

Dispatch is sort-based (no T x E x C one-hot matmuls): tokens are ranked
within their expert via a stable sort of expert assignments, truncated at
capacity C = ceil(T * k * capacity_factor / E), scattered to an (E, C, d)
buffer, processed with batched expert matmuls, and combined back weighted by
the (renormalized) router probabilities. Overflowing tokens drop (standard
capacity semantics); the residual path keeps them finite.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import lamp as L
from repro.core.mixed_matmul import dot_ps
from repro.core.policy import LampSite

from .layers import dtype_of


def moe_params(cfg, key) -> Dict[str, jnp.ndarray]:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    gated = cfg.act in ("swiglu", "geglu")
    wi_cols = 2 * ff if gated else ff
    return {
        "router": (jax.random.normal(ks[0], (d, E)) * d ** -0.5).astype(jnp.float32),
        "we_in": (jax.random.normal(ks[1], (E, d, wi_cols)) * d ** -0.5).astype(dt),
        "we_out": (jax.random.normal(ks[2], (E, ff, d)) * ff ** -0.5).astype(dt),
    }


def router_probs_lamp(x2d: jnp.ndarray, router_w: jnp.ndarray,
                      site: LampSite) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Router logits with LAMP evaluation. x2d: (T, d). Returns (probs, rate)."""
    xf = x2d.astype(jnp.float32)
    if not site.enabled:
        return jax.nn.softmax(xf @ router_w, axis=-1), jnp.zeros(())
    y_low = dot_ps(xf, router_w, site.mu, granularity=site.granularity)
    if site.rule == "relaxed":
        mask = L.select_softmax_relaxed(y_low, site.tau)
    else:
        mask = L.select_softmax_strict(y_low, site.tau)
    y = jnp.where(mask, xf @ router_w, y_low)
    return jax.nn.softmax(y, axis=-1), jnp.mean(mask.astype(jnp.float32))


def moe_apply(cfg, p, x: jnp.ndarray, *, lamp_site: LampSite,
              num_groups: int = 1, dropless: bool = False,
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, T, d) -> (B, T, d). `num_groups` splits tokens into independent
    dispatch groups (aligning groups with the data-parallel axis keeps the
    scatter local to a shard). `dropless=True` sizes capacity for the worst
    case (decode steps: exactness over buffer size)."""
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    N = B * T
    x2d = x.reshape(N, d)

    probs, rate = router_probs_lamp(x2d, p["router"], lamp_site)
    top_p, top_e = jax.lax.top_k(probs, k)                       # (N, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    G = num_groups
    while N % G:
        G //= 2
    Ng = N // G
    import math
    if dropless:
        cap = Ng * k                     # worst case: exactness (tests, small B)
    elif T == 1:
        # decode at scale: bounded-imbalance capacity -- 4x headroom over
        # perfect balance instead of the E-fold dropless worst case
        # (EXPERIMENTS Sec Perf, hillclimb B)
        cap = min(Ng * k, max(1, math.ceil(Ng * k * 4 / E)))
    else:
        cap = max(1, math.ceil(Ng * k * cfg.capacity_factor / E))

    def dispatch_group(xg, eg, pg):
        # xg: (Ng, d); eg, pg: (Ng, k)
        flat_e = eg.reshape(-1)                                   # (Ng*k,)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))
        pos_sorted = jnp.arange(Ng * k) - seg_start[sorted_e]
        pos = jnp.zeros(Ng * k, jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
        keep = pos < cap
        pos_c = jnp.minimum(pos, cap - 1)
        tok_idx = jnp.repeat(jnp.arange(Ng), k)
        contrib = jnp.where(keep[:, None], xg[tok_idx], 0).astype(xg.dtype)
        buf = jnp.zeros((E, cap, d), xg.dtype).at[flat_e, pos_c].add(contrib)
        # expert FFN (batched over E)
        h = jnp.einsum("ecd,edf->ecf", buf, p["we_in"])
        if cfg.act in ("swiglu", "geglu"):
            ff = p["we_out"].shape[1]
            g, u = h[..., :ff], h[..., ff:]
            a = jax.nn.silu(g.astype(jnp.float32)) if cfg.act == "swiglu" \
                else jax.nn.gelu(g.astype(jnp.float32), approximate=True)
            h = (a * u.astype(jnp.float32)).astype(h.dtype)
        else:
            h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(h.dtype)
        out_buf = jnp.einsum("ecf,efd->ecd", h, p["we_out"])
        y_tok = out_buf[flat_e, pos_c] * keep[:, None]            # (Ng*k, d)
        w = pg.reshape(-1)[:, None].astype(y_tok.dtype)
        yg = jnp.zeros((Ng, d), y_tok.dtype).at[tok_idx].add(y_tok * w)
        return yg, jnp.mean(keep.astype(jnp.float32))

    if G == 1:
        y, kept = dispatch_group(x2d, top_e, top_p)
    else:
        # groups are B-major, i.e. aligned with the batch shards; make that
        # explicit or SPMD replicates the (G, E, cap, d) dispatch buffers
        # (observed on the multi-pod mesh: 72 GB/dev -> sharded).
        from repro.distributed.sharding import shard_hint
        xg = shard_hint(x2d.reshape(G, Ng, d), "batch", None, None)
        eg = shard_hint(top_e.reshape(G, Ng, k), "batch", None, None)
        pg = shard_hint(top_p.reshape(G, Ng, k), "batch", None, None)
        y, kept = jax.vmap(dispatch_group)(xg, eg, pg)
        y = shard_hint(y, "batch", None, None).reshape(N, d)
        kept = jnp.mean(kept)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(jax.nn.one_hot(top_e[..., 0], E), axis=0)
    pe = jnp.mean(probs, axis=0)
    aux_loss = E * jnp.sum(me * pe)

    metrics = {"router_lamp_rate": rate, "kept_frac": kept, "moe_aux_loss": aux_loss}
    return y.reshape(B, T, d).astype(x.dtype), metrics


def moe_dispatch(cfg, p, x: jnp.ndarray, *, lamp_site: LampSite,
                 num_groups: int = 1, dropless: bool = False):
    """Pick the dispatch implementation: shard_map expert-parallel when a
    >1 `model` mesh axis is ambient (scales; multi-pod safe), else the
    einsum/scatter path (single device, tests, REPRO_BASELINE=1)."""
    from repro.core.attention import baseline_mode
    try:
        am = jax.sharding.get_abstract_mesh()
        names = getattr(am, "axis_names", ()) if am is not None else ()
    except Exception:
        names = ()
    if ("model" in names and am.shape["model"] > 1
            and cfg.n_experts % am.shape["model"] == 0
            and not baseline_mode()):
        baxes = tuple(a for a in ("pod", "data") if a in names)
        n_batch = 1
        for a in baxes:
            n_batch *= am.shape[a]
        if x.shape[0] % max(n_batch, 1) == 0:
            return moe_apply_ep(cfg, p, x, lamp_site=lamp_site, mesh=am)
    return moe_apply(cfg, p, x, lamp_site=lamp_site, num_groups=num_groups,
                     dropless=dropless)


# ---------------------------------------------------------------------------
# shard_map expert-parallel dispatch (EXPERIMENTS Sec Perf / multi-pod fix)
# ---------------------------------------------------------------------------

def moe_apply_ep(cfg, p, x: jnp.ndarray, *, lamp_site: LampSite, mesh,
                 capacity_mult: float = 2.0,
                 ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Expert-parallel MoE via shard_map: no token movement at all.

    Layout: tokens are batch-sharded over (pod, data) and replicated over
    `model`; expert weights are sharded E over `model` (and FSDP over
    `data`, gathered locally). Every device therefore already holds BOTH
    its tokens and its expert shard: it processes its local tokens through
    its local experts and the per-token results are summed over `model`
    with ONE psum -- the same wire pattern as a TP MLP, sidestepping the
    XLA involuntary-remat reshard the einsum-level dispatch hits on the
    multi-pod mesh (EXPERIMENTS Sec Roofline summary).

    Capacity is per local expert: ceil(T_local * k / E * capacity_mult).
    """
    import math
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    names = mesh.axis_names
    baxes = tuple(a for a in ("pod", "data") if a in names)
    n_model = mesh.shape["model"]
    E_l = E // n_model
    n_batch = 1
    for a in baxes:
        n_batch *= mesh.shape[a]
    Tl = (B // n_batch) * T
    cap = max(1, math.ceil(Tl * k / E * capacity_mult))

    def local(x_l, router_w, we_in_l, we_out_l):
        m_idx = jax.lax.axis_index("model")
        Bl = x_l.shape[0]
        x2d = x_l.reshape(Tl, d)
        probs, rate = router_probs_lamp(x2d, router_w, lamp_site)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
        flat_e = top_e.reshape(-1)
        local_id = flat_e - m_idx * E_l
        mine = (local_id >= 0) & (local_id < E_l)
        key = jnp.where(mine, local_id, E_l)            # foreign -> sentinel
        order = jnp.argsort(key, stable=True)
        sorted_key = key[order]
        seg_start = jnp.searchsorted(sorted_key, jnp.arange(E_l + 1))
        pos_sorted = jnp.arange(Tl * k) - seg_start[jnp.minimum(sorted_key, E_l)]
        pos = jnp.zeros(Tl * k, jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
        keep = mine & (pos < cap)
        pos_c = jnp.minimum(jnp.maximum(pos, 0), cap - 1)
        lid_c = jnp.minimum(jnp.maximum(local_id, 0), E_l - 1)
        tok_idx = jnp.repeat(jnp.arange(Tl), k)
        contrib = jnp.where(keep[:, None], x2d[tok_idx], 0).astype(x2d.dtype)
        buf = jnp.zeros((E_l, cap, d), x2d.dtype).at[lid_c, pos_c].add(contrib)
        # FSDP: assemble full expert weights for the local expert shard
        w_in = jax.lax.all_gather(we_in_l, "data", axis=1, tiled=True)
        w_out = jax.lax.all_gather(we_out_l, "data", axis=2, tiled=True)
        h = jnp.einsum("ecd,edf->ecf", buf, w_in)
        if cfg.act in ("swiglu", "geglu"):
            ff = w_out.shape[1]
            g, u = h[..., :ff], h[..., ff:]
            a = jax.nn.silu(g.astype(jnp.float32)) if cfg.act == "swiglu" \
                else jax.nn.gelu(g.astype(jnp.float32), approximate=True)
            h = (a * u.astype(jnp.float32)).astype(h.dtype)
        else:
            h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(h.dtype)
        out_buf = jnp.einsum("ecf,efd->ecd", h, w_out)
        y_tok = out_buf[lid_c, pos_c] * keep[:, None]
        w_gate = top_p.reshape(-1)[:, None].astype(y_tok.dtype)
        y_l = jnp.zeros((Tl, d), y_tok.dtype).at[tok_idx].add(y_tok * w_gate)
        y_l = jax.lax.psum(y_l, "model")                 # combine expert shards
        kept = jax.lax.psum(jnp.sum(keep.astype(jnp.float32)), "model") / (Tl * k)
        return y_l.reshape(Bl, T, d), rate, kept

    bspec = baxes if baxes else None
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec, None, None), P(), P("model", "data", None),
                  P("model", None, "data")),
        out_specs=(P(bspec, None, None), P(), P()),
        check_rep=False)
    y, rate, kept = fn(x, p["router"], p["we_in"], p["we_out"])
    metrics = {"router_lamp_rate": rate, "kept_frac": kept,
               "moe_aux_loss": jnp.zeros(())}
    return y.astype(x.dtype), metrics
