"""Quickstart: LAMP on a single composition f(g(x)) = softmax(A @ x).

Shows the whole idea in 40 lines: accumulate the matmul in PS(mu), look
ahead at the softmax to find the numerically sensitive entries (rule (8)),
recompute only those in FP32, and compare the error against uniform
low-precision evaluation.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import dot_ps, lamp_matmul_softmax, masked_softmax


def main():
    key = jax.random.PRNGKey(0)
    d, n = 64, 256
    A = jax.random.normal(key, (1, n, d)) * 1.2      # "keys"
    x = jax.random.normal(jax.random.PRNGKey(1), (1, d, n)) * 1.2  # "queries"

    z_exact = jax.nn.softmax(jnp.matmul(A, x), axis=-1)

    mu, tau = 4, 0.05
    # uniform low precision (no recompute)
    z_low, _, _ = lamp_matmul_softmax(A, x, mu, tau, rule="none")
    # LAMP: strict rule (8)
    z_lamp, y_adapt, mask = lamp_matmul_softmax(A, x, mu, tau, rule="strict")

    def kl(p, q):
        return float(jnp.mean(jnp.sum(
            p * (jnp.log(p + 1e-30) - jnp.log(q + 1e-30)), -1)))

    rate = float(jnp.mean(mask))
    print(f"PS(mu={mu}) accumulation, LAMP threshold tau={tau}")
    print(f"  KL(exact || uniform-low) = {kl(z_exact, z_low):.3e}")
    print(f"  KL(exact || LAMP)        = {kl(z_exact, z_lamp):.3e}")
    print(f"  recompute rate           = {rate:.2%}")
    print(f"  improvement              = "
          f"{kl(z_exact, z_low) / max(kl(z_exact, z_lamp), 1e-30):.0f}x")


if __name__ == "__main__":
    main()
