"""Fault-tolerance demo: train, simulate a preemption, resume from the
atomic checkpoint, and verify the loss trajectory continues seamlessly.

    PYTHONPATH=src python examples/train_resume.py
"""

import shutil
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config, reduced
from repro.data.synthetic import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.runtime.train_loop import TrainLoopConfig, train


def main():
    ckpt_dir = Path(tempfile.mkdtemp(prefix="lamp_ckpt_"))
    cfg = reduced(get_config("glm4-9b"), layers=2, d_model=64, vocab=256)
    mesh = make_host_mesh()
    data = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4, branching=4)

    print("=== phase 1: train 20 steps, checkpoint every 10 ===")
    loop1 = TrainLoopConfig(total_steps=20, checkpoint_every=10, log_every=5,
                            checkpoint_dir=str(ckpt_dir))
    out1 = train(cfg, mesh, loop1, data_cfg=data)
    print(f"phase 1 ran {len(out1['metrics'])} steps "
          f"(simulated preemption after step 19)\n")

    print("=== phase 2: resume -> continue to step 40 ===")
    loop2 = TrainLoopConfig(total_steps=40, checkpoint_every=10, log_every=5,
                            checkpoint_dir=str(ckpt_dir))
    out2 = train(cfg, mesh, loop2, data_cfg=data)
    print(f"phase 2 ran {len(out2['metrics'])} steps (resumed, not restarted)")

    l1 = [m["loss"] for m in out1["metrics"]]
    l2 = [m["loss"] for m in out2["metrics"]]
    print(f"\nloss: start {l1[0]:.4f} -> preempt {l1[-1]:.4f} -> "
          f"end {l2[-1]:.4f}")
    assert len(out2["metrics"]) == 20, "resume must run only remaining steps"
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    print("OK: checkpoint-restart continued the run exactly.")


if __name__ == "__main__":
    main()
