"""Paper reproduction in miniature: Figure 1 on a reduced GPT-2.

Runs the paper's exact experiment shape -- KQ inner products accumulated in
PS(mu), LAMP-selected products recomputed in FP32, KL divergence against the
uniform-FP32 reference -- across mu, with the random-recompute control arm.

    PYTHONPATH=src python examples/paper_repro.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import SMALL, build_model, eval_policy, make_batches
from repro.core.policy import LampPolicy


def main():
    cfg, params = build_model(SMALL)
    batches = make_batches(cfg, n_batches=2)
    tau = 0.1
    print(f"{'mu':>3s} {'KL uniform':>12s} {'KL LAMP':>12s} {'KL random':>12s} "
          f"{'rate':>7s} {'gain':>7s}")
    for mu in (3, 4, 5, 7, 10):
        uni = eval_policy(cfg, params, batches,
                          LampPolicy.paper_default(mu=mu, tau=1e9))
        lamp = eval_policy(cfg, params, batches,
                           LampPolicy.paper_default(mu=mu, tau=tau))
        rand = eval_policy(cfg, params, batches,
                           LampPolicy.paper_default(mu=mu, tau=tau,
                                                    rule="random"))
        gain = uni["kl"] / max(lamp["kl"], 1e-12)
        print(f"{mu:3d} {uni['kl']:12.3e} {lamp['kl']:12.3e} "
              f"{rand['kl']:12.3e} {lamp['recompute_rate']:7.2%} {gain:6.0f}x")
    print("\nPaper claims reproduced: LAMP gains 1-2 orders of magnitude at "
          "~10% recompute; random recompute gains nothing; rate ~ mu-independent.")


if __name__ == "__main__":
    main()
