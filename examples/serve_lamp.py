"""End-to-end serving driver: batched requests against a small model with
LAMP inference enabled (the paper's deployment scenario).

Prefills a batch of prompts, decodes new tokens with the relaxed-LAMP
attention path + router-LAMP (for MoE), and reports throughput and the
LAMP recompute rate. Runs on any arch:

    PYTHONPATH=src python examples/serve_lamp.py [arch]
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import api
from repro.runtime.serve_loop import ServeConfig, generate


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "qwen3-moe-30b-a3b"
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)

    batch_size, prompt_len, new_tokens = 4, 32, 24
    batch = {"tokens": jax.random.randint(key, (batch_size, prompt_len),
                                          0, cfg.vocab)}
    if cfg.family == "whisper":
        batch["frames"] = jax.random.normal(
            key, (batch_size, cfg.enc_seq, cfg.d_model)) * 0.1
    if cfg.family == "llava":
        batch["image_embeds"] = jax.random.normal(
            key, (batch_size, cfg.n_patches, cfg.d_model)) * 0.1

    cache_len = prompt_len + new_tokens + cfg.n_patches + cfg.n_meta_tokens + 8
    for use_lamp in (False, True):
        serve = ServeConfig(max_new_tokens=new_tokens, temperature=0.7,
                            use_lamp=use_lamp, cache_len=cache_len, seed=7)
        out = generate(cfg, params, batch, serve)
        tag = "LAMP" if use_lamp else "FP32"
        print(f"[{tag}] prefill {out['prefill_s']*1e3:6.0f}ms  "
              f"decode {out['decode_tok_per_s']:6.1f} tok/s  "
              f"first-seq tokens: {out['tokens'][0][:8].tolist()}")
    print("\n(LAMP serving: KQ products in PS(mu) with rule-(9) selective "
          "FP32 recompute; MoE router logits under rule (8).)")


if __name__ == "__main__":
    main()
