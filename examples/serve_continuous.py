"""Continuous-batching serving demo: the paged-KV LAMP engine.

Feeds a burst of variable-length requests to `serving.LampEngine`, streams
completions as they finish (not in arrival order -- short requests overtake
long ones), and prints per-request LAMP recompute rates: the paper's
telemetry, now observable per serving request.

The fused single-launch mixed step (scheduler emits one mixed
prefill+decode+verify plan per step; the engine runs it as one bucketed
jitted call) is the default; pass --no-fused to fall back to the split
per-phase launches.

    PYTHONPATH=src python examples/serve_continuous.py [arch] [--no-fused]
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import api
from repro.serving import EngineConfig, LampEngine, SamplingParams


def main():
    flags = {"--fused", "--no-fused"}
    args = [a for a in sys.argv[1:] if a not in flags]
    fused = "--no-fused" not in sys.argv[1:]
    arch = args[0] if args else "gpt2"
    cfg = reduced(get_config(arch))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=8, max_model_len=96, use_lamp=True, fused_step=fused))

    rng = np.random.default_rng(7)
    for i in range(8):
        plen = int(rng.integers(4, 32))
        new = int(rng.integers(4, 24))
        engine.add_request(rng.integers(0, cfg.vocab, size=plen).tolist(),
                           SamplingParams(max_new_tokens=new, seed=i,
                                          temperature=0.7))

    print(f"[demo] {arch}: 8 requests, pool "
          f"{engine.pool.num_total}x{engine.pool.block_size} blocks")
    while engine.has_unfinished():
        for o in engine.step():
            print(f"[demo] req {o.req_id} finished: {len(o.prompt)} prompt + "
                  f"{len(o.tokens)} new tokens, "
                  f"lamp recompute rate {o.lamp_recompute_rate:.4f}, "
                  f"tokens: {o.tokens[:6]}...")
    s = engine.stats()
    shape = (f"{s['mixed_steps']} mixed, {s['launches']} launches"
             if fused else
             f"{s['prefill_steps']} prefill/{s['decode_steps']} decode")
    print(f"[demo] {s['tokens_per_s']:.1f} tok/s over {s['steps']} steps "
          f"({shape}), kv util mean {s['kv_util_mean']:.2%}, "
          f"aggregate lamp rate {s['lamp_recompute_rate']:.4f}")


if __name__ == "__main__":
    main()
