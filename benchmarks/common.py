"""Shared benchmark harness for the paper-reproduction experiments.

Changed assumption vs the paper (DESIGN.md Sec 7): no pretrained GPT-2
weights or OpenWebText offline. We use the GPT-2 architecture with seeded
random weights whose QK scale is calibrated to produce trained-model-like
logit ranges (concentrated attention), and deterministic synthetic token
streams. All reported comparisons are *relative* (LAMP vs uniform vs random,
strict vs relaxed, trends in mu/tau), which survive this substitution.
"""

from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.policy import LampPolicy
from repro.data.synthetic import DataConfig, SyntheticDataset
from repro.models import api, transformer

# benchmark model scales (GPT-2 family, reduced for CPU)
SMALL = dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_ff=512,
             vocab=512, max_seq=256)
LARGE = dict(n_layers=8, d_model=256, n_heads=8, n_kv_heads=8, d_ff=1024,
             vocab=512, max_seq=256)
SEQ = 128
BATCH = 2
QK_GAIN = 2.0   # calibrates attention-logit std toward trained-model range


def build_model(scale: Dict = SMALL, seed: int = 0):
    cfg = get_config("gpt2-small").replace(**scale)
    params = api.init_params(cfg, jax.random.PRNGKey(seed))
    # concentrate attention: scale query/key projections
    blocks = dict(params["blocks"])
    attn = dict(blocks["attn"])
    attn["wq"] = attn["wq"] * QK_GAIN
    attn["wk"] = attn["wk"] * QK_GAIN
    blocks["attn"] = attn
    params = {**params, "blocks": blocks}
    return cfg, params


def make_batches(cfg, n_batches: int = 2, *, seed: int = 0, kind: str = "markov",
                 branching: int = 8, permute: bool = False):
    ds = SyntheticDataset(DataConfig(vocab=cfg.vocab, seq_len=SEQ,
                                     global_batch=BATCH, seed=seed, kind=kind,
                                     branching=branching))
    out = []
    rng = np.random.default_rng(seed + 99)
    for i in range(n_batches):
        b = ds.batch_at(i)["tokens"]
        if permute:
            b = np.stack([row[rng.permutation(len(row))] for row in b])
        out.append({"tokens": jnp.asarray(b)})
    return out


def eval_policy(cfg, params, batches, policy: Optional[LampPolicy],
                ) -> Dict[str, float]:
    """Run the model under `policy` and compare to the FP32 reference.
    Returns mean KL, flip rate, recompute rate, nll (for perplexity)."""
    kls, flips, rates, nlls = [], [], [], []
    for batch in batches:
        ref_logits, _ = transformer.forward(
            cfg.replace(lamp=LampPolicy.disabled()), params, batch["tokens"],
            use_lamp=False, attn_impl="full")
        if policy is None:
            test_logits, aux = ref_logits, {"attn_lamp_rate": 0.0}
        else:
            test_logits, aux = transformer.forward(
                cfg.replace(lamp=policy), params, batch["tokens"],
                use_lamp=True, attn_impl="full")
        p = jax.nn.softmax(ref_logits, -1)
        lp = jax.nn.log_softmax(ref_logits, -1)
        lq = jax.nn.log_softmax(test_logits, -1)
        kls.append(float(jnp.mean(jnp.sum(p * (lp - lq), -1))))
        flips.append(float(jnp.mean(
            (jnp.argmax(test_logits, -1) != jnp.argmax(ref_logits, -1)))))
        rates.append(float(aux["attn_lamp_rate"]))
        tgt = batch["tokens"][:, 1:]
        nll = -jnp.take_along_axis(jax.nn.log_softmax(
            test_logits[:, :-1], -1), tgt[..., None], -1)
        nlls.append(float(jnp.mean(nll)))
    return {
        "kl": float(np.mean(kls)),
        "flip_rate": float(np.mean(flips)),
        "recompute_rate": float(np.mean(rates)),
        "perplexity": float(np.exp(np.mean(nlls))),
    }


def timed(fn, *args, warmup: int = 1, iters: int = 3) -> Tuple[float, object]:
    out = None
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6, out


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")
