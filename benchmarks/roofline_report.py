"""Roofline report: aggregates results/dryrun/*.json into the EXPERIMENTS
table and emits one CSV row per (arch x shape x mesh) cell."""

from __future__ import annotations

import json
from pathlib import Path

from .common import emit

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(tag: str = ""):
    cells = []
    for f in sorted(RESULTS.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("overrides_tag", "") != tag:
            continue
        cells.append(rec)
    return cells


def roofline_report():
    cells = load_cells()
    if not cells:
        emit("roofline_report", 0.0, "no dryrun results; run repro.launch.dryrun")
        return
    n_ok = n_skip = n_err = 0
    for rec in cells:
        name = f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh']}"
        if rec["status"] == "skipped":
            n_skip += 1
            emit(name, 0.0, "skipped:" + rec["reason"][:60])
            continue
        if rec["status"] != "ok":
            n_err += 1
            emit(name, 0.0, "error:" + rec.get("error", "?")[:80])
            continue
        n_ok += 1
        r = rec["roofline"]
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(name, step_s * 1e6,
             f"dominant={r['dominant']};compute_s={r['compute_s']:.3g};"
             f"memory_s={r['memory_s']:.3g};collective_s={r['collective_s']:.3g};"
             f"useful_flops_ratio={rec['useful_flops_ratio']:.3f};"
             f"hbm_bytes/dev={rec['memory'].get('peak_bytes_est', 0)}")
    emit("roofline_summary", 0.0, f"ok={n_ok};skipped={n_skip};error={n_err}")


def _lever(rec) -> str:
    """One sentence: what would move the dominant term down."""
    dom = rec["roofline"]["dominant"]
    kind = ("decode" if "decode" in rec["shape"] or "500k" in rec["shape"]
            else "prefill" if "prefill" in rec["shape"] else "train")
    moe = "moe" in rec["arch"] or "olmoe" in rec["arch"]
    if kind == "decode" and dom == "memory":
        return "per-token KV-cache read is the floor; next: int8/fp8 cache (complementary to LAMP per paper Sec 1.2)"
    if kind == "decode" and dom == "collective":
        return "small model: replicate serving weights instead of FSDP-gathering them each step"
    if kind == "prefill" and dom == "memory":
        return "materialized online-softmax logit blocks; fused Pallas lamp_attention keeps them in VMEM"
    if kind == "prefill" and dom == "collective":
        return ("all-to-all expert dispatch; larger dispatch groups + fused a2a"
                if moe else "FSDP weight gathers; gather-once weight caching across q-tiles")
    if dom == "collective":
        return ("expert all-to-all + FSDP gathers; hybrid-shard experts or "
                "grad compression (optim/compression.py)" if moe else
                "per-layer FSDP weight gathers; larger per-device batch or 2D hybrid sharding amortizes them")
    return "activation traffic under remat; microbatching trades it against collectives"


def roofline_fraction(rec) -> float:
    """MODEL_FLOPS-time / dominant term: fraction of ideal compute-bound
    step time actually achievable (1.0 = at the compute roofline)."""
    r = rec["roofline"]
    from .common import emit  # noqa: F401  (no-op; keeps import graph simple)
    from repro.launch.mesh import PEAK_FLOPS_BF16
    model_time = rec["model_flops_per_device"] / PEAK_FLOPS_BF16
    dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
    return model_time / dom if dom else 0.0


def markdown_table(cells=None, tag: str = "") -> str:
    """EXPERIMENTS.md-ready table for the single-pod baseline."""
    cells = cells if cells is not None else load_cells(tag)
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | 6ND/2ND vs HLO | roofline frac | HBM GB/dev | lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in cells:
        if rec["status"] == "skipped":
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                         f"-- | -- | -- | skipped | -- | -- | -- | "
                         f"{rec['reason'][:60]} |")
            continue
        if rec["status"] != "ok":
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                         f"ERR | | | | | | | |")
            continue
        r = rec["roofline"]
        mem = rec["memory"].get("peak_bytes_est", 0) / 1e9
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
            f"{r['compute_s']:.3g} | {r['memory_s']:.3g} | "
            f"{r['collective_s']:.3g} | **{r['dominant']}** | "
            f"{rec['useful_flops_ratio']:.2f} | {roofline_fraction(rec):.3f} | "
            f"{mem:.2f} | {_lever(rec)} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
