"""Paper figure/table reproductions (one function per figure).

Each emits CSV rows `name,us_per_call,derived` where `derived` packs the
figure's metrics. Qualitative claims validated per figure are listed in
EXPERIMENTS.md with the measured numbers.
"""

from __future__ import annotations

import functools

import numpy as np

from repro.core.policy import LampPolicy

from .common import (LARGE, SMALL, build_model, emit, eval_policy,
                     make_batches, timed)


def _policy(mu, tau, rule="strict", granularity=1):
    return LampPolicy.paper_default(mu=mu, tau=tau, rule=rule,
                                    granularity=granularity)


_UNIFORM_TAU = 1e9  # strict rule with huge tau selects nothing == uniform low


def fig1_kl_vs_mu():
    """Fig 1: KL vs mu at tau=0.1 -- uniform / LAMP / random-control."""
    cfg, params = build_model(SMALL)
    batches = make_batches(cfg)
    for mu in (3, 4, 5, 7, 10):
        r_uni = eval_policy(cfg, params, batches, _policy(mu, _UNIFORM_TAU))
        us, _ = timed(lambda: eval_policy(cfg, params, batches[:1],
                                          _policy(mu, 0.1)))
        r_lamp = eval_policy(cfg, params, batches, _policy(mu, 0.1))
        r_rand = eval_policy(cfg, params, batches, _policy(mu, 0.1, "random"))
        emit(f"fig1_mu{mu}", us,
             f"kl_uniform={r_uni['kl']:.3e};kl_lamp={r_lamp['kl']:.3e};"
             f"kl_random={r_rand['kl']:.3e};rate={r_lamp['recompute_rate']:.4f}")


def fig2_tau_sweep():
    """Fig 2: tau sweep per mu -- KL, flip rate, recompute rate."""
    cfg, params = build_model(SMALL)
    batches = make_batches(cfg)
    for mu in (3, 4, 6):
        for tau in (0.4, 0.2, 0.1, 0.05, 0.02):
            us, _ = timed(lambda: eval_policy(cfg, params, batches[:1],
                                              _policy(mu, tau)))
            r = eval_policy(cfg, params, batches, _policy(mu, tau))
            emit(f"fig2_mu{mu}_tau{tau}", us,
                 f"kl={r['kl']:.3e};flip={r['flip_rate']:.4f};"
                 f"rate={r['recompute_rate']:.4f}")


def fig3_strict_vs_relaxed():
    """Fig 3: Pareto boundaries of strict (8) vs relaxed (9) at mu=4."""
    cfg, params = build_model(SMALL)
    batches = make_batches(cfg)
    for rule, taus in (("strict", (0.4, 0.1, 0.02, 0.005)),
                       ("relaxed", (0.8, 0.4, 0.1, 0.02))):
        for tau in taus:
            us, _ = timed(lambda: eval_policy(cfg, params, batches[:1],
                                              _policy(4, tau, rule)))
            r = eval_policy(cfg, params, batches, _policy(4, tau, rule))
            emit(f"fig3_{rule}_tau{tau}", us,
                 f"kl={r['kl']:.3e};flip={r['flip_rate']:.4f};"
                 f"rate={r['recompute_rate']:.4f}")


def fig4_datasets():
    """Fig 4 (C.1): input-agnosticism across dataset structures."""
    cfg, params = build_model(SMALL)
    for name, kw in (("markov8", dict(kind="markov", branching=8)),
                     ("markov2", dict(kind="markov", branching=2)),
                     ("uniform", dict(kind="uniform"))):
        batches = make_batches(cfg, **kw)
        us, _ = timed(lambda: eval_policy(cfg, params, batches[:1],
                                          _policy(4, 0.1)))
        r = eval_policy(cfg, params, batches, _policy(4, 0.1))
        emit(f"fig4_{name}", us,
             f"kl={r['kl']:.3e};rate={r['recompute_rate']:.4f}")


def fig5_model_scale():
    """Fig 5 (C.2): larger model benefits at least as much."""
    batches_ref = None
    for name, scale in (("small", SMALL), ("large", LARGE)):
        cfg, params = build_model(scale)
        batches = make_batches(cfg)
        r_uni = eval_policy(cfg, params, batches, _policy(4, _UNIFORM_TAU))
        us, _ = timed(lambda: eval_policy(cfg, params, batches[:1],
                                          _policy(4, 0.1)))
        r = eval_policy(cfg, params, batches, _policy(4, 0.1))
        emit(f"fig5_{name}", us,
             f"kl_uniform={r_uni['kl']:.3e};kl_lamp={r['kl']:.3e};"
             f"gain={r_uni['kl'] / max(r['kl'], 1e-12):.1f}x;"
             f"rate={r['recompute_rate']:.4f}")


def fig6_permuted():
    """Fig 6 (C.3): token-order permutation does not break LAMP."""
    cfg, params = build_model(SMALL)
    for name, permute in (("direct", False), ("permuted", True)):
        batches = make_batches(cfg, permute=permute)
        us, _ = timed(lambda: eval_policy(cfg, params, batches[:1],
                                          _policy(4, 0.1)))
        r = eval_policy(cfg, params, batches, _policy(4, 0.1))
        emit(f"fig6_{name}", us,
             f"kl={r['kl']:.3e};flip={r['flip_rate']:.4f};"
             f"rate={r['recompute_rate']:.4f}")


def fig7_random_control():
    """Fig 7 (C.4): Pareto of LAMP vs random recompute across tau."""
    cfg, params = build_model(SMALL)
    batches = make_batches(cfg)
    for tau in (0.4, 0.1, 0.02):
        r_lamp = eval_policy(cfg, params, batches, _policy(4, tau))
        us, _ = timed(lambda: eval_policy(cfg, params, batches[:1],
                                          _policy(4, tau, "random")))
        r_rand = eval_policy(cfg, params, batches, _policy(4, tau, "random"))
        emit(f"fig7_tau{tau}", us,
             f"kl_lamp={r_lamp['kl']:.3e};kl_random={r_rand['kl']:.3e};"
             f"rate={r_lamp['recompute_rate']:.4f}")


def table1_perplexity():
    """Table 1 (C.5): perplexity -- full / low / relaxed / relaxed-LN."""
    cfg, params = build_model(SMALL)
    for ds_name, kw in (("markov8", dict(kind="markov", branching=8)),
                        ("markov2", dict(kind="markov", branching=2)),
                        ("uniform", dict(kind="uniform"))):
        batches = make_batches(cfg, **kw)
        rows = [("full", None),
                ("low", _policy(4, _UNIFORM_TAU)),
                ("relaxed_t03", _policy(4, 0.03, "relaxed")),
                ("relaxed_ln_t03", _policy(4, 0.03, "relaxed_ln")),
                ("relaxed_t09", _policy(4, 0.09, "relaxed")),
                ("relaxed_ln_t09", _policy(4, 0.09, "relaxed_ln"))]
        for mname, pol in rows:
            us, _ = timed(lambda: eval_policy(cfg, params, batches[:1], pol),
                          warmup=1, iters=1)
            r = eval_policy(cfg, params, batches, pol)
            emit(f"table1_{ds_name}_{mname}", us,
                 f"ppl={r['perplexity']:.4f};rate={r['recompute_rate']:.4f}")


ALL = [fig1_kl_vs_mu, fig2_tau_sweep, fig3_strict_vs_relaxed, fig4_datasets,
       fig5_model_scale, fig6_permuted, fig7_random_control, table1_perplexity]


def rwkv_logits_site():
    """Beyond-paper: LAMP at the LM-head -> sampling-softmax site for the
    attention-free rwkv6 (DESIGN.md Sec 6 -- the arch has no KQ softmax).
    Rule (8) on final logits protects the sampling distribution under
    low-precision logit computation."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.models import api
    from repro.runtime.serve_loop import lamp_logits_softmax
    from repro.core.numerics import round_to_mantissa

    cfg = reduced(get_config("rwkv6-7b"), d_model=128, vocab=2048)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    logits = api.forward_logits(cfg, params, {"tokens": toks}) * 4.0
    p_ref = jax.nn.softmax(logits, -1)
    for mu in (4, 6):
        p_low = jax.nn.softmax(round_to_mantissa(logits, mu), -1)
        us, (p_lamp, rate) = timed(
            lambda: lamp_logits_softmax(logits, mu, 0.05))
        kl_low = float(jnp.mean(jnp.sum(
            p_ref * (jnp.log(p_ref + 1e-20) - jnp.log(p_low + 1e-20)), -1)))
        kl_lamp = float(jnp.mean(jnp.sum(
            p_ref * (jnp.log(p_ref + 1e-20) - jnp.log(p_lamp + 1e-20)), -1)))
        emit(f"rwkv_logits_site_mu{mu}", us,
             f"kl_low={kl_low:.3e};kl_lamp={kl_lamp:.3e};"
             f"rate={float(rate):.4f}")


ALL.append(rwkv_logits_site)


def rmsnorm_site():
    """Paper Sec 3.2 (Props 3.1/3.2): LAMP for the matmul -> RMSNorm
    composition. Greedy prefix selection on the largest y_i^2 vs uniform low
    precision vs random selection of the same size, across tau."""
    import jax
    import jax.numpy as jnp
    from repro.core.lamp import select_rmsnorm
    from repro.core.mixed_matmul import dot_ps

    def rms(y):
        return (len(y) ** 0.5) * y / jnp.maximum(jnp.linalg.norm(y), 1e-30)

    key = jax.random.PRNGKey(0)
    n, kdim, mu = 256, 128, 4
    A = jax.random.normal(key, (n, kdim)) * jnp.exp(
        jax.random.normal(jax.random.PRNGKey(1), (n, 1)))  # heavy-tailed rows
    xv = jax.random.normal(jax.random.PRNGKey(2), (kdim,))
    y_exact = A @ xv
    z_ref = rms(y_exact)
    y_low = dot_ps(A[None], xv[None, :, None], mu, granularity=1)[0, :, 0]

    # The composition-amplified quantity the greedy rule controls is the
    # normalization factor ||y|| (errors there multiply EVERY output);
    # each component's own c_g*u rounding is outside LAMP's scope (Sec 2.2).
    norm_ref = float(jnp.linalg.norm(y_exact))

    def norm_err(y):
        return abs(float(jnp.linalg.norm(y)) - norm_ref) / norm_ref

    err_low = norm_err(y_low)
    # kappa_c for RMSNorm lies in (1, 2]: 2 - sum_in/||y||^2 with tiny
    # y_min (Prop 3.1), so the meaningful threshold range is tau in (1, 2)
    for tau in (1.9, 1.5, 1.2, 1.05):
        us, mask = timed(lambda: select_rmsnorm(y_low, tau))
        y_ad = jnp.where(mask, y_exact, y_low)
        err = norm_err(y_ad)
        # random control of the same size
        rmask = jnp.zeros(n, bool).at[jax.random.permutation(
            jax.random.PRNGKey(3), n)[: int(mask.sum())]].set(True)
        y_rd = jnp.where(rmask, y_exact, y_low)
        err_rand = norm_err(y_rd)
        emit(f"rmsnorm_site_tau{tau}", us,
             f"err_low={err_low:.3e};err_lamp={err:.3e};"
             f"err_random={err_rand:.3e};rate={float(mask.mean()):.4f}")


ALL.append(rmsnorm_site)
