"""Kernel microbenchmarks: interpret-mode allclose + wall time per call.

Interpret-mode wall time on CPU is NOT TPU performance -- the derived column
carries the correctness deltas and the work size; TPU perf is modeled in the
roofline report (results/dryrun).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import emit, timed


def kernels_micro():
    key = jax.random.PRNGKey(0)

    # lamp_flash_attention
    B, H, T, D = 1, 4, 256, 64
    q = jax.random.normal(key, (B, H, T, D)) * 1.5
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, T, D)) * 1.5
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, T, D))
    kw = dict(mu=7, tau=0.05, causal=True, block_q=64, block_k=64, k_subtile=32)
    us, (out, nsel) = timed(
        lambda: ops.lamp_flash_attention(q, k, v, interpret=True, **kw))
    want, nref = ref.lamp_flash_attention_ref(q, k, v, **kw)
    err = float(jnp.max(jnp.abs(out - want)))
    emit("kernel_lamp_attention_256", us,
         f"max_err={err:.2e};nsel={int(nsel)};nsel_ref={int(nref)};"
         f"flops={4 * B * H * T * T * D}")

    # flash_decode
    S = 2048
    qd = jax.random.normal(key, (2, 4, 1, 64)) * 1.5
    kc = jax.random.normal(jax.random.PRNGKey(3), (2, 4, S, 64)) * 1.5
    vc = jax.random.normal(jax.random.PRNGKey(4), (2, 4, S, 64))
    length = jnp.array([S, S - 100])
    us, (out, nsel) = timed(
        lambda: ops.flash_decode(qd, kc, vc, length, mu=7, tau=0.05,
                                 block_k=256, k_subtile=32, interpret=True))
    want, nref = ref.flash_decode_ref(qd, kc, vc, length, mu=7, tau=0.05,
                                      block_k=256, k_subtile=32)
    emit("kernel_flash_decode_2k", us,
         f"max_err={float(jnp.max(jnp.abs(out - want))):.2e};"
         f"nsel={int(nsel)};nsel_ref={int(nref)}")

    # ps_matmul
    a = jax.random.normal(key, (256, 256))
    b = jax.random.normal(jax.random.PRNGKey(5), (256, 256))
    us, out = timed(lambda: ops.ps_matmul(a, b, mu=7, interpret=True))
    want = ref.ps_matmul_ref(a, b, 7, 128)
    emit("kernel_ps_matmul_256", us,
         f"max_err={float(jnp.max(jnp.abs(out - want))):.2e};"
         f"flops={2 * 256 ** 3}")

    # rmsnorm
    x = jax.random.normal(key, (1024, 512)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(6), (512,)) * 0.1
    us, out = timed(lambda: ops.rmsnorm(x, w, interpret=True))
    want = ref.rmsnorm_ref(x, w)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    emit("kernel_rmsnorm_1024x512", us, f"max_err={err:.2e}")
