"""Kernel microbenchmarks: interpret-mode allclose + wall time per call.

Interpret-mode wall time on CPU is NOT TPU performance -- the derived column
carries the correctness deltas and the work size; TPU perf is modeled in the
roofline report (results/dryrun).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import decode_attention_lamp
from repro.core.policy import LampSite
from repro.kernels import ops, ref
from repro.kernels.paged_attention import decode_kv_bytes

from .common import emit, timed


def paged_decode_micro(R: int = 8, H: int = 4, Hkv: int = 2, hd: int = 64,
                       bs: int = 16, n_max: int = 16):
    """Gather-vs-fused paged decode at R concurrent ragged sequences."""
    rng = np.random.default_rng(0)
    n_blocks = 1 + R * n_max
    arena_k = jnp.asarray(rng.normal(size=(n_blocks, bs, Hkv, hd)) * 1.5,
                          jnp.float32)
    arena_v = jnp.asarray(rng.normal(size=(n_blocks, bs, Hkv, hd)),
                          jnp.float32)
    lengths = jnp.asarray(rng.integers(1, n_max * bs, size=R), jnp.int32)
    perm = rng.permutation(np.arange(1, n_blocks))
    bt = np.zeros((R, n_max), np.int32)
    for r in range(R):
        nb = -(-int(lengths[r]) // bs)
        bt[r, :nb] = perm[r * n_max:r * n_max + nb]
    bt = jnp.asarray(bt)
    q = jnp.asarray(rng.normal(size=(R, H, 1, hd)) * 1.5, jnp.float32)
    site = LampSite(enabled=True, rule="relaxed", mu=7, tau=0.05,
                    granularity=0)

    @jax.jit
    def gather_decode(q, ak, av, bt, lengths):
        ks = ak[bt].reshape(R, -1, Hkv, hd)
        vs = av[bt].reshape(R, -1, Hkv, hd)
        kh = jnp.repeat(jnp.moveaxis(ks, 2, 1), H // Hkv, axis=1)
        vh = jnp.repeat(jnp.moveaxis(vs, 2, 1), H // Hkv, axis=1)
        out, aux = decode_attention_lamp(q, kh, vh, lengths, site,
                                         reduce=False)
        return out, aux.n_selected

    us_g, (out_g, nsel_g) = timed(
        lambda: gather_decode(q, arena_k, arena_v, bt, lengths))
    us_f, (out_f, nsel_f) = timed(
        lambda: ops.paged_decode_attention(q, arena_k, arena_v, bt, lengths,
                                           site, interpret=True))
    err = float(jnp.max(jnp.abs(out_f - out_g)))
    b_gather, b_fused = decode_kv_bytes(
        np.asarray(lengths), n_max=n_max, block_size=bs,
        bytes_per_token=Hkv * hd * 4, lamp=True)
    emit("kernel_paged_decode_gather", us_g,
         f"bytes_kv={b_gather};nsel={int(jnp.sum(nsel_g))}")
    emit("kernel_paged_decode_fused", us_f,
         f"bytes_kv={b_fused};nsel={int(jnp.sum(nsel_f))};max_err={err:.2e};"
         f"bytes_saved={1.0 - b_fused / b_gather:.1%}")


def kernels_micro():
    key = jax.random.PRNGKey(0)

    # lamp_flash_attention
    B, H, T, D = 1, 4, 256, 64
    q = jax.random.normal(key, (B, H, T, D)) * 1.5
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, T, D)) * 1.5
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, T, D))
    kw = dict(mu=7, tau=0.05, causal=True, block_q=64, block_k=64, k_subtile=32)
    us, (out, nsel) = timed(
        lambda: ops.lamp_flash_attention(q, k, v, interpret=True, **kw))
    want, nref = ref.lamp_flash_attention_ref(q, k, v, **kw)
    err = float(jnp.max(jnp.abs(out - want)))
    emit("kernel_lamp_attention_256", us,
         f"max_err={err:.2e};nsel={int(nsel)};nsel_ref={int(nref)};"
         f"flops={4 * B * H * T * T * D}")

    # flash_decode
    S = 2048
    qd = jax.random.normal(key, (2, 4, 1, 64)) * 1.5
    kc = jax.random.normal(jax.random.PRNGKey(3), (2, 4, S, 64)) * 1.5
    vc = jax.random.normal(jax.random.PRNGKey(4), (2, 4, S, 64))
    length = jnp.array([S, S - 100])
    us, (out, nsel) = timed(
        lambda: ops.flash_decode(qd, kc, vc, length, mu=7, tau=0.05,
                                 block_k=256, k_subtile=32, interpret=True))
    want, nref = ref.flash_decode_ref(qd, kc, vc, length, mu=7, tau=0.05,
                                      block_k=256, k_subtile=32)
    emit("kernel_flash_decode_2k", us,
         f"max_err={float(jnp.max(jnp.abs(out - want))):.2e};"
         f"nsel={int(nsel)};nsel_ref={int(nref)}")

    # paged decode: gather reference vs fused kernel over one block arena.
    # Interpret-mode wall time is not TPU perf; the decisive column is the
    # modeled KV bytes DMA'd per step (the gather path always moves the
    # full block-table span, the fused kernel only live blocks).
    paged_decode_micro()

    # ps_matmul
    a = jax.random.normal(key, (256, 256))
    b = jax.random.normal(jax.random.PRNGKey(5), (256, 256))
    us, out = timed(lambda: ops.ps_matmul(a, b, mu=7, interpret=True))
    want = ref.ps_matmul_ref(a, b, 7, 128)
    emit("kernel_ps_matmul_256", us,
         f"max_err={float(jnp.max(jnp.abs(out - want))):.2e};"
         f"flops={2 * 256 ** 3}")

    # rmsnorm
    x = jax.random.normal(key, (1024, 512)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(6), (512,)) * 0.1
    us, out = timed(lambda: ops.rmsnorm(x, w, interpret=True))
    want = ref.rmsnorm_ref(x, w)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    emit("kernel_rmsnorm_1024x512", us, f"max_err={err:.2e}")
