"""Benchmark harness entrypoint: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Run:
    PYTHONPATH=src python -m benchmarks.run [--only fig1,fig7,...]
"""

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig1..fig7,table1,kernels,roofline")
    args = ap.parse_args()

    from . import paper_figs
    from .kernels_micro import kernels_micro
    from .roofline_report import roofline_report

    jobs = {
        "fig1": paper_figs.fig1_kl_vs_mu,
        "fig2": paper_figs.fig2_tau_sweep,
        "fig3": paper_figs.fig3_strict_vs_relaxed,
        "fig4": paper_figs.fig4_datasets,
        "fig5": paper_figs.fig5_model_scale,
        "fig6": paper_figs.fig6_permuted,
        "fig7": paper_figs.fig7_random_control,
        "table1": paper_figs.table1_perplexity,
        "rwkv_logits": paper_figs.rwkv_logits_site,
        "rmsnorm_site": paper_figs.rmsnorm_site,
        "kernels": kernels_micro,
        "roofline": roofline_report,
    }
    selected = args.only.split(",") if args.only else list(jobs)
    print("name,us_per_call,derived")
    failed = 0
    for key in selected:
        try:
            jobs[key]()
        except Exception:
            failed += 1
            print(f"{key},0.0,ERROR", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
