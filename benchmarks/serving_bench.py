"""Serving benchmark: continuous-batching engine vs the static-batch loop.

Reports throughput, latency percentiles, KV-block utilization, and the LAMP
overhead (lamp on vs off) for both serving modes on the same request set:

  * static  -- `runtime.serve_loop.generate`: one fixed batch, dense
               per-request KV cache sized to prompt+new, every request padded
               to the longest prompt and decoded for the max new tokens.
  * engine  -- `serving.LampEngine`: paged KV pool + continuous batching;
               requests finish (and free blocks) as their own stop
               conditions hit.

    PYTHONPATH=src python -m benchmarks.serving_bench [--requests 16]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import api
from repro.runtime.serve_loop import ServeConfig, generate
from repro.serving import EngineConfig, LampEngine, SamplingParams


def make_requests(rng, cfg, n, min_prompt=8, max_prompt=40, min_new=4,
                  max_new=24):
    reqs = []
    for i in range(n):
        plen = int(rng.integers(min_prompt, max_prompt + 1))
        new = int(rng.integers(min_new, max_new + 1))
        reqs.append((rng.integers(0, cfg.vocab, size=plen).tolist(), new))
    return reqs


def bench_static(cfg, params, reqs, use_lamp):
    """Static batch: pad everything to the worst case, one generate() call."""
    max_prompt = max(len(p) for p, _ in reqs)
    max_new = max(n for _, n in reqs)
    tokens = np.zeros((len(reqs), max_prompt), np.int32)
    for i, (p, _) in enumerate(reqs):
        tokens[i, max_prompt - len(p):] = p   # right-align; crude but typical
    serve = ServeConfig(max_new_tokens=max_new, use_lamp=use_lamp,
                        cache_len=max_prompt + max_new + 8)
    t0 = time.monotonic()
    out = generate(cfg, params, {"tokens": jnp.asarray(tokens)}, serve)
    jax.block_until_ready(out["tokens"])
    wall = time.monotonic() - t0
    useful = sum(n for _, n in reqs)
    return {"wall_s": wall, "useful_tok_per_s": useful / wall,
            "padded_tok_per_s": len(reqs) * max_new / wall}


def bench_engine(cfg, params, reqs, use_lamp):
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=8, max_model_len=128, use_lamp=use_lamp))
    t0 = time.monotonic()
    for i, (prompt, new) in enumerate(reqs):
        engine.add_request(prompt, SamplingParams(max_new_tokens=new, seed=i))
    outs = engine.run_to_completion()
    wall = time.monotonic() - t0
    lat = sorted(o.latency for o in outs)
    s = engine.stats()
    useful = sum(n for _, n in reqs)
    return {"wall_s": wall, "useful_tok_per_s": useful / wall,
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p99_s": float(np.percentile(lat, 99)),
            "kv_util_mean": s["kv_util_mean"],
            "lamp_rate": s["lamp_recompute_rate"],
            "preemptions": s["preemptions"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduce_cfg(get_config("gpt2"))
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = make_requests(rng, cfg, args.requests)

    print("name,us_per_call,derived")
    results = {}
    for mode in ("static", "engine"):
        for use_lamp in (False, True):
            fn = bench_static if mode == "static" else bench_engine
            # warmup compiles, then measure
            fn(cfg, params, reqs, use_lamp)
            r = fn(cfg, params, reqs, use_lamp)
            results[(mode, use_lamp)] = r
            tag = f"serve_{mode}_{'lamp' if use_lamp else 'fp32'}"
            derived = f"tok/s={r['useful_tok_per_s']:.1f}"
            if mode == "engine":
                derived += (f";p50={r['latency_p50_s']*1e3:.0f}ms"
                            f";p99={r['latency_p99_s']*1e3:.0f}ms"
                            f";kv_util={r['kv_util_mean']:.2f}"
                            f";lamp_rate={r['lamp_rate']:.4f}")
            print(f"{tag},{r['wall_s']*1e6:.0f},{derived}")

    for mode in ("static", "engine"):
        off = results[(mode, False)]["useful_tok_per_s"]
        on = results[(mode, True)]["useful_tok_per_s"]
        print(f"serve_{mode}_lamp_overhead,0,"
              f"overhead={100.0 * (off - on) / off:.1f}%")
    spd = (results[("engine", True)]["useful_tok_per_s"] /
           results[("static", True)]["useful_tok_per_s"])
    print(f"serve_engine_vs_static,0,speedup={spd:.2f}x")


if __name__ == "__main__":
    main()
