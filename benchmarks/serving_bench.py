"""Serving benchmark: continuous-batching engine vs the static-batch loop,
a shared-prefix stream for the prefix cache, and a gather-vs-fused
paged-attention kernel comparison.

Reports throughput, latency percentiles, KV-block utilization, and the LAMP
overhead (lamp on vs off) for both serving modes on the same request set:

  * static  -- `runtime.serve_loop.generate`: one fixed batch, dense
               per-request KV cache sized to prompt+new, every request padded
               to the longest prompt and decoded for the max new tokens.
  * engine  -- `serving.LampEngine`: paged KV pool + continuous batching;
               requests finish (and free blocks) as their own stop
               conditions hit.

The shared-prefix section replays one request stream (groups of prompts
opening with the same system prefix, arrivals staggered so later requests
can hit the cache of earlier ones) through the engine with prefix caching +
chunked prefill ON and OFF, checks the per-request outputs are
token-identical, and reports the KV blocks allocated and prefill tokens
computed by each.

The kernel section replays one decode-heavy stream (every request admitted
up front, so the decode batch stays >= 8 concurrent sequences) through the
engine with kernel="gather" and kernel="pallas", checks the outputs are
token-identical, and reports the measured decode-step latency plus the
modeled per-step KV traffic of each path. On CPU the fused kernel runs in
interpret mode, so its wall time is NOT TPU performance -- the decisive
column is bytes moved (the gather path always streams the full
block-table span; the fused kernel only live blocks).

The speculative section (also standalone via --spec-only, the CI
spec-decode CSV artifact) replays one decode-heavy greedy stream with LAMP
self-draft speculative decoding ON and OFF, asserts token identity, and
reports accepted tokens per decode round (each round replaces that many
sequential decode steps) plus the verify pass's LAMP recompute rate.

The policy section (standalone via --policy-only, the CI policy-bench CSV
artifact) replays one burst stream -- all requests admitted at once into a
deliberately small KV pool -- with the adaptive LAMP policy controller
off, frozen (observe-only; must be token-identical to off), and on. It
asserts the on-arm actually traverses the degradation ladder, triggers
zero recompiles after warmup (tau is a traced operand), does not
meaningfully regress preemptions, and keeps the recompute-rate increase
bounded.

The fused-step section (standalone via --fused-only, the CI fused-step
CSV artifact) replays one decode-heavy greedy stream (every request
admitted up front, chunked prefill + speculation on) through the fused
single-launch mixed step and through its split-execution twin (the same
mixed plans run through the legacy phase-segregated sub-steps), on both
kernels. It asserts token identity, strictly fewer kernel launches per
step, and a smaller jit cache (compiled signatures from cold), and
reports launches/step plus jit-cache entries for each arm.

The observability section (standalone via --obs-only) replays one stream
with step-phase tracing ON and OFF, asserts token identity (observability
must never perturb serving), reports the per-step overhead of tracing, and
emits one CSV row per engine phase (schedule / alloc / prefill / decode /
sync / emit) with its measured mean wall time from the phase histograms.

The shadow-audit section (standalone via --audit-only, the CI audit-bench
CSV artifact) replays one full-feature stream (chunked prefill +
speculation + fused step) with the accuracy auditor on and off. It asserts
audit-on streams token-identical to audit-off on both kernels, that the
per-step overhead at the recommended sampling rate (0.05) stays under 5%,
and that the fused mixed step shows the same audited error as its split
twin -- the burn-in gate behind fused_step defaulting on.

The fault-tolerance section (standalone via --faults-only, the CI chaos
CSV artifact) gates the numerical health guard at < 5%% per-step overhead
when no faults fire (token-identical to guard-off), then replays a
fixed-seed injected-fault stream (NaN poisoning + allocation failures +
a stall) and asserts zero engine crashes, every request individually
finished (recovered ones token-identical to the fault-free run), and
bit-for-bit replay of the whole chaos run.

    PYTHONPATH=src python -m benchmarks.serving_bench [--requests 16]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import api
from repro.obs import ObsConfig
from repro.runtime.serve_loop import ServeConfig, generate
from repro.serving import (AuditConfig, EngineConfig, FaultConfig,
                           LampEngine, PolicyConfig, SamplingParams)


def make_requests(rng, cfg, n, min_prompt=8, max_prompt=40, min_new=4,
                  max_new=24):
    reqs = []
    for i in range(n):
        plen = int(rng.integers(min_prompt, max_prompt + 1))
        new = int(rng.integers(min_new, max_new + 1))
        reqs.append((rng.integers(0, cfg.vocab, size=plen).tolist(), new))
    return reqs


def bench_static(cfg, params, reqs, use_lamp):
    """Static batch: pad everything to the worst case, one generate() call."""
    max_prompt = max(len(p) for p, _ in reqs)
    max_new = max(n for _, n in reqs)
    tokens = np.zeros((len(reqs), max_prompt), np.int32)
    for i, (p, _) in enumerate(reqs):
        tokens[i, max_prompt - len(p):] = p   # right-align; crude but typical
    serve = ServeConfig(max_new_tokens=max_new, use_lamp=use_lamp,
                        cache_len=max_prompt + max_new + 8)
    t0 = time.monotonic()
    out = generate(cfg, params, {"tokens": jnp.asarray(tokens)}, serve)
    jax.block_until_ready(out["tokens"])
    wall = time.monotonic() - t0
    useful = sum(n for _, n in reqs)
    return {"wall_s": wall, "useful_tok_per_s": useful / wall,
            "padded_tok_per_s": len(reqs) * max_new / wall}


def bench_engine(cfg, params, reqs, use_lamp):
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=8, max_model_len=128, use_lamp=use_lamp))
    t0 = time.monotonic()
    for i, (prompt, new) in enumerate(reqs):
        engine.add_request(prompt, SamplingParams(max_new_tokens=new, seed=i))
    outs = engine.run_to_completion()
    wall = time.monotonic() - t0
    lat = sorted(o.latency for o in outs)
    s = engine.stats()
    useful = sum(n for _, n in reqs)
    return {"wall_s": wall, "useful_tok_per_s": useful / wall,
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p99_s": float(np.percentile(lat, 99)),
            "kv_util_mean": s["kv_util_mean"],
            "lamp_rate": s["lamp_recompute_rate"],
            "preemptions": s["preemptions"]}


def make_shared_prefix_requests(rng, cfg, n, groups=4, prefix_len=32,
                                min_suffix=4, max_suffix=16, new_tokens=8):
    """Groups of prompts sharing a long per-group prefix (system prompts)."""
    prefixes = [rng.integers(0, cfg.vocab, size=prefix_len).tolist()
                for _ in range(groups)]
    reqs = []
    for i in range(n):
        if i % 5 == 4 and reqs:
            # exact duplicate of the previous prompt: the match is capped at
            # prompt-1 tokens, exercising the mid-block copy-on-write path
            reqs.append(reqs[-1])
            continue
        suffix = rng.integers(
            0, cfg.vocab,
            size=int(rng.integers(min_suffix, max_suffix + 1))).tolist()
        reqs.append((prefixes[i % groups] + suffix, new_tokens))
    return reqs


def run_prefix_stream(cfg, params, reqs, *, prefix_cache, chunked_prefill,
                      use_lamp=True):
    """Replay the stream with arrivals staggered one prefill step apart, so
    later arrivals can hit the prefix cache of earlier ones."""
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=8, max_model_len=128, max_prefill_tokens=24,
        use_lamp=use_lamp, prefix_cache=prefix_cache,
        chunked_prefill=chunked_prefill))
    t0 = time.monotonic()
    outs = []
    for i, (prompt, new) in enumerate(reqs):
        engine.add_request(prompt,
                           SamplingParams(max_new_tokens=new, seed=i))
        outs.extend(engine.step())     # admit + run one step per arrival
    outs.extend(engine.run_to_completion())
    wall = time.monotonic() - t0
    s = engine.stats()
    return {"wall_s": wall,
            "tokens": {o.req_id: o.tokens for o in outs},
            "blocks_allocated": s["blocks_allocated"],
            "blocks_saved": s["blocks_saved"],
            "cache_hit_rate": s["cache_hit_rate"],
            "prefill_tokens_run": s["prefill_tokens_run"],
            "cow_copies": s["cow_copies"],
            "prefill_chunks": s["prefill_chunks"]}


def bench_prefix_cache(cfg, params, rng, n_requests):
    reqs = make_shared_prefix_requests(rng, cfg, n_requests)
    on = run_prefix_stream(cfg, params, reqs, prefix_cache=True,
                           chunked_prefill=True)
    off = run_prefix_stream(cfg, params, reqs, prefix_cache=False,
                            chunked_prefill=False)
    identical = on["tokens"] == off["tokens"]
    saved = 1.0 - on["blocks_allocated"] / max(1, off["blocks_allocated"])
    print(f"serve_prefix_cache_on,{on['wall_s']*1e6:.0f},"
          f"blocks={on['blocks_allocated']}"
          f";hit_rate={on['cache_hit_rate']:.2f}"
          f";cow={on['cow_copies']};chunks={on['prefill_chunks']}")
    print(f"serve_prefix_cache_off,{off['wall_s']*1e6:.0f},"
          f"blocks={off['blocks_allocated']}")
    print(f"serve_prefix_cache_savings,0,"
          f"blocks_saved={saved:.1%};outputs_identical={identical}")
    if not identical:
        raise SystemExit("prefix-cache outputs diverged from baseline")
    return saved


def run_kernel_stream(cfg, params, reqs, kernel, *, block_size=8,
                      max_model_len=128):
    """All requests admitted up front -> a fat continuous decode batch."""
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=block_size, max_model_len=max_model_len,
        max_decode_batch=16, use_lamp=True, kernel=kernel))
    for i, (prompt, new) in enumerate(reqs):
        engine.add_request(prompt, SamplingParams(max_new_tokens=new, seed=i))
    outs, dec_wall, dec_steps, conc = [], 0.0, 0, []
    while engine.has_unfinished():
        before = engine.decode_steps
        alive = len(engine.scheduler.running)
        t0 = time.monotonic()
        done = engine.step()
        dt = time.monotonic() - t0
        if engine.decode_steps > before:
            dec_wall += dt
            dec_steps += 1
            conc.append(alive)
        outs.extend(done)
    final_lens = [len(p) + n for p, n in reqs]
    from repro.kernels.paged_attention import decode_kv_bytes
    b_gather, b_fused = decode_kv_bytes(
        final_lens, n_max=engine.blocks_per_seq, block_size=block_size,
        bytes_per_token=cfg.n_kv_heads * cfg.hd * 4, lamp=True)
    return {"tokens": {o.req_id: o.tokens for o in outs},
            "decode_step_us": dec_wall / max(dec_steps, 1) * 1e6,
            "mean_concurrency": float(np.mean(conc)) if conc else 0.0,
            "bytes_per_step": b_fused if kernel == "pallas" else b_gather,
            "lamp_rate": engine.stats()["lamp_recompute_rate"]}


def bench_kernel_paths(cfg, params, rng, n_requests):
    """Gather vs fused paged attention on one decode-heavy stream."""
    n = max(n_requests, 12)
    reqs = make_requests(rng, cfg, n, min_prompt=6, max_prompt=24,
                         min_new=10, max_new=16)
    rows = {}
    for kernel in ("gather", "pallas"):
        run_kernel_stream(cfg, params, reqs[:2], kernel)   # warm compiles
        rows[kernel] = run_kernel_stream(cfg, params, reqs, kernel)
        r = rows[kernel]
        print(f"serve_kernel_{kernel},{r['decode_step_us']:.0f},"
              f"kv_bytes_per_step={r['bytes_per_step']}"
              f";concurrency={r['mean_concurrency']:.1f}"
              f";lamp_rate={r['lamp_rate']:.4f}")
    identical = rows["gather"]["tokens"] == rows["pallas"]["tokens"]
    saved = 1.0 - (rows["pallas"]["bytes_per_step"]
                   / max(1, rows["gather"]["bytes_per_step"]))
    print(f"serve_kernel_fused_vs_gather,0,"
          f"bytes_saved={saved:.1%};outputs_identical={identical}"
          f";concurrency={rows['pallas']['mean_concurrency']:.1f}")
    if not identical:
        raise SystemExit("fused-kernel outputs diverged from gather path")
    if rows["pallas"]["mean_concurrency"] < 8:
        raise SystemExit("kernel bench fell below 8 concurrent sequences")
    if saved <= 0:
        raise SystemExit("fused kernel did not reduce modeled KV traffic")
    return saved


def run_spec_stream(cfg, params, reqs, *, speculative, draft_len=4,
                    kernel="gather"):
    """Decode-heavy stream, all requests admitted up front."""
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=8, max_model_len=128, max_decode_batch=16,
        use_lamp=True, kernel=kernel, speculative=speculative,
        draft_len=draft_len))
    t0 = time.monotonic()
    for i, (prompt, new) in enumerate(reqs):
        engine.add_request(prompt, SamplingParams(max_new_tokens=new, seed=i))
    outs = engine.run_to_completion()
    wall = time.monotonic() - t0
    s = engine.stats()
    useful = sum(n for _, n in reqs)
    return {"tokens": {o.req_id: o.tokens for o in outs},
            "wall_s": wall, "useful_tok_per_s": useful / wall,
            "decode_rounds": s["decode_steps"],
            "tokens_per_round": (s["spec_tokens_per_round"] if speculative
                                 else 1.0),
            "acceptance_rate": s["spec_acceptance_rate"],
            "verify_recompute_rate": (s["verify_recompute_rate"]
                                      if speculative
                                      else s["lamp_recompute_rate"])}


def bench_speculative(cfg, params, rng, n_requests, draft_len=4):
    """LAMP self-draft speculative decoding on a decode-heavy greedy
    stream: spec-on vs spec-off must be token-identical; reports accepted
    tokens per decode round (the speedup lever: each round replaces that
    many sequential decode steps) and the verify pass's LAMP recompute
    rate vs the per-step rate of plain decoding."""
    n = max(n_requests, 8)
    reqs = make_requests(rng, cfg, n, min_prompt=6, max_prompt=20,
                         min_new=12, max_new=20)
    for spec in (False, True):
        # warm with the full stream so the measured runs hit the same
        # batch-bucket shapes and pay zero jit compilation
        run_spec_stream(cfg, params, reqs, speculative=spec,
                        draft_len=draft_len)
    off = run_spec_stream(cfg, params, reqs, speculative=False)
    on = run_spec_stream(cfg, params, reqs, speculative=True,
                         draft_len=draft_len)
    identical = on["tokens"] == off["tokens"]
    print(f"serve_spec_off,{off['wall_s']*1e6:.0f},"
          f"tok/s={off['useful_tok_per_s']:.1f}"
          f";decode_rounds={off['decode_rounds']}"
          f";tokens_per_round=1.00"
          f";lamp_rate={off['verify_recompute_rate']:.4f}")
    print(f"serve_spec_on,{on['wall_s']*1e6:.0f},"
          f"tok/s={on['useful_tok_per_s']:.1f}"
          f";decode_rounds={on['decode_rounds']}"
          f";tokens_per_round={on['tokens_per_round']:.2f}"
          f";acceptance_rate={on['acceptance_rate']:.3f}"
          f";verify_lamp_rate={on['verify_recompute_rate']:.4f}")
    rounds_saved = 1 - on["decode_rounds"] / max(1, off["decode_rounds"])
    print(f"serve_spec_vs_base,0,outputs_identical={identical}"
          f";rounds_saved={rounds_saved:.1%}"
          f";accepted_per_step={on['tokens_per_round']:.2f}")
    if not identical:
        raise SystemExit("speculative outputs diverged from baseline")
    if on["tokens_per_round"] <= 1.0:
        raise SystemExit("speculative decoding emitted <= 1 token per round")
    return on


def run_fused_stream(cfg, params, reqs, *, exec_, kernel):
    """Mixed-plan stream: all requests admitted up front so most steps mix
    a decode/verify majority with chunked-prefill windows riding along.
    exec_: "fused" (one launch per step) or "split" (the same plans through
    the legacy sub-steps). Runs from a cold step-fn cache so compile
    counts are comparable across arms."""
    from repro.serving.engine import reset_step_caches
    from repro.serving.fn_cache import STEP_FNS
    reset_step_caches()
    # pool sized to hold the whole batch resident (the auto default fits
    # ~4 full sequences): this arm measures launch/compile counts, not
    # preemption churn
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=8, n_blocks=160, max_model_len=128, max_prefill_tokens=48,
        max_decode_batch=16, use_lamp=True, kernel=kernel,
        chunked_prefill=True, speculative=True, draft_len=4,
        fused_step=True, mixed_exec=exec_))
    for i, (prompt, new) in enumerate(reqs):
        engine.add_request(prompt, SamplingParams(max_new_tokens=new, seed=i))
    conc, outs = [], []
    t0 = time.monotonic()
    while engine.has_unfinished():
        conc.append(len(engine.scheduler.running))
        outs.extend(engine.step())
    wall = time.monotonic() - t0
    s = engine.stats()
    return {"tokens": {o.req_id: o.tokens for o in outs},
            "wall_s": wall, "steps": s["steps"],
            "launches": s["launches"],
            "launches_per_step": s["launches"] / max(1, s["steps"]),
            "compiles": s["compiles"],
            "fn_entries": len(STEP_FNS),
            "mixed_steps": s["mixed_steps"],
            "mean_concurrency": float(np.mean(conc)) if conc else 0.0}


def bench_fused(cfg, params, rng, n_requests):
    """Fused single-launch mixed step vs its split-execution twin on one
    decode-heavy greedy stream, both kernels. The twin executes the SAME
    mixed plans through the legacy sub-steps, so any token divergence is a
    fused-launch bug, not a scheduling difference."""
    # speculation accepts several tokens per round, so requests drain fast;
    # the stream needs headroom to hold >= 8 concurrent sequences mid-run
    n = max(n_requests, 16)
    reqs = make_requests(rng, cfg, n, min_prompt=6, max_prompt=40,
                         min_new=24, max_new=32)
    for kernel in ("gather", "pallas"):
        rows = {}
        for exec_ in ("fused", "split"):
            r = run_fused_stream(cfg, params, reqs, exec_=exec_,
                                 kernel=kernel)
            rows[exec_] = r
            print(f"serve_fused_{kernel}_{exec_},{r['wall_s']*1e6:.0f},"
                  f"steps={r['steps']}"
                  f";launches_per_step={r['launches_per_step']:.2f}"
                  f";compiles={r['compiles']}"
                  f";fn_entries={r['fn_entries']}"
                  f";concurrency={r['mean_concurrency']:.1f}")
        f, sp = rows["fused"], rows["split"]
        identical = f["tokens"] == sp["tokens"]
        print(f"serve_fused_vs_split_{kernel},0,"
              f"outputs_identical={identical}"
              f";launches={f['launches']}v{sp['launches']}"
              f";compiles={f['compiles']}v{sp['compiles']}"
              f";mixed_steps={f['mixed_steps']}")
        if not identical:
            raise SystemExit(f"fused-step outputs diverged from split "
                             f"execution on kernel={kernel}")
        if f["mean_concurrency"] < 8:
            raise SystemExit("fused-step bench fell below 8 concurrent "
                             "sequences")
        if f["launches"] >= sp["launches"]:
            raise SystemExit(f"fused step did not reduce kernel launches "
                             f"({f['launches']} vs {sp['launches']})")
        if not 0 < f["compiles"] < sp["compiles"]:
            raise SystemExit(f"fused step did not shrink the jit cache "
                             f"({f['compiles']} vs {sp['compiles']} "
                             f"compiled signatures)")
    return rows


def run_obs_stream(cfg, params, reqs, *, trace):
    """One stream, all requests admitted up front, with tracing on or off
    (the metrics registry itself is always on, by design)."""
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=8, max_model_len=128, max_decode_batch=16, use_lamp=True,
        obs=ObsConfig(trace=trace)))
    for i, (prompt, new) in enumerate(reqs):
        engine.add_request(prompt, SamplingParams(max_new_tokens=new, seed=i))
    t0 = time.monotonic()
    outs = engine.run_to_completion()
    wall = time.monotonic() - t0
    return {"tokens": {o.req_id: o.tokens for o in outs},
            "wall_s": wall, "steps": engine.total_steps,
            "us_per_step": wall / max(1, engine.total_steps) * 1e6,
            "engine": engine}


def bench_obs(cfg, params, rng, n_requests):
    """Observability cost: tracing on vs off must be token-identical, and
    the per-step overhead of recording every phase span must stay small
    (<5% is the acceptance bar; the dominant cost per step is the jitted
    model call, so span bookkeeping should be noise). Also emits the
    per-phase mean wall times the trace/metrics pipeline measured."""
    n = max(n_requests, 8)
    reqs = make_requests(rng, cfg, n, min_prompt=6, max_prompt=24,
                         min_new=8, max_new=16)
    for trace in (False, True):                      # warm the jit caches
        run_obs_stream(cfg, params, reqs, trace=trace)
    # best-of-2 per arm: per-step walls are a few ms on CPU, so a single
    # noisy run could fake (or mask) the overhead being measured
    off, on = [min((run_obs_stream(cfg, params, reqs, trace=t)
                    for _ in range(2)), key=lambda r: r["us_per_step"])
               for t in (False, True)]
    identical = on["tokens"] == off["tokens"]
    overhead = (on["us_per_step"] - off["us_per_step"]) / off["us_per_step"]
    print(f"serve_obs_off,{off['us_per_step']:.0f},steps={off['steps']}")
    print(f"serve_obs_on,{on['us_per_step']:.0f},steps={on['steps']}"
          f";trace_events={len(on['engine'].obs.tracer.events())}")
    print(f"serve_obs_overhead,0,overhead={overhead:+.1%}"
          f";outputs_identical={identical}")
    for name, h in sorted(on["engine"].obs._phase_children.items()):
        if h.count:
            print(f"serve_obs_phase_{name},{h.mean * 1e6:.0f},"
                  f"count={h.count};p99_us={h.quantile(0.99) * 1e6:.0f}")
    if not identical:
        raise SystemExit("tracing-on outputs diverged from tracing-off")
    if overhead > 0.05:
        raise SystemExit(f"observability overhead {overhead:.1%} exceeds "
                         f"the 5% per-step budget")
    return overhead


def run_policy_stream(cfg, params, reqs, *, mode, n_blocks=40, draft_len=4,
                      target_rate=0.05, util_high=0.55, util_low=0.35,
                      shed_util=0.80):
    """Burst load: every request admitted up front into a deliberately
    small pool, so utilization and preemption pressure climb fast enough
    to exercise the controller's degradation ladder.

    mode: "off" (no controller), "frozen" (controller observes and
    publishes but never actuates -- must be token-identical to off), or
    "on" (full actuation)."""
    policy = PolicyConfig(
        enabled=(mode != "off"), frozen=(mode == "frozen"),
        target_rate=target_rate, interval=1,
        util_high=util_high, util_low=util_low, shed_util=shed_util)
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=8, max_model_len=128, max_decode_batch=16,
        n_blocks=n_blocks, use_lamp=True, speculative=True,
        draft_len=draft_len, policy=policy))
    for i, (prompt, new) in enumerate(reqs):
        engine.add_request(prompt, SamplingParams(max_new_tokens=new, seed=i))
    outs, walls = [], []
    t0 = time.monotonic()
    while engine.has_unfinished():
        s0 = time.monotonic()
        outs.extend(engine.step())
        walls.append(time.monotonic() - s0)
    wall = time.monotonic() - t0
    s = engine.stats()
    return {"tokens": {o.req_id: o.tokens for o in outs},
            "wall_s": wall,
            "step_p99_us": float(np.percentile(walls, 99)) * 1e6,
            "preemptions": s["preemptions"],
            "lamp_rate": s["lamp_recompute_rate"],
            "kv_util_mean": s["kv_util_mean"],
            "compiles": s["compiles"],
            "policy": s["policy"]}


def bench_policy(cfg, params, rng, n_requests):
    """Adaptive LAMP policy controller under burst load (standalone via
    --policy-only, the CI policy-bench CSV artifact). Three arms on the
    same burst stream: controller off, frozen (observe-only: must be
    token-identical to off, zero actuations), and on (full actuation).
    The on-arm must actually traverse the degradation ladder (mode
    transitions > 0) and -- because tau rides through the jitted steps as
    a traced operand and the warm pass has already compiled every rule
    tier it visits -- trigger ZERO recompiles during the measured run."""
    n = max(n_requests, 16)
    reqs = make_requests(rng, cfg, n, min_prompt=6, max_prompt=24,
                         min_new=16, max_new=28)
    # warm every arm with the full stream: the controller's trajectory is
    # deterministic, so the warm on-run compiles every (bucket, rule-tier)
    # variant the measured on-run will visit
    for mode in ("off", "frozen", "on"):
        run_policy_stream(cfg, params, reqs, mode=mode, draft_len=8)
    off = run_policy_stream(cfg, params, reqs, mode="off", draft_len=8)
    frozen = run_policy_stream(cfg, params, reqs, mode="frozen", draft_len=8)
    on = run_policy_stream(cfg, params, reqs, mode="on", draft_len=8)
    identical = frozen["tokens"] == off["tokens"]
    pol = on["policy"]
    print(f"serve_policy_off,{off['wall_s']*1e6:.0f},"
          f"preemptions={off['preemptions']}"
          f";p99_step_us={off['step_p99_us']:.0f}"
          f";lamp_rate={off['lamp_rate']:.4f}"
          f";kv_util={off['kv_util_mean']:.2f}")
    print(f"serve_policy_frozen,{frozen['wall_s']*1e6:.0f},"
          f"outputs_identical={identical}"
          f";actuations={frozen['policy']['actuations']}"
          f";mode={frozen['policy']['mode']}")
    print(f"serve_policy_on,{on['wall_s']*1e6:.0f},"
          f"preemptions={on['preemptions']}"
          f";p99_step_us={on['step_p99_us']:.0f}"
          f";lamp_rate={on['lamp_rate']:.4f}"
          f";mode={pol['mode']}"
          f";transitions={pol['mode_transitions']}"
          f";actuations={pol['actuations']}"
          f";tau_mean={pol['tau_mean']:.4f}"
          f";draft_len={pol['draft_len']}"
          f";compiles={on['compiles']}")
    rate_delta = on["lamp_rate"] - off["lamp_rate"]
    print(f"serve_policy_degradation,0,"
          f"preempt_off={off['preemptions']};preempt_on={on['preemptions']}"
          f";p99_off_us={off['step_p99_us']:.0f}"
          f";p99_on_us={on['step_p99_us']:.0f}"
          f";lamp_rate_delta={rate_delta:+.4f}")
    if not identical:
        raise SystemExit("frozen-controller outputs diverged from "
                         "controller-off baseline")
    if frozen["policy"]["actuations"] != 0:
        raise SystemExit("frozen controller actuated")
    if pol["mode_transitions"] == 0:
        raise SystemExit("burst load did not trigger any policy mode "
                         "transition")
    if on["compiles"] != 0:
        raise SystemExit(f"policy actuation triggered {on['compiles']} "
                         f"recompiles after warmup (tau must ride as a "
                         f"traced operand)")
    # the on-arm's token stream diverges from off once the rule tier drops
    # (that IS the degradation), so preemption counts can wobble by a
    # couple of events; the invariant is "no meaningful regression"
    if on["preemptions"] > off["preemptions"] + 2:
        raise SystemExit("controller-on preempted meaningfully more than "
                         "controller-off under the same burst")
    if on["lamp_rate"] > off["lamp_rate"] + 0.10:
        raise SystemExit(f"controller-on recompute rate {on['lamp_rate']:.4f} "
                         f"exceeded the bounded-increase budget")
    return on


def run_audit_stream(cfg, params, reqs, *, rate, kernel="gather",
                     exec_="fused", salt=0):
    """Full-feature stream (chunked prefill + speculation + fused step) with
    the shadow auditor sampling at `rate`. Deterministic step hashing means
    two runs with the same salt audit exactly the same steps."""
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=8, n_blocks=160, max_model_len=128, max_prefill_tokens=48,
        max_decode_batch=16, use_lamp=True, kernel=kernel,
        chunked_prefill=True, speculative=True, draft_len=4,
        fused_step=True, mixed_exec=exec_,
        audit=AuditConfig(rate=rate, salt=salt)))
    for i, (prompt, new) in enumerate(reqs):
        engine.add_request(prompt, SamplingParams(max_new_tokens=new, seed=i))
    t0 = time.monotonic()
    outs = engine.run_to_completion()
    wall = time.monotonic() - t0
    steps = engine.total_steps
    return {"tokens": {o.req_id: o.tokens for o in outs},
            "wall_s": wall, "steps": steps,
            "us_per_step": wall / max(1, steps) * 1e6,
            "audit": engine.stats()["audit"]}


def bench_audit(cfg, params, rng, n_requests):
    """Shadow-audit cost and invariants (standalone via --audit-only, the
    CI audit-bench CSV artifact). Three checks on one full-feature stream
    (chunked prefill + speculation + fused step):

      1. zero token perturbation: audit at rate=1.0 must stream
         token-identical to audit-off, on BOTH kernels (the audit launch
         must never write back to the served KV arena);
      2. overhead: at the recommended sampling rate (0.05) the per-step
         cost of auditing stays under the 5%% budget (best-of-2, warmed);
      3. fused-vs-split audited-error delta: the fused mixed step and its
         split twin must show the same audited error (this is the burn-in
         gate behind fused_step defaulting on)."""
    n = max(n_requests, 8)
    reqs = make_requests(rng, cfg, n, min_prompt=6, max_prompt=24,
                         min_new=12, max_new=20)
    # -- 1. token identity, both kernels, every step audited ---------------
    for kernel in ("gather", "pallas"):
        off = run_audit_stream(cfg, params, reqs, rate=0.0, kernel=kernel)
        on = run_audit_stream(cfg, params, reqs, rate=1.0, kernel=kernel)
        identical = on["tokens"] == off["tokens"]
        a = on["audit"]
        print(f"serve_audit_{kernel},{on['wall_s']*1e6:.0f},"
              f"outputs_identical={identical}"
              f";audited_steps={a['audited_steps']}"
              f";audited_rows={a['audited_rows']}"
              f";flip_rate={a['flip_rate']:.4f}"
              f";logit_rel_err={a['logit_rel_err']:.3e}")
        if not identical:
            raise SystemExit(f"audit-on outputs diverged from audit-off on "
                             f"kernel={kernel} (the audit must not perturb "
                             f"served tokens)")
        if a["audited_steps"] != on["steps"]:
            raise SystemExit("rate=1.0 audit did not cover every step")
    # -- 2. per-step overhead at the recommended sampling rate -------------
    for rate in (0.0, 0.05):                        # warm the jit caches
        run_audit_stream(cfg, params, reqs, rate=rate)
    # best-of-2 per arm: per-step walls are a few ms on CPU, so one noisy
    # run could fake (or mask) the overhead being measured
    off, on = [min((run_audit_stream(cfg, params, reqs, rate=r)
                    for _ in range(2)), key=lambda x: x["us_per_step"])
               for r in (0.0, 0.05)]
    overhead = (on["us_per_step"] - off["us_per_step"]) / off["us_per_step"]
    print(f"serve_audit_off,{off['us_per_step']:.0f},steps={off['steps']}")
    print(f"serve_audit_sampled,{on['us_per_step']:.0f},"
          f"steps={on['steps']}"
          f";audited_steps={on['audit']['audited_steps']}")
    print(f"serve_audit_overhead,0,overhead={overhead:+.1%}"
          f";rate=0.05")
    if overhead > 0.05:
        raise SystemExit(f"audit overhead {overhead:.1%} at rate=0.05 "
                         f"exceeds the 5% per-step budget")
    # -- 3. fused vs split audited error (the fused default's gate) --------
    fused = run_audit_stream(cfg, params, reqs, rate=1.0, exec_="fused")
    split = run_audit_stream(cfg, params, reqs, rate=1.0, exec_="split")
    fa, sa = fused["audit"], split["audit"]
    d_rel = abs(fa["logit_rel_err"] - sa["logit_rel_err"])
    d_flip = abs(fa["flip_rate"] - sa["flip_rate"])
    print(f"serve_audit_fused_vs_split,0,"
          f"rel_err_delta={d_rel:.2e};flip_delta={d_flip:.4f}"
          f";fused_rel_err={fa['logit_rel_err']:.3e}"
          f";split_rel_err={sa['logit_rel_err']:.3e}")
    if d_flip > 0 or d_rel > 1e-6:
        raise SystemExit(f"fused step changed audited error vs split twin "
                         f"(rel delta {d_rel:.2e}, flip delta {d_flip:.4f})"
                         f" -- the fused-default burn-in gate failed")
    return overhead


def run_faults_stream(cfg, params, reqs, *, faults=None, guard=True,
                      stall_patience=16):
    """Full-feature stream (chunked prefill + speculation + fused step)
    with optional deterministic fault injection and the numerical health
    guard on/off. Same salt + rates + stream replays identical faults."""
    engine = LampEngine(cfg, params, EngineConfig(
        block_size=8, n_blocks=160, max_model_len=128, max_prefill_tokens=48,
        max_decode_batch=16, use_lamp=True, chunked_prefill=True,
        speculative=True, draft_len=4, fused_step=True,
        health_guard=guard, stall_patience=stall_patience,
        faults=faults if faults is not None else FaultConfig()))
    for i, (prompt, new) in enumerate(reqs):
        engine.add_request(prompt, SamplingParams(max_new_tokens=new, seed=i))
    t0 = time.monotonic()
    outs = engine.run_to_completion()
    wall = time.monotonic() - t0
    steps = engine.total_steps
    s = engine.stats()
    return {"tokens": {o.req_id: o.tokens for o in outs},
            "outs": {o.req_id: o for o in outs},
            "wall_s": wall, "steps": steps,
            "us_per_step": wall / max(1, steps) * 1e6,
            "faults": s["faults"], "recoveries": s["recoveries"],
            "failed": s["failed_requests"]}


def bench_faults(cfg, params, rng, n_requests):
    """Fault tolerance (standalone via --faults-only, the CI chaos CSV
    artifact). Two gates on one full-feature stream:

      1. health-guard overhead: with no faults firing, the per-row
         non-finite checks (an in-jit reduce plus a host float compare)
         must stream token-identical to guard-off and cost < 5%% per step
         (best-of-2, warmed);
      2. chaos: a fixed-seed injected-fault stream (NaN poisoning +
         allocation failures + a stall) must complete with ZERO engine
         crashes, every request individually finished (recovered requests
         token-identical to the fault-free run -- recovery replays the
         same keyed sampling stream -- and failed ones carrying a
         diagnostic error), and must replay bit-for-bit."""
    n = max(n_requests, 8)
    reqs = make_requests(rng, cfg, n, min_prompt=6, max_prompt=24,
                         min_new=12, max_new=20)
    # -- 1. health-guard overhead, no faults -------------------------------
    for guard in (False, True):                     # warm the jit caches
        run_faults_stream(cfg, params, reqs, guard=guard)
    off, on = [min((run_faults_stream(cfg, params, reqs, guard=g)
                    for _ in range(2)), key=lambda x: x["us_per_step"])
               for g in (False, True)]
    identical = on["tokens"] == off["tokens"]
    overhead = (on["us_per_step"] - off["us_per_step"]) / off["us_per_step"]
    print(f"serve_guard_off,{off['us_per_step']:.0f},steps={off['steps']}")
    print(f"serve_guard_on,{on['us_per_step']:.0f},steps={on['steps']}")
    print(f"serve_guard_overhead,0,overhead={overhead:+.1%}"
          f";outputs_identical={identical}")
    if not identical:
        raise SystemExit("health-guard-on outputs diverged from guard-off "
                         "with no faults firing")
    if overhead > 0.05:
        raise SystemExit(f"health-guard overhead {overhead:.1%} exceeds "
                         f"the 5% per-step budget")
    # -- 2. chaos: fixed-seed fault stream must be absorbed ----------------
    chaos_cfg = FaultConfig(enabled=True, salt=7, nan_rate=0.10,
                            alloc_rate=0.10, stall_rate=0.02,
                            stall_steps=3, stall_s=0.0)
    base = run_faults_stream(cfg, params, reqs)
    chaos = run_faults_stream(cfg, params, reqs, faults=chaos_cfg,
                              stall_patience=4)
    replay = run_faults_stream(cfg, params, reqs, faults=chaos_cfg,
                               stall_patience=4)
    f = chaos["faults"]
    by = " ".join(f"{k}={v}" for k, v in f["by_site"].items())
    print(f"serve_chaos,{chaos['us_per_step']:.0f},steps={chaos['steps']}"
          f";injected={f['injected']};{by}"
          f";recoveries={chaos['recoveries']};failed={chaos['failed']}")
    if f["injected"] == 0:
        raise SystemExit("chaos arm injected zero faults -- the gate is "
                         "vacuous; raise the rates or the request count")
    if len(chaos["outs"]) != len(base["outs"]):
        raise SystemExit(f"chaos run finished {len(chaos['outs'])} of "
                         f"{len(base['outs'])} requests -- some were "
                         f"dropped without a finish reason")
    mismatched = []
    for rid, o in chaos["outs"].items():
        if o.finish_reason is None:
            raise SystemExit(f"chaos req {rid} has no finish_reason")
        if o.error is None and o.tokens != base["tokens"][rid]:
            mismatched.append(rid)
    if mismatched:
        raise SystemExit(f"chaos requests {mismatched} recovered but are "
                         f"not token-identical to the fault-free run")
    if (replay["tokens"] != chaos["tokens"]
            or replay["faults"] != chaos["faults"]):
        raise SystemExit("chaos replay diverged: same salt + rates + "
                         "stream must inject and recover identically")
    print(f"serve_chaos_replay,0,identical=True"
          f";failed_with_error={chaos['failed']}")
    return overhead


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--spec-only", action="store_true",
                    help="run only the speculative-decoding section (the "
                         "CI spec-decode CSV artifact)")
    ap.add_argument("--obs-only", action="store_true",
                    help="run only the observability-cost section (the CI "
                         "obs CSV artifact)")
    ap.add_argument("--policy-only", action="store_true",
                    help="run only the adaptive-policy burst section (the "
                         "CI policy-bench CSV artifact)")
    ap.add_argument("--fused-only", action="store_true",
                    help="run only the fused-step vs split-twin section "
                         "(the CI fused-step CSV artifact)")
    ap.add_argument("--audit-only", action="store_true",
                    help="run only the shadow-audit section (the CI "
                         "audit-bench CSV artifact)")
    ap.add_argument("--faults-only", action="store_true",
                    help="run only the fault-tolerance section (the CI "
                         "chaos CSV artifact): health-guard overhead gate "
                         "plus a fixed-seed injected-fault stream")
    args = ap.parse_args()

    cfg = reduce_cfg(get_config("gpt2"))
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = make_requests(rng, cfg, args.requests)

    print("name,us_per_call,derived")
    if args.spec_only:
        bench_speculative(cfg, params, rng, args.requests)
        return
    if args.obs_only:
        bench_obs(cfg, params, rng, args.requests)
        return
    if args.policy_only:
        bench_policy(cfg, params, rng, args.requests)
        return
    if args.fused_only:
        bench_fused(cfg, params, rng, args.requests)
        return
    if args.audit_only:
        bench_audit(cfg, params, rng, args.requests)
        return
    if args.faults_only:
        bench_faults(cfg, params, rng, args.requests)
        return
    results = {}
    for mode in ("static", "engine"):
        for use_lamp in (False, True):
            fn = bench_static if mode == "static" else bench_engine
            # warmup compiles, then measure
            fn(cfg, params, reqs, use_lamp)
            r = fn(cfg, params, reqs, use_lamp)
            results[(mode, use_lamp)] = r
            tag = f"serve_{mode}_{'lamp' if use_lamp else 'fp32'}"
            derived = f"tok/s={r['useful_tok_per_s']:.1f}"
            if mode == "engine":
                derived += (f";p50={r['latency_p50_s']*1e3:.0f}ms"
                            f";p99={r['latency_p99_s']*1e3:.0f}ms"
                            f";kv_util={r['kv_util_mean']:.2f}"
                            f";lamp_rate={r['lamp_rate']:.4f}")
            print(f"{tag},{r['wall_s']*1e6:.0f},{derived}")

    for mode in ("static", "engine"):
        off = results[(mode, False)]["useful_tok_per_s"]
        on = results[(mode, True)]["useful_tok_per_s"]
        print(f"serve_{mode}_lamp_overhead,0,"
              f"overhead={100.0 * (off - on) / off:.1f}%")
    spd = (results[("engine", True)]["useful_tok_per_s"] /
           results[("static", True)]["useful_tok_per_s"])
    print(f"serve_engine_vs_static,0,speedup={spd:.2f}x")

    bench_prefix_cache(cfg, params, rng, args.requests)

    bench_kernel_paths(cfg, params, rng, args.requests)

    bench_speculative(cfg, params, rng, args.requests)

    bench_fused(cfg, params, rng, args.requests)

    bench_obs(cfg, params, rng, args.requests)

    bench_policy(cfg, params, rng, args.requests)

    bench_audit(cfg, params, rng, args.requests)

    bench_faults(cfg, params, rng, args.requests)


if __name__ == "__main__":
    main()
